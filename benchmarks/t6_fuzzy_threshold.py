"""Table 6: fuzzy keyword matching — threshold sweep (hit rate vs accuracy).

The sweep also carries the ``repro.index`` backend dimension: the
``*_bucketed`` row runs the same workload with the LSH-backed matcher —
at Table 4's 100-entry cache it falls back to the exact scan, so its
hit-rate/accuracy must match the brute row (a live consistency check).
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.agent_loop import AgentConfig
from repro.core.harness import run_workload


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    rows = []
    settings = [("exact_1.00", False, 1.0, "brute"),
                ("fuzzy_0.80", True, 0.8, "brute"),
                ("fuzzy_0.80_bucketed", True, 0.8, "bucketed"),
                ("fuzzy_0.60", True, 0.6, "brute")]
    for label, fz, thr, backend in settings:
        r = run_workload(
            "financebench", "apc", n,
            agent_cfg=AgentConfig(fuzzy=fz, fuzzy_threshold=thr,
                                  index_backend=backend),
        )
        rows.append(
            Row(
                f"t6/financebench/{label}",
                0.0,
                {
                    "hit_rate": round(r.hit_rate, 3),
                    "cost_usd": round(r.cost, 4),
                    "accuracy": round(r.accuracy, 4),
                    "latency_s": round(r.latency_s, 1),
                },
            )
        )
    return rows

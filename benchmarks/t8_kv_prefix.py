"""t8: paged KV prefix cache — tokens prefetched vs prefilled per hit.

The APC claim this measures: a plan-cache hit re-serves a known template
prefix, so with the paged KV pool wired the engine prefills only the
adaptation suffix. Rows report, per hit at batch >= 4:

  * ``t8/full_prefill``   — the no-prefix baseline: every hit prefills
    template + adaptation (tokens_prefilled = B * (Sp + Ss))
  * ``t8/prefix_prefill`` — the paged path: suffix-only prefill with the
    template KV gathered from the page pool (tokens_prefilled = B * Ss,
    tokens_prefetched = B * Sp); ``prefill_drop_pct`` is the headline
    (acceptance: >= 50%)
  * ``t8/paged_attention``— one decode step read through the page table
    (kernels/paged_attention.py) vs the dense decode kernel on the
    gathered cache; ``bit_match`` must be true (page_size == block_k ->
    identical arithmetic)
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import registry
from repro.kernels import ops
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.kv_cache import KVPrefixCache, plan_cache_point, pool_for_config


def run(fast: bool = False) -> List[Row]:
    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, Sp, Ss = 4, 32, 8
    page_size = 8
    pool = pool_for_config(cfg, num_pages=16, page_size=page_size)
    kv = KVPrefixCache(pool)
    eng = Engine(cfg, params, max_len=64, kv_prefix=kv)

    rs = np.random.RandomState(0)
    template = rs.randint(3, 400, (Sp,)).astype(np.int32)
    suffix = rs.randint(3, 400, (B, Ss)).astype(np.int32)
    prompts = np.concatenate([np.broadcast_to(template, (B, Sp)), suffix], 1)
    point = plan_cache_point("t8-template", template, prompts)
    assert point is not None and point.prefix_len == Sp

    rows: List[Row] = []
    repeats = 2 if fast else 3

    # baseline: every hit re-prefills template + adaptation
    us_full = timeit(lambda: eng.prefill(prompts), repeats=repeats)
    rows.append(Row("t8/full_prefill", us_full, {
        "batch": B, "prefix_len": Sp, "suffix_len": Ss,
        "tokens_prefilled_per_hit": B * (Sp + Ss),
    }))

    # the paged path: register once (the miss), then suffix-only hits
    _, cache = eng.prefill(prompts)
    eng.register_prefix(point.template_id, cache, point.prefix_len)
    us_pfx = timeit(
        lambda: eng.prefill_with_prefix(point.template_id, suffix),
        repeats=repeats,
    )
    prefilled = B * Ss
    drop = 100.0 * (1.0 - prefilled / (B * (Sp + Ss)))
    rows.append(Row("t8/prefix_prefill", us_pfx, {
        "batch": B, "prefix_len": Sp, "suffix_len": Ss,
        "tokens_prefilled_per_hit": prefilled,
        "tokens_prefetched_per_hit": B * Sp,
        "prefill_drop_pct": round(drop, 1),
        "pages_per_hit": -(-Sp // page_size),
    }))

    # paged-attention decode through the page table vs the dense kernel
    # on the gathered cache: with page_size == block_k the arithmetic is
    # block-identical, so outputs must BIT-match
    leases = [kv.acquire(point.template_id) for _ in range(B)]
    table, lengths = kv.page_table(leases)
    layer = 0
    k_pages, v_pages = pool.kernel_view(layer)
    q = jax.random.normal(
        jax.random.PRNGKey(1), (B, 1, cfg.num_heads, cfg.head_dim), jnp.float32
    )
    o_paged = ops.paged_decode_attention_op(q, k_pages, v_pages, table, lengths)
    pt = np.maximum(np.asarray(table, np.int64), 0)
    kd = jnp.asarray(np.asarray(k_pages)[pt].reshape(B, -1, cfg.num_kv_heads,
                                                     cfg.head_dim))
    vd = jnp.asarray(np.asarray(v_pages)[pt].reshape(B, -1, cfg.num_kv_heads,
                                                     cfg.head_dim))
    o_dense = ops.decode_attention_op(q, kd, vd, lengths, block_k=page_size)
    bit_match = bool(np.array_equal(np.asarray(o_paged), np.asarray(o_dense)))
    us_paged = timeit(
        lambda: ops.paged_decode_attention_op(
            q, k_pages, v_pages, table, lengths
        ).block_until_ready(),
        repeats=repeats,
    )
    for lease in leases:
        kv.release_lease(lease)
    rows.append(Row("t8/paged_attention", us_paged, {
        "batch": B, "pages": int(table.shape[1]), "page_size": page_size,
        "bit_match": bit_match,
    }))
    return rows

"""Tables 9-11: model-choice sensitivity (planner/actor quality + pricing).

Each named configuration shifts the QualityProfile + pricing map the way the
paper's model swaps do (e.g. Claude-3.5 as large planner: higher p_plan,
higher $; Llama-3.2-3B actor: lower p_actor, cheaper).
"""

from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import Row
from repro.configs.apc_minion import APCDeployment
from repro.core.backends import DEFAULT_QUALITY
from repro.core.harness import run_workload

VARIANTS = {
    # label -> (quality overrides, pricing overrides)
    "large=gpt-4o": ({}, {}),
    "large=claude-3.5": (
        {"p_plan_large": 0.985},
        {"large_planner": "claude-3.5-sonnet"},
    ),
    "small=qwen-2.5-7b": (
        {"p_adapt": 0.96},
        {"small_planner": "qwen-2.5-7b"},
    ),
    "small=llama-3.2-3b": (
        {"p_adapt": 0.915},
        {"small_planner": "llama-3.2-3b"},
    ),
    "actor=llama-3.2-3b": (
        {"p_actor": 0.94},
        {"actor": "llama-3.2-3b"},
    ),
    "actor=qwen-2.5-7b": (
        {"p_actor": 0.99},
        {"actor": "qwen-2.5-7b"},
    ),
}


def run(fast: bool = False) -> List[Row]:
    n = 60 if fast else 200
    rows = []
    for label, (q_over, p_over) in VARIANTS.items():
        quality = dataclasses.replace(DEFAULT_QUALITY, **q_over)
        pricing = dict(APCDeployment().pricing)
        pricing.update(p_over)
        dep = dataclasses.replace(APCDeployment(), pricing=pricing)
        for method in ("accuracy_optimal", "apc"):
            r = run_workload("financebench", method, n,
                             deployment=dep, quality=quality)
            rows.append(
                Row(
                    f"t9/{label}/{method}",
                    0.0,
                    {"accuracy": round(r.accuracy, 4),
                     "cost_usd": round(r.cost, 4)},
                )
            )
    return rows

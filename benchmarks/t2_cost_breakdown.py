"""Table 2: per-component cost breakdown, main results + worst case
(zero hit rate — forced by a capacity-0 cache so every task misses and
regenerates its cache entry)."""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.agent_loop import AgentConfig
from repro.core.cache import PlanCache
from repro.core.harness import run_workload


def _breakdown_row(env: str, label: str, res) -> Row:
    total = res.cost
    comp = {}
    for role, d in res.breakdown.items():
        comp[role] = {"usd": d["cost"], "pct": round(100 * d["cost"] / total, 2)}
    overhead = sum(
        res.breakdown.get(r, {}).get("cost", 0.0)
        for r in ("keyword_extractor", "cache_generator")
    )
    return Row(
        f"t2/{env}/{label}",
        0.0,
        {
            "total_usd": round(total, 4),
            "overhead_pct": round(100 * overhead / total, 2),
            **{k: v["pct"] for k, v in comp.items()},
        },
    )


def run(fast: bool = False) -> List[Row]:
    n = 60 if fast else 200
    rows = []
    for env in (["financebench"] if fast else ["financebench", "tabmwp"]):
        main = run_workload(env, "apc", n)
        rows.append(_breakdown_row(env, "main", main))
        worst = run_workload(
            env, "apc", n, cache=PlanCache(capacity=0)
        )  # zero hit rate
        assert worst.hit_rate == 0.0
        rows.append(_breakdown_row(env, "worst_case", worst))
    return rows

"""Table 5: exact vs fuzzy cache-lookup latency vs cache size (µs).

Exact matching uses the dict-backed PlanCache (O(1)). Fuzzy matching now
carries an **index-backend dimension** (``repro.index``):

* ``brute``     the paper prototype's O(N*dim) numpy cosine scan — this is
                the Table 5 scaling cliff, kept as the baseline;
* ``pallas``    ``ops.batch_topk`` blocked kernel against the *host* bank:
                every call re-uploads the whole ``capacity * DIM * 4``-byte
                arena to the device. On this CPU container it runs in
                interpret mode (constant-factor slow; capped at 50k
                entries) — on TPU the identical call compiles to Mosaic;
* ``bucketed``  multi-probe SRP-LSH candidate generation: sublinear in N,
                falling back to the exact brute scan below its size
                threshold (so small sizes print identical latencies);
* ``device``    ``ops.resident_topk`` against a device-resident
                ``DeviceBank`` arena: the bank never travels again after
                admission, so steady-state H2D is the query batch only
                (~DIM*4 bytes/lookup vs the pallas column's
                ``capacity*DIM*4``).

Every fuzzy row's ``derived`` includes ``h2d_per_lookup`` — host-to-device
bytes moved per lookup (0 for the host-resident brute/bucketed backends;
measured from DeviceBank telemetry for ``device``; the full arena + query
upload for ``pallas``).

Rows: ``t5/exact/{n}``, ``t5/fuzzy/{backend}/{n}``, plus derived speedup
rows at the largest common size: ``t5/fuzzy/speedup_bucketed_vs_brute/{n}``
and ``t5/fuzzy/speedup_device_vs_pallas/{n}`` (hit_x/miss_x = how many
times faster the resident-bank device backend answers the same lookups
than the re-uploading host-bank pallas backend).

Standalone CLI (the CI docs job smoke-tests ``--help``):

    PYTHONPATH=src python -m benchmarks.t5_lookup_scalability \
        --backend device --fast
"""

from __future__ import annotations

import pathlib
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

if __package__ in (None, ""):  # direct-file execution: python benchmarks/t5_...
    _root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root / "src"))
    sys.path.insert(0, str(_root))

from benchmarks.common import Row, timeit
from repro.core.cache import PlanCache
from repro.index import DIM, SimilarityIndex

FUZZY_BACKENDS = ("brute", "pallas", "bucketed", "device")
PALLAS_MAX_N = 50_000  # interpret-mode CPU cap; on TPU there is no cap
DEVICE_MAX_N = 100_000  # bounds resident-arena memory on the CPU container
ADMISSION_WAVE = 8192  # device builds insert in waves (one scatter each)


def _fill_exact(n: int) -> PlanCache:
    c = PlanCache(capacity=n + 1)
    for i in range(n):
        c.insert(f"intent keyword number {i}", i)
    return c


def _build_index(backend: str, M: np.ndarray) -> SimilarityIndex:
    # build in admission waves for every backend: one lock acquisition and
    # (device) one donated multi-slot scatter per wave, instead of paying
    # N Python-level add() calls at the 1M sizes
    idx = SimilarityIndex(backend=backend, initial_capacity=M.shape[0])
    for lo in range(0, M.shape[0], ADMISSION_WAVE):
        hi = min(lo + ADMISSION_WAVE, M.shape[0])
        idx.add_batch([f"k{i}" for i in range(lo, hi)], M[lo:hi])
    return idx


def _skip(backend: str, n: int) -> bool:
    return (backend == "pallas" and n > PALLAS_MAX_N) or (
        backend == "device" and n > DEVICE_MAX_N
    )


def run(
    fast: bool = False, backends: Optional[Sequence[str]] = None
) -> List[Row]:
    # fast still reaches 50k: the brute-vs-bucketed and pallas-vs-device
    # gaps are the point of this table, and they only become unambiguous
    # past ~10k entries
    sizes = ([100, 1_000, 10_000, 50_000] if fast
             else [100, 1_000, 10_000, 50_000, 100_000, 1_000_000])
    backends = tuple(backends) if backends else FUZZY_BACKENDS
    # the device column's acceptance metric is its speedup over the
    # host-bank pallas backend, so measuring device implies the reference
    if "device" in backends and "pallas" not in backends:
        backends = backends + ("pallas",)
    rows: List[Row] = []
    for n in sizes:
        c = _fill_exact(n)
        hit_us = timeit(lambda: c.lookup(f"intent keyword number {n // 2}"),
                        repeats=5, number=100)
        miss_us = timeit(lambda: c.lookup("never inserted keyword"),
                         repeats=5, number=100)
        rows.append(Row(f"t5/exact/{n}", hit_us,
                        {"hit_us": round(hit_us, 1), "miss_us": round(miss_us, 1)}))

    # fuzzy: one shared bank of normalized embeddings per size
    measured: Dict[str, Dict[int, Tuple[float, float]]] = {
        b: {} for b in FUZZY_BACKENDS
    }
    for n in sizes:
        M = np.random.RandomState(0).randn(n, DIM).astype(np.float32)
        M /= np.linalg.norm(M, axis=1, keepdims=True)
        q_hit = (M[n // 2] + 0.01).astype(np.float32)
        q_hit /= np.linalg.norm(q_hit)
        q_miss = -M[0]
        for backend in backends:
            if _skip(backend, n):
                continue
            idx = _build_index(backend, M)

            def lookup(q):
                return idx.best_match(q, threshold=0.8)

            on_device = backend in ("pallas", "device")
            reps, num = (2, 1) if on_device else (3, max(3, 2000 // n))
            if on_device:
                lookup(q_hit)  # warm the jit cache outside the timed region
            h2d_before = (
                idx.telemetry()["device"]["h2d_bytes_total"]
                if backend == "device" else 0
            )
            hit_us = timeit(lambda: lookup(q_hit), repeats=reps, number=num)
            miss_us = timeit(lambda: lookup(q_miss), repeats=reps, number=num)
            derived = {"hit_us": round(hit_us, 1), "miss_us": round(miss_us, 1)}
            if backend == "device":
                # steady-state H2D measured from DeviceBank telemetry: the
                # bank is resident, only query batches crossed
                moved = idx.telemetry()["device"]["h2d_bytes_total"] - h2d_before
                derived["h2d_per_lookup"] = moved // (2 * reps * num)
                derived["bank_h2d_per_lookup"] = 0
            elif backend == "pallas":
                # the host arena is re-uploaded inside every batch_topk call
                arena_bytes = idx.bank.arena().nbytes
                derived["h2d_per_lookup"] = arena_bytes + 8 * DIM * 4
                derived["bank_h2d_per_lookup"] = arena_bytes
            else:
                derived["h2d_per_lookup"] = 0  # host-resident compute
            rows.append(Row(f"t5/fuzzy/{backend}/{n}", hit_us, derived))
            measured[backend][n] = (hit_us, miss_us)

    for name, fast_b, slow_b in (
        ("speedup_bucketed_vs_brute", "bucketed", "brute"),
        ("speedup_device_vs_pallas", "device", "pallas"),
    ):
        common = sorted(set(measured[fast_b]) & set(measured[slow_b]))
        if not common:
            continue
        n_at = common[-1]
        sh, sm = measured[slow_b][n_at]
        fh, fm = measured[fast_b][n_at]
        rows.append(Row(f"t5/fuzzy/{name}/{n_at}", 0.0,
                        {"hit_x": round(sh / max(fh, 1e-9), 1),
                         "miss_x": round(sm / max(fm, 1e-9), 1)}))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="Table 5 lookup-scalability sweep (exact + fuzzy "
        "backends, H2D bytes per lookup)"
    )
    ap.add_argument("--fast", action="store_true",
                    help="sizes up to 50k instead of 1M")
    ap.add_argument(
        "--backend", default="",
        help="comma list of fuzzy backends to measure "
        f"(default: all of {','.join(FUZZY_BACKENDS)}); 'device' always "
        "measures the pallas reference too for the speedup row",
    )
    args = ap.parse_args()
    backends = tuple(b for b in args.backend.split(",") if b) or None
    for b in backends or ():
        if b not in FUZZY_BACKENDS:
            raise SystemExit(f"unknown backend {b!r} (choose from "
                             f"{','.join(FUZZY_BACKENDS)})")
    print("name,us_per_call,derived")
    for row in run(fast=args.fast, backends=backends):
        print(row.csv())


if __name__ == "__main__":  # pragma: no cover - exercised by the CI docs job
    main()

"""Table 5: exact vs fuzzy cache-lookup latency vs cache size (µs).

Exact matching uses the dict-backed PlanCache (O(1)); fuzzy uses the
brute-force cosine scan (O(N*dim)) — reproducing the paper's scaling gap.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, timeit
from repro.core.cache import PlanCache
from repro.core import fuzzy


def _fill_exact(n: int) -> PlanCache:
    c = PlanCache(capacity=n + 1)
    for i in range(n):
        c.insert(f"intent keyword number {i}", i)
    return c


def run(fast: bool = False) -> List[Row]:
    sizes = [100, 1_000, 10_000] if fast else [100, 1_000, 10_000, 100_000, 1_000_000]
    rows: List[Row] = []
    for n in sizes:
        c = _fill_exact(n)
        hit_us = timeit(lambda: c.lookup(f"intent keyword number {n // 2}"),
                        repeats=5, number=100)
        miss_us = timeit(lambda: c.lookup("never inserted keyword"),
                         repeats=5, number=100)
        rows.append(Row(f"t5/exact/{n}", hit_us,
                        {"hit_us": round(hit_us, 1), "miss_us": round(miss_us, 1)}))
    # fuzzy: pre-built embedding matrix, cosine scan per lookup
    f_sizes = [s for s in sizes if s <= (10_000 if fast else 1_000_000)]
    for n in f_sizes:
        M = np.random.RandomState(0).randn(n, fuzzy.DIM).astype(np.float32)
        M /= np.linalg.norm(M, axis=1, keepdims=True)
        q_hit = M[n // 2] + 0.01
        q_miss = -M[0]

        def lookup(q):
            sims = M @ q
            i = int(np.argmax(sims))
            return i if sims[i] > 0.8 else None

        hit_us = timeit(lambda: lookup(q_hit), repeats=3,
                        number=max(1, 1000 // max(1, n // 1000)))
        miss_us = timeit(lambda: lookup(q_miss), repeats=3,
                         number=max(1, 1000 // max(1, n // 1000)))
        rows.append(Row(f"t5/fuzzy/{n}", hit_us,
                        {"hit_us": round(hit_us, 1), "miss_us": round(miss_us, 1)}))
    return rows

"""Table 5: exact vs fuzzy cache-lookup latency vs cache size (µs).

Exact matching uses the dict-backed PlanCache (O(1)). Fuzzy matching now
carries an **index-backend dimension** (``repro.index``):

* ``brute``     the paper prototype's O(N*dim) numpy cosine scan — this is
                the Table 5 scaling cliff, kept as the baseline;
* ``pallas``    ``ops.batch_topk`` blocked kernel. On this CPU container it
                runs in interpret mode (constant-factor slow; measured only
                up to 10k entries) — on TPU the identical call compiles to
                Mosaic and the N axis streams through the MXU;
* ``bucketed``  multi-probe SRP-LSH candidate generation: sublinear in N,
                falling back to the exact brute scan below its size
                threshold (so small sizes print identical latencies).

Rows: ``t5/exact/{n}``, ``t5/fuzzy/{backend}/{n}``, plus a derived
``t5/fuzzy/speedup_bucketed_vs_brute/{n_max}`` row whose ``hit_x``/
``miss_x`` record how many times faster the bucketed backend answers the
same lookups at the largest measured size.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, timeit
from repro.core.cache import PlanCache
from repro.index import DIM, SimilarityIndex

PALLAS_MAX_N = 10_000  # interpret-mode CPU cap; on TPU there is no cap


def _fill_exact(n: int) -> PlanCache:
    c = PlanCache(capacity=n + 1)
    for i in range(n):
        c.insert(f"intent keyword number {i}", i)
    return c


def _build_index(backend: str, M: np.ndarray) -> SimilarityIndex:
    idx = SimilarityIndex(backend=backend, initial_capacity=M.shape[0])
    for i in range(M.shape[0]):
        idx.add(f"k{i}", M[i])
    return idx


def run(fast: bool = False) -> List[Row]:
    # fast still reaches 50k: the brute-vs-bucketed gap is the point of this
    # table, and it only becomes unambiguous past ~10k entries
    sizes = ([100, 1_000, 10_000, 50_000] if fast
             else [100, 1_000, 10_000, 100_000, 1_000_000])
    rows: List[Row] = []
    for n in sizes:
        c = _fill_exact(n)
        hit_us = timeit(lambda: c.lookup(f"intent keyword number {n // 2}"),
                        repeats=5, number=100)
        miss_us = timeit(lambda: c.lookup("never inserted keyword"),
                         repeats=5, number=100)
        rows.append(Row(f"t5/exact/{n}", hit_us,
                        {"hit_us": round(hit_us, 1), "miss_us": round(miss_us, 1)}))

    # fuzzy: one shared bank of normalized embeddings per size, three backends
    brute_at, bucketed_at = {}, {}
    for n in sizes:
        M = np.random.RandomState(0).randn(n, DIM).astype(np.float32)
        M /= np.linalg.norm(M, axis=1, keepdims=True)
        q_hit = (M[n // 2] + 0.01).astype(np.float32)
        q_hit /= np.linalg.norm(q_hit)
        q_miss = -M[0]
        for backend in ("brute", "pallas", "bucketed"):
            if backend == "pallas" and n > PALLAS_MAX_N:
                continue
            idx = _build_index(backend, M)

            def lookup(q):
                return idx.best_match(q, threshold=0.8)

            reps, num = (2, 1) if backend == "pallas" else (3, max(3, 2000 // n))
            if backend == "pallas":
                lookup(q_hit)  # warm the jit cache outside the timed region
            hit_us = timeit(lambda: lookup(q_hit), repeats=reps, number=num)
            miss_us = timeit(lambda: lookup(q_miss), repeats=reps, number=num)
            rows.append(Row(f"t5/fuzzy/{backend}/{n}", hit_us,
                            {"hit_us": round(hit_us, 1),
                             "miss_us": round(miss_us, 1)}))
            if backend == "brute":
                brute_at[n] = (hit_us, miss_us)
            elif backend == "bucketed":
                bucketed_at[n] = (hit_us, miss_us)

    n_max = sizes[-1]
    bh, bm = brute_at[n_max]
    ch, cm = bucketed_at[n_max]
    rows.append(Row(f"t5/fuzzy/speedup_bucketed_vs_brute/{n_max}", 0.0,
                    {"hit_x": round(bh / max(ch, 1e-9), 1),
                     "miss_x": round(bm / max(cm, 1e-9), 1)}))
    return rows

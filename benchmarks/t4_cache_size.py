"""Table 4: effect of cache size (LRU eviction), plus the eviction-policy
face-off the ``repro.memory`` policies exist for.

``t4/financebench/cache_size_*`` reproduces the paper's table. The
``t4/eviction_skew/*`` rows run a skewed-reuse stream — a small hot set of
keywords re-accessed every round while a long tail of one-shot keywords
floods the cache — through each eviction policy at a capacity smaller than
one round's working set. Plain LRU churns the hot set out on every tail
flood; the cost-aware policy (paper §4.4: score = tokens-saved x reuse)
keeps the reused templates resident, which shows up directly as hit rate.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.agent_loop import AgentConfig
from repro.core.cache import PlanCache
from repro.core.harness import run_workload

HOT_KEYS = 20
TAIL_PER_ROUND = 30
SKEW_CAPACITY = 24  # < hot set + one round's tail: eviction pressure


class _Tpl:
    """Stand-in template: carries the uses/size_tokens surface the
    cost-aware policy scores (hot templates are larger = save more)."""

    def __init__(self, tokens: int):
        self.uses = 0
        self._tokens = tokens

    def size_tokens(self) -> int:
        return self._tokens


def _skewed_stream(cache: PlanCache, rounds: int) -> None:
    """Each round: the hot set is served twice (lookup, then a re-use
    touch), then the tail floods with one-shot keywords."""
    tail_i = 0
    for _ in range(rounds):
        for h in range(HOT_KEYS):
            kw = f"hot-keyword-{h}"
            if cache.lookup(kw) is None:
                cache.insert(kw, _Tpl(tokens=300))
            cache.lookup(kw)  # the reuse that makes the entry worth keeping
        for _ in range(TAIL_PER_ROUND):
            kw = f"tail-keyword-{tail_i}"
            tail_i += 1
            if cache.lookup(kw) is None:
                cache.insert(kw, _Tpl(tokens=40))


def eviction_skew_rows(fast: bool = False) -> List[Row]:
    rounds = 12 if fast else 40
    hit_rates = {}
    rows = []
    for policy in ("lru", "lfu", "cost"):
        c: PlanCache = PlanCache(capacity=SKEW_CAPACITY, eviction=policy)
        _skewed_stream(c, rounds)
        hit_rates[policy] = c.stats.hit_rate
        rows.append(
            Row(
                f"t4/eviction_skew/{policy}",
                0.0,
                {
                    "hit_rate": round(c.stats.hit_rate, 3),
                    "evictions": c.stats.evictions,
                    "capacity": SKEW_CAPACITY,
                },
            )
        )
    rows.append(
        Row(
            "t4/eviction_skew/cost_vs_lru",
            0.0,
            {
                "hit_rate_delta": round(hit_rates["cost"] - hit_rates["lru"], 3),
                "cost_beats_lru": hit_rates["cost"] > hit_rates["lru"],
            },
        )
    )
    return rows


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    sizes = [1, 10, 100] if fast else [1, 10, 20, 50, 100]
    rows = []
    for cap in sizes:
        r = run_workload(
            "financebench", "apc", n, agent_cfg=AgentConfig(cache_capacity=cap)
        )
        rows.append(
            Row(
                f"t4/financebench/cache_size_{cap}",
                0.0,
                {
                    "hit_rate": round(r.hit_rate, 3),
                    "cost_usd": round(r.cost, 4),
                    "accuracy": round(r.accuracy, 4),
                    "latency_s": round(r.latency_s, 1),
                    "cache_entries": r.cache_entries,
                },
            )
        )
    rows += eviction_skew_rows(fast)
    return rows

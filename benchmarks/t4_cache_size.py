"""Table 4: effect of cache size (LRU eviction)."""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.agent_loop import AgentConfig
from repro.core.harness import run_workload


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    sizes = [1, 10, 100] if fast else [1, 10, 20, 50, 100]
    rows = []
    for cap in sizes:
        r = run_workload(
            "financebench", "apc", n, agent_cfg=AgentConfig(cache_capacity=cap)
        )
        rows.append(
            Row(
                f"t4/financebench/cache_size_{cap}",
                0.0,
                {
                    "hit_rate": round(r.hit_rate, 3),
                    "cost_usd": round(r.cost, 4),
                    "accuracy": round(r.accuracy, 4),
                    "latency_s": round(r.latency_s, 1),
                    "cache_entries": r.cache_entries,
                },
            )
        )
    return rows

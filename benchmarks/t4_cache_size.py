"""Table 4: effect of cache size (LRU eviction), plus the eviction-policy
face-off the ``repro.memory`` policies exist for.

``t4/financebench/cache_size_*`` reproduces the paper's table. The
``t4/eviction_skew/*`` rows run a skewed-reuse stream — a small hot set of
keywords re-accessed every round while a long tail of one-shot keywords
floods the cache — through each eviction policy at a capacity smaller than
one round's working set. Plain LRU churns the hot set out on every tail
flood; the cost-aware policy (paper §4.4: score = tokens-saved x reuse)
keeps the reused templates resident, which shows up directly as hit rate.
"""

from __future__ import annotations

import shutil
import tempfile
from typing import List, Optional

from benchmarks.common import Row
from repro.core.agent_loop import AgentConfig
from repro.core.cache import PlanCache
from repro.core.harness import run_workload
from repro.core.template import PlanStep, PlanTemplate

HOT_KEYS = 20
TAIL_PER_ROUND = 30
SKEW_CAPACITY = 24  # < hot set + one round's tail: eviction pressure


class _Tpl:
    """Stand-in template: carries the uses/size_tokens surface the
    cost-aware policy scores (hot templates are larger = save more)."""

    def __init__(self, tokens: int):
        self.uses = 0
        self._tokens = tokens

    def size_tokens(self) -> int:
        return self._tokens


def _skewed_stream(cache: PlanCache, rounds: int) -> None:
    """Each round: the hot set is served twice (lookup, then a re-use
    touch), then the tail floods with one-shot keywords."""
    tail_i = 0
    for _ in range(rounds):
        for h in range(HOT_KEYS):
            kw = f"hot-keyword-{h}"
            if cache.lookup(kw) is None:
                cache.insert(kw, _Tpl(tokens=300))
            cache.lookup(kw)  # the reuse that makes the entry worth keeping
        for _ in range(TAIL_PER_ROUND):
            kw = f"tail-keyword-{tail_i}"
            tail_i += 1
            if cache.lookup(kw) is None:
                cache.insert(kw, _Tpl(tokens=40))


def eviction_skew_rows(fast: bool = False) -> List[Row]:
    rounds = 12 if fast else 40
    hit_rates = {}
    rows = []
    for policy in ("lru", "lfu", "cost"):
        c: PlanCache = PlanCache(capacity=SKEW_CAPACITY, eviction=policy)
        _skewed_stream(c, rounds)
        hit_rates[policy] = c.stats.hit_rate
        rows.append(
            Row(
                f"t4/eviction_skew/{policy}",
                0.0,
                {
                    "hit_rate": round(c.stats.hit_rate, 3),
                    "evictions": c.stats.evictions,
                    "capacity": SKEW_CAPACITY,
                },
            )
        )
    rows.append(
        Row(
            "t4/eviction_skew/cost_vs_lru",
            0.0,
            {
                "hit_rate_delta": round(hit_rates["cost"] - hit_rates["lru"], 3),
                "cost_beats_lru": hit_rates["cost"] > hit_rates["lru"],
            },
        )
    )
    return rows


def _template(kw: str, body_chars: int) -> PlanTemplate:
    """A real (JSON-serializable) template so victims survive a cold spill."""
    return PlanTemplate(
        kw,
        [
            PlanStep("message", "u" * (body_chars // 2), {"tool": "search"}),
            PlanStep("output", "o" * body_chars),
            PlanStep("answer", "done"),
        ],
        source_task=kw,
    )


def _skewed_template_stream(cache: PlanCache, rounds: int) -> None:
    """The eviction_skew stream with real templates: hot entries that a cold
    tier can bring back after a tail flood churns them out of RAM."""
    tail_i = 0
    for _ in range(rounds):
        for h in range(HOT_KEYS):
            kw = f"hot-keyword-{h}"
            if cache.lookup(kw) is None:
                cache.insert(kw, _template(kw, body_chars=600))
            cache.lookup(kw)
        for _ in range(TAIL_PER_ROUND):
            kw = f"tail-keyword-{tail_i}"
            tail_i += 1
            if cache.lookup(kw) is None:
                cache.insert(kw, _template(kw, body_chars=80))


def cold_tier_rows(fast: bool = False) -> List[Row]:
    """``t4/cold_tier/*``: the same skewed stream with and without the
    persistent cold tier under the hot store. LRU churns the hot set out on
    every tail flood; with a cold tier those victims spill to disk and come
    back as promotes instead of misses, so the hit-rate delta is the direct
    win of keeping a persistent tier."""
    rounds = 12 if fast else 40
    rows = []
    hit_rates = {}
    for label, cold in (("hot_only", False), ("with_cold", True)):
        cold_dir: Optional[str] = (
            tempfile.mkdtemp(prefix="bench-cold-") if cold else None
        )
        try:
            c = PlanCache(capacity=SKEW_CAPACITY, eviction="lru",
                          cold_dir=cold_dir, cold_budget_tokens=10**6)
            _skewed_template_stream(c, rounds)
            hit_rates[label] = c.stats.hit_rate
            extra = {"hit_rate": round(c.stats.hit_rate, 3),
                     "evictions": c.stats.evictions,
                     "capacity": SKEW_CAPACITY}
            if cold:
                extra.update(c.stats.cold_snapshot())
            rows.append(Row(f"t4/cold_tier/{label}", 0.0, extra))
        finally:
            if cold_dir is not None:
                shutil.rmtree(cold_dir, ignore_errors=True)
    rows.append(
        Row(
            "t4/cold_tier/cold_vs_hot_only",
            0.0,
            {
                "hit_rate_delta": round(
                    hit_rates["with_cold"] - hit_rates["hot_only"], 3
                ),
                "cold_beats_hot_only":
                    hit_rates["with_cold"] > hit_rates["hot_only"],
            },
        )
    )
    return rows


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    sizes = [1, 10, 100] if fast else [1, 10, 20, 50, 100]
    rows = []
    for cap in sizes:
        r = run_workload(
            "financebench", "apc", n, agent_cfg=AgentConfig(cache_capacity=cap)
        )
        rows.append(
            Row(
                f"t4/financebench/cache_size_{cap}",
                0.0,
                {
                    "hit_rate": round(r.hit_rate, 3),
                    "cost_usd": round(r.cost, 4),
                    "accuracy": round(r.accuracy, 4),
                    "latency_s": round(r.latency_s, 1),
                    "cache_entries": r.cache_entries,
                },
            )
        )
    rows += eviction_skew_rows(fast)
    rows += cold_tier_rows(fast)
    return rows

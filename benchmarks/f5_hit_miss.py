"""Figure 5: cache-hit vs cache-miss accuracy per caching method."""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.harness import run_workload


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    rows = []
    envs = ["financebench"] if fast else ["financebench", "tabmwp"]
    for env in envs:
        for method in ("semantic", "full_history", "apc"):
            r = run_workload(env, method, n)
            rows.append(
                Row(
                    f"f5/{env}/{method}",
                    0.0,
                    {
                        "hit_accuracy": None if r.hit_accuracy is None
                        else round(r.hit_accuracy, 4),
                        "miss_accuracy": None if r.miss_accuracy is None
                        else round(r.miss_accuracy, 4),
                        "hit_rate": round(r.hit_rate, 3),
                    },
                )
            )
    return rows

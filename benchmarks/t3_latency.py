"""Table 3: wall-clock latency breakdown by pipeline component
(+ the beyond-paper async-cachegen variant the paper lists as future work)."""

from __future__ import annotations

from typing import List

from benchmarks.common import Row, latency_summary
from repro.core.agent_loop import AgentConfig
from repro.core.harness import run_workload


def _components(res) -> dict:
    plan = sum(
        res.breakdown.get(r, {}).get("latency_s", 0.0)
        for r in ("large_planner", "small_planner")
    )
    act = res.breakdown.get("actor", {}).get("latency_s", 0.0)
    kw = res.breakdown.get("keyword_extractor", {}).get("latency_s", 0.0)
    gen = res.breakdown.get("cache_generator", {}).get("latency_s", 0.0)
    lookup = sum(r.cache_lookup_s for r in res.records)
    return {
        "plan_s": round(plan, 1),
        "act_s": round(act, 1),
        "keyword_s": round(kw, 1),
        "lookup_s": round(lookup, 4),
        "cachegen_s": round(gen, 1),
        "total_s": round(res.latency_s, 1),
        # per-request tails, not just sums: same histogram math as the
        # runtime router.lookup_latency export
        "request_latency": latency_summary(
            (r.latency_s for r in res.records), unit="s", digits=2),
        "lookup_latency": latency_summary(
            (r.cache_lookup_s for r in res.records), unit="us", digits=1),
    }


def run(fast: bool = False) -> List[Row]:
    n = 50 if fast else 100
    env = "financebench"
    rows = []
    for method in ("accuracy_optimal", "cost_optimal", "apc"):
        r = run_workload(env, method, n, keep_records=True)
        rows.append(Row(f"t3/{env}/{method}", 0.0, _components(r)))
    # beyond-paper: async cache generation off the critical path
    r = run_workload(
        env, "apc", n, keep_records=True,
        agent_cfg=AgentConfig(async_cachegen=True),
    )
    d = _components(r)
    d["note"] = "async cachegen (paper future work): gen off critical path"
    d["total_s"] = round(r.latency_s, 1)
    rows.append(Row(f"t3/{env}/apc_async_cachegen", 0.0, d))
    return rows

"""Sim throughput: how many simulated ops/second the deterministic
harness sustains per scenario and fault plan.

This row keeps the verification loop itself honest: the sim is only
useful as a pre-merge gate if a seed matrix stays cheap, so a regression
in ops/sec (e.g. an accidentally quadratic oracle) shows up in the same
benchmark artifact stream as the serving-path rows.
"""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row
from repro.sim import SimConfig, run_sim


def run(fast: bool = False) -> List[Row]:
    n_ops = 30 if fast else 80
    rows: List[Row] = []
    cells = [
        ("skewed_reuse", "none"),
        ("skewed_reuse", "crash_restart"),
        ("evict_then_hit", "mid_wave_evict"),
        ("skewed_reuse", "hedge_timeout"),
    ]
    for scenario, fault in cells:
        cfg = SimConfig(seed=0, scenario=scenario, fault=fault, n_ops=n_ops)
        t0 = time.perf_counter()
        report = run_sim(cfg)
        wall = time.perf_counter() - t0
        assert report.ok, report.violations[:3]
        rows.append(
            Row(
                f"s1/{scenario}/{fault}",
                wall * 1e6 / max(1, report.ops_applied),
                {
                    "ops": report.ops_applied,
                    "steps": report.steps,
                    "lookups": report.lookups,
                    "ops_per_s": round(report.ops_applied / max(wall, 1e-9), 1),
                    "trace_hash": report.trace_hash[:12],
                },
            )
        )
    return rows

"""Sim throughput: how many simulated ops/second the deterministic
harness sustains per scenario and fault plan.

This row keeps the verification loop itself honest: the sim is only
useful as a pre-merge gate if a seed matrix stays cheap, so a regression
in ops/sec (e.g. an accidentally quadratic oracle) shows up in the same
benchmark artifact stream as the serving-path rows. The
``membership_churn`` and ``async_cachegen`` rows additionally carry
``interceptor_calls`` — the per-shard RPCs the run charged, now including
the control-plane ops (``keys``/``len``/``autotune``/membership scans) —
so control-plane overhead is tracked per commit via
``benchmarks/run.py --json-dir`` (``BENCH_s1.json``).
"""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row
from repro.sim import SimConfig, run_sim


def run(fast: bool = False) -> List[Row]:
    n_ops = 30 if fast else 80
    rows: List[Row] = []
    cells = [
        ("skewed_reuse", "none"),
        ("skewed_reuse", "crash_restart"),
        ("evict_then_hit", "mid_wave_evict"),
        ("skewed_reuse", "hedge_timeout"),
        # control plane under elastic churn: joins/drains/rebalances all
        # pay the interceptor seam, as do the keys/len scans in the mix
        ("skewed_reuse", "membership_churn"),
        ("paraphrase_burst", "membership_churn"),
        # async cache-generation: worker clients add scheduler steps and
        # the admission race costs extra model mirroring per wave
        ("skewed_reuse", "async_cachegen"),
    ]
    for scenario, fault in cells:
        cfg = SimConfig(seed=0, scenario=scenario, fault=fault, n_ops=n_ops)
        t0 = time.perf_counter()
        report = run_sim(cfg)
        wall = time.perf_counter() - t0
        assert report.ok, report.violations[:3]
        derived = {
            "ops": report.ops_applied,
            "steps": report.steps,
            "lookups": report.lookups,
            "ops_per_s": round(report.ops_applied / max(wall, 1e-9), 1),
            "interceptor_calls": report.interceptor["calls"],
            "trace_hash": report.trace_hash[:12],
            # the traced serving path: span census + stream digest prove
            # tracing stays on (and deterministic) inside the sim
            "spans": report.n_spans,
            "span_digest": report.span_digest[:12],
        }
        if report.cachegen is not None:
            derived["cachegen_submitted"] = report.cachegen["submitted"]
        if report.router_metrics is not None:
            lat = report.router_metrics.get("lookup_latency") or {}
            if lat.get("count"):
                derived["lookup_latency"] = {
                    "count": lat["count"],
                    "p50_us": round((lat["p50"] or 0.0) * 1e6, 1),
                    "p99_us": round((lat["p99"] or 0.0) * 1e6, 1),
                }
        rows.append(
            Row(
                f"s1/{scenario}/{fault}",
                wall * 1e6 / max(1, report.ops_applied),
                derived,
            )
        )
    return rows

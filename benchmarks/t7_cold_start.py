"""Table 7: cold-start warm-up time series (per-quintile hit rate/cost)."""

from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.harness import run_workload


def run(fast: bool = False) -> List[Row]:
    n = 100 if fast else 200
    r = run_workload("financebench", "apc", n, keep_records=True)
    rows = []
    recs = r.records
    for q in (20, 40, 60, 80, 100):
        upto = recs[: max(1, n * q // 100)]
        hit = sum(x.hit for x in upto) / len(upto)
        cost = sum(x.cost for x in upto)
        lat = sum(x.latency_s for x in upto)
        entries = len({x.keyword for x in upto if x.keyword})
        rows.append(
            Row(
                f"t7/financebench/p{q}",
                0.0,
                {
                    "hit_rate": round(hit, 4),
                    "cost_usd": round(cost, 4),
                    "latency_s": round(lat, 1),
                    "distinct_keywords": entries,
                },
            )
        )
    return rows

"""Figure 3: query-similarity vs keyword-based cache search — FPR/FNR.

Ground truth: two tasks share a reusable plan iff they share an intent.
Query-based search: cosine similarity of full query embeddings > threshold.
Keyword-based: extracted-keyword exact match.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import fuzzy
from repro.core.backends import SimulatedBackend
from repro.envs.workloads import get_env


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    env = get_env("financebench")
    tasks = env.generate(n, seed=0)
    be = SimulatedBackend(seed=0)
    embs = np.stack([fuzzy.embed(t.query) for t in tasks])
    kws = [be.extract_keyword(t)[0] for t in tasks]
    intents = [t.intent.id for t in tasks]

    rows: List[Row] = []
    # pairwise: for each ordered pair (i cached, j query), predict hit
    sims = embs @ embs.T
    same = np.asarray(
        [[intents[i] == intents[j] for i in range(n)] for j in range(n)]
    )
    mask = ~np.eye(n, dtype=bool)
    for thr in (0.7, 0.8, 0.85, 0.9, 0.95):
        pred = sims > thr
        fp = (pred & ~same & mask).sum() / max(1, (~same & mask).sum())
        fn = (~pred & same & mask).sum() / max(1, (same & mask).sum())
        rows.append(
            Row(f"f3/query_sim_thr_{thr}", 0.0,
                {"fpr": round(float(fp), 4), "fnr": round(float(fn), 4)})
        )
    kw_pred = np.asarray([[kws[i] == kws[j] for i in range(n)] for j in range(n)])
    fp = (kw_pred & ~same & mask).sum() / max(1, (~same & mask).sum())
    fn = (~kw_pred & same & mask).sum() / max(1, (same & mask).sum())
    rows.append(
        Row("f3/keyword_exact", 0.0,
            {"fpr": round(float(fp), 4), "fnr": round(float(fn), 4)})
    )
    return rows

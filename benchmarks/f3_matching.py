"""Figure 3: query-similarity vs keyword-based cache search — FPR/FNR.

Ground truth: two tasks share a reusable plan iff they share an intent.
Query-based search: cosine similarity of full query embeddings > threshold.
Keyword-based: extracted-keyword exact match.

Index-backend dimension (``repro.index``): embeddings come from the
vectorized ``embed_batch`` (one scatter-add for the whole task set), and
``f3/index_top2_agreement/{pallas,bucketed}`` measures how often each
accelerated backend returns the same nearest *other* query (top-2, row 0 is
the query itself) as the exact numpy reference — pallas must agree exactly;
bucketed agreement is its measured LSH recall at this scale.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import fuzzy
from repro.core.backends import SimulatedBackend
from repro.envs.workloads import get_env


def run(fast: bool = False) -> List[Row]:
    n = 80 if fast else 200
    env = get_env("financebench")
    tasks = env.generate(n, seed=0)
    be = SimulatedBackend(seed=0)
    embs = fuzzy.embed_batch([t.query for t in tasks])
    kws = [be.extract_keyword(t)[0] for t in tasks]
    intents = [t.intent.id for t in tasks]

    rows: List[Row] = []
    # pairwise: for each ordered pair (i cached, j query), predict hit
    sims = embs @ embs.T
    same = np.asarray(
        [[intents[i] == intents[j] for i in range(n)] for j in range(n)]
    )
    mask = ~np.eye(n, dtype=bool)
    for thr in (0.7, 0.8, 0.85, 0.9, 0.95):
        pred = sims > thr
        fp = (pred & ~same & mask).sum() / max(1, (~same & mask).sum())
        fn = (~pred & same & mask).sum() / max(1, (same & mask).sum())
        rows.append(
            Row(f"f3/query_sim_thr_{thr}", 0.0,
                {"fpr": round(float(fp), 4), "fnr": round(float(fn), 4)})
        )
    kw_pred = np.asarray([[kws[i] == kws[j] for i in range(n)] for j in range(n)])
    fp = (kw_pred & ~same & mask).sum() / max(1, (~same & mask).sum())
    fn = (~kw_pred & same & mask).sum() / max(1, (same & mask).sum())
    rows.append(
        Row("f3/keyword_exact", 0.0,
            {"fpr": round(float(fp), 4), "fnr": round(float(fn), 4)})
    )

    # index-backend agreement on the nearest *other* query (top-2, col 1)
    from repro.index.bucketed import BucketedIndex
    from repro.index import EmbeddingBank
    from repro.kernels import ops, ref

    _, ref_i = ref.topk_cosine_ref(embs, embs, 2)
    _, pl_i = ops.batch_topk(embs, embs, k=2)
    pl_agree = float(np.mean(np.asarray(pl_i)[:, 1] == ref_i[:, 1]))
    rows.append(Row("f3/index_top2_agreement/pallas", 0.0,
                    {"agreement": round(pl_agree, 4)}))

    bank = EmbeddingBank(initial_capacity=n)
    for i in range(n):
        bank.add(f"q{i}", embs[i])
    # scan_threshold=0 forces the LSH probe path even at this small n,
    # so the row reports real multi-probe recall, not the exact fallback
    bidx = BucketedIndex(bank, n_bits=8, scan_threshold=0)
    _, bk_i = bidx.topk(embs, k=2)
    bk_agree = float(np.mean(bk_i[:, 1] == ref_i[:, 1]))
    rows.append(Row("f3/index_top2_agreement/bucketed", 0.0,
                    {"agreement": round(bk_agree, 4)}))
    return rows

"""Kernel microbenchmarks (interpret mode on CPU: relative scaling only;
absolute TPU numbers come from the roofline analysis)."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.kernels import ops


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    k = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, hd = (1, 4, 2, 256, 64) if fast else (2, 8, 2, 512, 64)
    q = jax.random.normal(k, (B, S, Hq, hd), jnp.float32)
    kk = jax.random.normal(k, (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(k, (B, S, Hkv, hd), jnp.float32)
    f = lambda: ops.flash_attention_op(q, kk, v, block_q=128, block_k=128
                                       ).block_until_ready()
    f()
    rows.append(Row("kernel/flash_attention", timeit(f), {"S": S, "Hq": Hq}))

    M = 512 if fast else 2048
    q1 = jax.random.normal(k, (B, 1, Hq, hd), jnp.float32)
    ck = jax.random.normal(k, (B, M, Hkv, hd), jnp.float32)
    cv = jax.random.normal(k, (B, M, Hkv, hd), jnp.float32)
    ln = jnp.asarray(M - 3, jnp.int32)
    g = lambda: ops.decode_attention_op(q1, ck, cv, ln).block_until_ready()
    g()
    rows.append(Row("kernel/decode_attention", timeit(g), {"M": M}))

    H, N, S2 = 2, 64, 128 if fast else 256
    r = jax.random.normal(k, (B, S2, H, N)) * 0.3
    w = -jnp.exp(jax.random.normal(k, (B, S2, H, N)) * 0.3 - 2)
    u = jax.random.normal(k, (H, N)) * 0.3
    h = lambda: ops.wkv6_op(r, r, r, w, u, chunk=64)[0].block_until_ready()
    h()
    rows.append(Row("kernel/wkv6", timeit(h), {"S": S2, "N": N}))

    P, Ns = 64, 64
    x = jax.random.normal(k, (B, S2, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(k, (B, S2, H)))
    Bc = jax.random.normal(k, (B, S2, Ns)) * 0.3
    s = lambda: ops.ssd_op(x, dt, jnp.zeros((H,)), Bc, Bc, jnp.ones((H,)),
                           chunk=64)[0].block_until_ready()
    s()
    rows.append(Row("kernel/ssd", timeit(s), {"S": S2, "P": P}))

    # similarity top-k: a whole admission batch of fuzzy lookups per call
    Qb, Nb = (16, 2_000) if fast else (64, 20_000)
    qs = jax.random.normal(k, (Qb, 384), jnp.float32)
    qs = qs / jnp.linalg.norm(qs, axis=1, keepdims=True)
    bank = jax.random.normal(k, (Nb, 384), jnp.float32)
    bank = bank / jnp.linalg.norm(bank, axis=1, keepdims=True)
    t = lambda: ops.batch_topk(qs, bank, k=4)[0].block_until_ready()
    t()
    rows.append(Row("kernel/batch_topk", timeit(t), {"Q": Qb, "N": Nb}))
    return rows

"""Kernel microbenchmarks (interpret mode on CPU: relative scaling only;
absolute TPU numbers come from the roofline analysis)."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.kernels import ops


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    k = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, hd = (1, 4, 2, 256, 64) if fast else (2, 8, 2, 512, 64)
    q = jax.random.normal(k, (B, S, Hq, hd), jnp.float32)
    kk = jax.random.normal(k, (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(k, (B, S, Hkv, hd), jnp.float32)
    f = lambda: ops.flash_attention_op(q, kk, v, block_q=128, block_k=128
                                       ).block_until_ready()
    f()
    rows.append(Row("kernel/flash_attention", timeit(f), {"S": S, "Hq": Hq}))

    M = 512 if fast else 2048
    q1 = jax.random.normal(k, (B, 1, Hq, hd), jnp.float32)
    ck = jax.random.normal(k, (B, M, Hkv, hd), jnp.float32)
    cv = jax.random.normal(k, (B, M, Hkv, hd), jnp.float32)
    ln = jnp.asarray(M - 3, jnp.int32)
    g = lambda: ops.decode_attention_op(q1, ck, cv, ln).block_until_ready()
    g()
    rows.append(Row("kernel/decode_attention", timeit(g), {"M": M}))

    H, N, S2 = 2, 64, 128 if fast else 256
    r = jax.random.normal(k, (B, S2, H, N)) * 0.3
    w = -jnp.exp(jax.random.normal(k, (B, S2, H, N)) * 0.3 - 2)
    u = jax.random.normal(k, (H, N)) * 0.3
    h = lambda: ops.wkv6_op(r, r, r, w, u, chunk=64)[0].block_until_ready()
    h()
    rows.append(Row("kernel/wkv6", timeit(h), {"S": S2, "N": N}))

    P, Ns = 64, 64
    x = jax.random.normal(k, (B, S2, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(k, (B, S2, H)))
    Bc = jax.random.normal(k, (B, S2, Ns)) * 0.3
    s = lambda: ops.ssd_op(x, dt, jnp.zeros((H,)), Bc, Bc, jnp.ones((H,)),
                           chunk=64)[0].block_until_ready()
    s()
    rows.append(Row("kernel/ssd", timeit(s), {"S": S2, "P": P}))

    # similarity top-k: a whole admission batch of fuzzy lookups per call.
    # Two bank residencies, each with its H2D bytes-moved-per-call column:
    #  - host bank (batch_topk): the (N, 384) arena crosses to the device
    #    on every call — N*384*4 bytes + the query batch;
    #  - device bank (resident_topk over a DeviceBank arena): the bank was
    #    uploaded once at admission; steady state moves the queries only.
    import numpy as np

    from repro.index.device import DeviceBank

    Qb, Nb = (16, 2_000) if fast else (64, 20_000)
    rng = np.random.RandomState(0)
    qs_np = rng.randn(Qb, 384).astype(np.float32)
    qs_np /= np.linalg.norm(qs_np, axis=1, keepdims=True)
    bank_np = rng.randn(Nb, 384).astype(np.float32)
    bank_np /= np.linalg.norm(bank_np, axis=1, keepdims=True)

    t = lambda: ops.batch_topk(qs_np, bank_np, k=4)[0].block_until_ready()
    t()
    rows.append(Row("kernel/batch_topk", timeit(t),
                    {"Q": Qb, "N": Nb, "bank": "host",
                     "h2d_bytes_per_call": bank_np.nbytes + qs_np.nbytes}))

    dbank = DeviceBank(Nb)
    dbank.set_rows(list(range(Nb)), bank_np)  # one-time admission upload
    td = lambda: ops.resident_topk(qs_np, dbank.arena, k=4)[0].block_until_ready()
    td()
    rows.append(Row("kernel/batch_topk_resident", timeit(td),
                    {"Q": Qb, "N": Nb, "bank": "device",
                     "h2d_bytes_per_call": qs_np.nbytes,
                     "bank_h2d_bytes_per_call": 0}))
    return rows

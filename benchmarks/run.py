"""Benchmark runner: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only t1,t5] \
        [--json-dir DIR]

Prints ``name,us_per_call,derived`` CSV (one row per measured cell).
``--json-dir`` additionally writes one machine-readable ``BENCH_<key>.json``
per module ({"module", "fast", "rows": [{name, us_per_call, derived}]}) —
the CI smoke workflow uploads these as artifacts so the perf trajectory
(t1 headline aggregate, t5 lookup scaling) is tracked per commit.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
import traceback

from benchmarks import (
    f3_matching,
    f5_hit_miss,
    kernel_bench,
    s1_sim,
    t1_main,
    t2_cost_breakdown,
    t3_latency,
    t4_cache_size,
    t5_lookup_scalability,
    t6_fuzzy_threshold,
    t7_cold_start,
    t8_kv_prefix,
    t9_sensitivity,
    t10_speculative,
)

MODULES = {
    "t1": t1_main,
    "t2": t2_cost_breakdown,
    "t3": t3_latency,
    "t4": t4_cache_size,
    "t5": t5_lookup_scalability,
    "t6": t6_fuzzy_threshold,
    "t7": t7_cold_start,
    "t8": t8_kv_prefix,
    "t10": t10_speculative,
    "f3": f3_matching,
    "f5": f5_hit_miss,
    "t9": t9_sensitivity,
    "kernels": kernel_bench,
    "s1": s1_sim,
}


def _write_json(json_dir: str, key: str, payload: dict) -> None:
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{key}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument(
        "--json-dir", default="",
        help="also write one BENCH_<module>.json per module into this dir",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for key, mod in MODULES.items():
        if key not in only:
            continue
        t0 = time.time()
        try:
            rows = list(mod.run(fast=args.fast))
        except Exception:
            failures += 1
            print(f"{key},0,{{\"error\": true}}")
            traceback.print_exc(file=sys.stderr)
            if args.json_dir:
                _write_json(args.json_dir, key,
                            {"module": key, "fast": args.fast, "error": True})
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
            continue
        for row in rows:
            print(row.csv())
        if args.json_dir:
            _write_json(
                args.json_dir, key,
                {
                    "module": key,
                    "fast": args.fast,
                    "rows": [dataclasses.asdict(r) for r in rows],
                },
            )
        print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Table 1 / Figure 4: cost + accuracy across 5 workloads x 5 methods."""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row
from repro.core.harness import run_workload
from repro.core.methods import method_names
from repro.envs.workloads import ALL_ENVS


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    n = 60 if fast else 200
    envs = ["financebench", "tabmwp"] if fast else ALL_ENVS
    for env in envs:
        # live registry enumeration: a method registered after import
        # (an out-of-tree scenario baseline) is still benchmarked
        for method in method_names():
            t0 = time.perf_counter()
            r = run_workload(env, method, n)
            wall = (time.perf_counter() - t0) * 1e6 / n
            rows.append(
                Row(
                    f"t1/{env}/{method}",
                    wall,
                    {
                        "accuracy": round(r.accuracy, 4),
                        "cost_usd": round(r.cost, 4),
                        "hit_rate": round(r.hit_rate, 3),
                        "latency_s": round(r.latency_s, 1),
                    },
                )
            )
    # paper Table 1 "Open Deep Research" column: GAIA with the second agent
    # architecture (paper: $69.02 -> $16.27, accuracy 37.58% -> 36.97%)
    from repro.core.deep_research import run_deep_research

    n_dr = 60 if fast else 165
    for label, use_apc in (("no_cache", False), ("apc", True)):
        r = run_deep_research("gaia", n_dr, use_apc=use_apc)
        rows.append(
            Row(
                f"t1/gaia_open_deep_research/{label}",
                0.0,
                {
                    "accuracy": round(r["accuracy"], 4),
                    "cost_usd": round(r["cost"], 4),
                    "hit_rate": round(r["hit_rate"], 3),
                },
            )
        )

    # headline aggregates (paper abstract): cost & latency reduction, accuracy kept
    agg_envs = envs
    red_c, red_l, kept = [], [], []
    by = {(r.name.split("/")[1], r.name.split("/")[2]): r.derived for r in rows}
    for env in agg_envs:
        ao, apc = by[(env, "accuracy_optimal")], by[(env, "apc")]
        red_c.append(1 - apc["cost_usd"] / ao["cost_usd"])
        red_l.append(1 - apc["latency_s"] / ao["latency_s"])
        kept.append(apc["accuracy"] / ao["accuracy"])
    rows.append(
        Row(
            "t1/AGGREGATE/apc_vs_accuracy_optimal",
            0.0,
            {
                "mean_cost_reduction": round(sum(red_c) / len(red_c), 4),
                "mean_latency_reduction": round(sum(red_l) / len(red_l), 4),
                "mean_accuracy_kept": round(sum(kept) / len(kept), 4),
                "paper": "cost -50.31%; latency -27.28%; accuracy kept 96.61%",
            },
        )
    )
    return rows

"""Shared benchmark helpers: timing + CSV emission.

Every benchmark exposes ``run(fast: bool) -> list[Row]``; run.py aggregates.
CSV schema (required by the harness): name,us_per_call,derived
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: Dict[str, Any]

    def csv(self) -> str:
        d = json.dumps(self.derived, sort_keys=True).replace(",", ";")
        return f"{self.name},{self.us_per_call:.3f},{d}"


def timeit(fn: Callable[[], Any], *, repeats: int = 3, number: int = 1) -> float:
    """Best-of-repeats wall time per call, in microseconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6

"""Shared benchmark helpers: timing + CSV emission + latency histograms.

Every benchmark exposes ``run(fast: bool) -> list[Row]``; run.py aggregates.
CSV schema (required by the harness): name,us_per_call,derived

``latency_summary`` folds per-request samples through the same bucketed
histogram the serving path exports at runtime (``repro.obs``), so the
p50/p90/p99 in BENCH_*.json use one percentile implementation everywhere —
tails instead of means.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List

from repro.obs import Histogram, latency_buckets


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: Dict[str, Any]

    def csv(self) -> str:
        d = json.dumps(self.derived, sort_keys=True).replace(",", ";")
        return f"{self.name},{self.us_per_call:.3f},{d}"


def latency_summary(samples_s: Iterable[float], *, unit: str = "s",
                    digits: int = 4) -> Dict[str, Any]:
    """Histogram summary of per-request latencies (seconds in, ``unit`` out).

    Returns {count, mean, p50, p90, p99, max} — percentiles come from the
    shared obs bucketed histogram (interpolated, clamped to observed
    min/max), matching the runtime ``*_latency`` metric exports.
    """
    scale = {"s": 1.0, "ms": 1e3, "us": 1e6}[unit]
    h = Histogram("bench.latency", bounds=latency_buckets())
    n = 0
    for s in samples_s:
        h.observe(float(s))
        n += 1
    if n == 0:
        return {"count": 0}
    snap = h.snapshot()
    out: Dict[str, Any] = {"count": n}
    for k in ("mean", "p50", "p90", "p99", "max"):
        out[k] = round(snap[k] * scale, digits)
    out["unit"] = unit
    return out


def timeit(fn: Callable[[], Any], *, repeats: int = 3, number: int = 1) -> float:
    """Best-of-repeats wall time per call, in microseconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6

"""t10: speculative plan execution — latency hidden on wins vs paid on losses.

The APC claim this measures (§4.3 latency hiding): on a fuzzy *near* hit
the agent executes the adapted cached plan immediately while the large
planner verifies in the background, so an agreeing verification serves at
``max(execute, verify)`` instead of ``verify + execute``; a diverging one
rolls the journal back and pays the verification as pure overhead on top
of the miss path. Rows (latencies are the harness's simulated serving
latencies; wall time only on the headline row):

  * ``t10/speculative``      — the whole workload under the speculative
    method: outcome census (commits / patches / rollbacks / exact hits /
    misses), hit rate, accuracy
  * ``t10/win_latency_hidden`` — committed speculations vs the SAME tasks
    under conservative apc (exact-only cache: a near hit is a miss that
    replans sequentially); ``hidden_pct`` is the headline
  * ``t10/loss_overhead``    — rolled-back speculations vs the same tasks
    under apc: the rollback pays the miss path PLUS the wasted
    verification rounds; ``overhead_pct`` quantifies the loss
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

from benchmarks.common import Row
from repro.core.harness import run_workload

ENV = "qasper"


def _mean(xs: List[float]) -> float:
    return sum(xs) / max(1, len(xs))


def run(fast: bool = False) -> List[Row]:
    n = 60 if fast else 80
    seeds = (3,) if fast else (1, 3, 7)

    census: Dict[str, int] = {"commit": 0, "patch": 0, "rollback": 0,
                              "exact_hit": 0, "miss": 0}
    hits = correct = total = 0
    # (speculative latency, baseline latency) pairs, per outcome
    wins: List[Tuple[float, float]] = []
    losses: List[Tuple[float, float]] = []
    wall = 0.0

    for seed in seeds:
        t0 = time.perf_counter()
        spec = run_workload(ENV, "speculative", n=n, seed=seed,
                            keep_records=True)
        wall += time.perf_counter() - t0
        base = run_workload(ENV, "apc", n=n, seed=seed, keep_records=True)
        base_by_id = {r.task_id: r for r in base.records}
        for r in spec.records:
            total += 1
            hits += r.hit
            correct += r.correct
            if r.speculated:
                census[r.spec_outcome] += 1
                pair = (r.latency_s, base_by_id[r.task_id].latency_s)
                if r.spec_outcome == "commit":
                    wins.append(pair)
                elif r.spec_outcome == "rollback":
                    losses.append(pair)
            else:
                census["exact_hit" if r.hit else "miss"] += 1

    rows: List[Row] = [Row("t10/speculative", wall / max(1, total) * 1e6, {
        "env": ENV, "n_per_seed": n, "seeds": len(seeds), **census,
        "hit_rate": round(hits / max(1, total), 4),
        "accuracy": round(correct / max(1, total), 4),
    })]

    if wins:
        got, seq = _mean([w[0] for w in wins]), _mean([w[1] for w in wins])
        rows.append(Row("t10/win_latency_hidden", got * 1e6, {
            "simulated": True, "n_wins": len(wins),
            "spec_latency_s": round(got, 4),
            "sequential_latency_s": round(seq, 4),
            "hidden_pct": round(100.0 * (1.0 - got / max(seq, 1e-9)), 1),
        }))
    if losses:
        got, seq = _mean([l[0] for l in losses]), _mean([l[1] for l in losses])
        rows.append(Row("t10/loss_overhead", got * 1e6, {
            "simulated": True, "n_losses": len(losses),
            "spec_latency_s": round(got, 4),
            "miss_latency_s": round(seq, 4),
            "overhead_pct": round(100.0 * (got / max(seq, 1e-9) - 1.0), 1),
        }))
    return rows

"""Quickstart: the APC pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs FinanceBench-style tasks through every method registered in the
``repro.memory`` method registry (the paper's baselines, APC, and the
exact->fuzzy->semantic ``cascade`` hybrid) and prints the paper's headline
comparison against the accuracy-optimal baseline.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.harness import METHODS, run_workload

N = 120  # cold-start dominates below ~50 tasks; 120 shows steady-state savings

print(f"{'method':20s} {'accuracy':>9s} {'cost $':>8s} {'latency s':>10s} {'hit%':>6s}")
results = {}
for method in METHODS:  # enumerated from the registry, not a hand-kept list
    r = run_workload("financebench", method, N)
    results[method] = r
    print(f"{method:20s} {r.accuracy:9.3f} {r.cost:8.3f} "
          f"{r.latency_s:10.1f} {100*r.hit_rate:5.1f}%")

apc, ao = results["apc"], results["accuracy_optimal"]
print(f"\nAPC vs accuracy-optimal: "
      f"cost -{100*(1-apc.cost/ao.cost):.1f}%, "
      f"latency -{100*(1-apc.latency_s/ao.latency_s):.1f}%, "
      f"accuracy kept {100*apc.accuracy/ao.accuracy:.1f}% "
      f"(paper: -50.31%, -27.28%, 96.61%)")

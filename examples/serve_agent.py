"""End-to-end driver: serve a small model with batched requests through the
full APC serving stack (keyword extraction, plan-cache routing, two-tier
planners, actor) running REAL JAX engines.

    PYTHONPATH=src python examples/serve_agent.py [--n 30] [--env tabmwp]

This is the paper's deployment in miniature: every control-plane LM call is
executed on a JAX model (reduced configs on CPU; swap --full on TPU), with
batched continuous decoding inside each engine, and the cache deciding which
tier serves each request.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import registry
from repro.configs.apc_minion import DEFAULT
from repro.core.agent_loop import AgentConfig, PlanActAgent
from repro.core.cost_model import CostLedger
from repro.envs.workloads import get_env
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.jax_backend import JaxBackend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30)
    ap.add_argument("--env", default="tabmwp")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    dep = DEFAULT
    print(f"tiers: large={dep.large_planner}  small={dep.small_planner}  "
          f"actor={dep.actor}  (reduced configs, {len(jax.devices())} device)")
    engines, built = {}, {}
    for role, arch in (("large_planner", dep.large_planner),
                       ("small_planner", dep.small_planner),
                       ("actor", dep.actor),
                       ("keyword_extractor", dep.keyword_extractor)):
        if arch not in built:
            cfg = registry.get(arch) if args.full else registry.get_smoke(arch)
            params = lm.init_params(cfg, jax.random.PRNGKey(len(built)))
            built[arch] = Engine(cfg, params, max_len=160)
        engines[role] = built[arch]

    backend = JaxBackend(engines, seed=0)
    ledger = CostLedger(pricing_map=dict(dep.pricing))
    agent = PlanActAgent(backend, ledger, AgentConfig(method="apc"))

    tasks = get_env(args.env).generate(args.n, seed=0)
    t0 = time.time()
    ok = hits = 0
    for i, t in enumerate(tasks):
        rec = agent.run_task(t)
        ok += rec.correct
        hits += rec.hit
        tag = "HIT " if rec.hit else "MISS"
        if i < 8 or (i + 1) % 10 == 0:
            print(f"  [{i+1:3d}] {tag} kw={rec.keyword[:34]:36s} "
                  f"correct={rec.correct}")
    print(f"\nn={args.n}  accuracy={ok/args.n:.2f}  hit_rate={hits/args.n:.2f}  "
          f"cost=${ledger.total_cost():.3f}  wall={time.time()-t0:.1f}s")
    print("engine tokens served:",
          {r: e.stats.prefill_tokens + e.stats.decode_tokens
           for r, e in engines.items()})


if __name__ == "__main__":
    main()

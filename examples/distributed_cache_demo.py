"""Distributed plan cache: sharding, replication, failure, elastic scaling.

    PYTHONPATH=src python examples/distributed_cache_demo.py

Shows the deployment-scale behavior of the APC test-time memory: keywords
consistent-hash-sharded over cache nodes with replication; node failures
served from replicas; elastic add/remove moving only ~K/N keys.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.distributed_cache import DistributedPlanCache
from repro.core.harness import run_workload
from repro.core.agent_loop import AgentConfig

print("== populate a 6-node replicated cache from a real APC run ==")
dc = DistributedPlanCache(6, replication=2, capacity_per_node=64)
res = run_workload("financebench", "apc", 120, cache=dc)
print(f"run: accuracy={res.accuracy:.2f} hit_rate={res.hit_rate:.2f} "
      f"entries={len(dc)}")
print("load by node:", dc.load_by_node())

print("\n== crash one node: replicas keep serving ==")
keys = dc.keys()
dc.mark_down("cache-3")
survive = sum(dc.lookup(k) is not None for k in keys)
print(f"after cache-3 down: {survive}/{len(keys)} keys still served")

print("\n== elastic scale-out: add two nodes ==")
before = {k: True for k in dc.keys()}
dc.add_node("cache-6")
dc.add_node("cache-7")
print("load by node:", dc.load_by_node())
still = sum(dc.lookup(k) is not None for k in before)
print(f"all keys reachable after rescale: {still}/{len(before)}")

print("\n== graceful decommission (keys re-homed, not lost) ==")
dc.mark_up("cache-3")
dc.remove_node("cache-0")
still = sum(dc.lookup(k) is not None for k in before)
print(f"after removing cache-0: {still}/{len(before)} keys reachable")

"""Train a reduced model for a few hundred steps with fault tolerance.

    PYTHONPATH=src python examples/train_small.py [--arch qwen2.5-3b] [--steps 200]

Exercises the full training substrate: AdamW, remat, atomic checkpoints, and
the fault-tolerant runner (a NaN is injected mid-run to demonstrate
rollback + resume).
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.distributed.fault import FaultPolicy, FaultTolerantRunner
from repro.launch.train import synthetic_batches
from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []

    def wrapped(state, batch):
        p, o = state
        p, o, m = step(p, o, batch)
        loss = float(np.asarray(m["loss"]))
        losses.append(loss)
        if len(losses) % 25 == 1:
            print(f"  step {len(losses):4d}  loss {loss:.4f}")
        return (p, o), {"loss": loss}

    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    runner = FaultTolerantRunner(
        wrapped, CheckpointStore(ckpt, keep_last=2),
        FaultPolicy(checkpoint_every=50),
    )
    runner.inject(args.steps // 2, "nan")  # demo: mid-run failure
    state, done, events = runner.run(
        (params, opt), synthetic_batches(cfg, 8, 48), args.steps
    )
    print(f"completed {done} steps; injected faults handled: "
          f"{[(e.step, e.kind) for e in events]}")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'OK: decreased' if losses[-1] < losses[0] else 'WARNING'})")


if __name__ == "__main__":
    main()

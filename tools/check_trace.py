"""Trace artifact gate (run by the CI smoke job and ``make trace-check``).

Validates the artifacts the traced quickstart (``python -m repro.obs``)
writes, against the schemas ``docs/observability.md`` documents:

1. ``trace.jsonl`` — every line is canonical JSON (``sort_keys``, compact
   separators — re-serialising must reproduce the bytes), carries exactly
   the span fields {name, span_id, parent_id, start, end, attrs, events},
   span ids are unique, every non-null ``parent_id`` resolves to a span in
   the file, children start no earlier than their parent (children may END
   after it — async cachegen spans outlive the route that submitted them
   by design), and every span/event name is catalogued in
   ``repro.obs.names`` (SPAN_NAMES / EVENT_NAMES).
2. ``trace_chrome.json`` — valid Chrome trace-event JSON: a
   ``traceEvents`` list whose entries carry {name, ph, pid, tid}, with
   ``"X"`` events also carrying numeric ``ts``/``dur`` and ``args``.
3. Cross-check — the Chrome timeline contains one ``"X"`` event per JSONL
   span (same multiset of names), so the two exports cannot drift apart.
4. Acceptance shape — the span forest contains at least one chain
   router.route_batch -> dcache.lookup_batch -> dcache.tier ->
   cache.lookup_batch -> match.stage, and at least one
   ``cache.attribution`` event with ``hit=true`` carries ``tokens_saved``.
   (Disable with ``--no-require-serving-path`` for traces of other
   entrypoints.)

Usage:  PYTHONPATH=src python tools/check_trace.py [--dir trace-out]
        PYTHONPATH=src python tools/check_trace.py trace.jsonl trace_chrome.json
        PYTHONPATH=src python -m tools.analyze --gate trace   (same checks)
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import pathlib
import sys
from typing import Any, Dict, List

ROOT = pathlib.Path(__file__).resolve().parent.parent

SPAN_FIELDS = {"name", "span_id", "parent_id", "start", "end", "attrs",
               "events"}
EVENT_FIELDS = {"name", "t", "attrs"}

# the route_batch acceptance chain: each name must appear as a (transitive)
# descendant of the previous one
SERVING_CHAIN = ["router.route_batch", "dcache.lookup_batch", "dcache.tier",
                 "cache.lookup_batch", "match.stage"]


def _catalog(name: str) -> List[str]:
    """Literal tuple from repro/obs/names.py via the AST (no import)."""
    path = ROOT / "src/repro/obs/names.py"
    for node in ast.parse(path.read_text()).body:
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == name for t in node.targets
        ):
            return list(ast.literal_eval(node.value))
    raise SystemExit(f"FAIL: literal {name} not found in {path}")


def check_jsonl(path: str, errors: List[str]) -> List[Dict[str, Any]]:
    span_kinds = set(_catalog("SPAN_NAMES"))
    event_kinds = set(_catalog("EVENT_NAMES"))
    spans: List[Dict[str, Any]] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            where = f"{path}:{lineno}"
            try:
                s = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{where}: not JSON ({e})")
                continue
            canon = json.dumps(s, sort_keys=True, separators=(",", ":"))
            if canon != line:
                errors.append(f"{where}: not canonical JSON "
                              "(sort_keys + compact separators)")
            if set(s) != SPAN_FIELDS:
                errors.append(f"{where}: span fields {sorted(s)} != "
                              f"{sorted(SPAN_FIELDS)}")
                continue
            if s["name"] not in span_kinds:
                errors.append(f"{where}: span kind {s['name']!r} is not in "
                              "repro.obs.names.SPAN_NAMES")
            if not isinstance(s["span_id"], int):
                errors.append(f"{where}: span_id must be int")
            if s["parent_id"] is not None and not isinstance(s["parent_id"], int):
                errors.append(f"{where}: parent_id must be int or null")
            if not isinstance(s["attrs"], dict):
                errors.append(f"{where}: attrs must be an object")
            for fld in ("start", "end"):
                if not isinstance(s[fld], (int, float)):
                    errors.append(f"{where}: {fld} must be a number "
                                  "(finished span)")
            if isinstance(s["start"], (int, float)) and \
                    isinstance(s["end"], (int, float)) and s["end"] < s["start"]:
                errors.append(f"{where}: end {s['end']} < start {s['start']}")
            if not isinstance(s["events"], list):
                errors.append(f"{where}: events must be a list")
                continue
            for ev in s["events"]:
                if not isinstance(ev, dict) or set(ev) != EVENT_FIELDS:
                    errors.append(f"{where}: event fields != "
                                  f"{sorted(EVENT_FIELDS)}: {ev!r}")
                elif ev["name"] not in event_kinds:
                    errors.append(f"{where}: event kind {ev['name']!r} is not "
                                  "in repro.obs.names.EVENT_NAMES")
            spans.append(s)

    by_id: Dict[int, Dict[str, Any]] = {}
    for s in spans:
        if s["span_id"] in by_id:
            errors.append(f"{path}: duplicate span_id {s['span_id']}")
        by_id[s["span_id"]] = s
    for s in spans:
        pid = s["parent_id"]
        if pid is None:
            continue
        parent = by_id.get(pid)
        if parent is None:
            errors.append(f"{path}: span {s['span_id']} ({s['name']}) has "
                          f"unknown parent_id {pid}")
        elif parent["start"] > s["start"]:
            # end containment is deliberately NOT checked: async cachegen
            # spans end after the route_batch span that submitted them
            errors.append(
                f"{path}: span {s['span_id']} ({s['name']}) starts at "
                f"{s['start']}, before its parent {pid} ({parent['name']}) "
                f"at {parent['start']}")
    return spans


def check_chrome(path: str, errors: List[str]) -> List[Dict[str, Any]]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"{path}: unreadable ({e})")
        return []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        errors.append(f"{path}: traceEvents must be a list")
        return []
    complete: List[Dict[str, Any]] = []
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        missing = {"name", "ph", "pid", "tid"} - set(ev)
        if missing:
            errors.append(f"{where}: missing {sorted(missing)}")
            continue
        if ev["ph"] == "X":
            for fld in ("ts", "dur"):
                if not isinstance(ev.get(fld), (int, float)):
                    errors.append(f"{where}: 'X' event needs numeric {fld}")
            if not isinstance(ev.get("args"), dict):
                errors.append(f"{where}: 'X' event needs args object")
            else:
                complete.append(ev)
        elif ev["ph"] == "i" and not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"{where}: 'i' event needs numeric ts")
    return complete


def check_cross(spans, chrome_x, errors: List[str]) -> None:
    want = sorted(s["name"] for s in spans)
    got = sorted(ev["name"] for ev in chrome_x)
    if want != got:
        only_j = [n for n in want if n not in got]
        only_c = [n for n in got if n not in want]
        errors.append(
            "chrome trace drifted from jsonl: "
            f"{len(want)} jsonl spans vs {len(got)} 'X' events "
            f"(jsonl-only {only_j[:5]}, chrome-only {only_c[:5]})")


def check_serving_path(spans, errors: List[str]) -> None:
    by_id = {s["span_id"]: s for s in spans}

    def ancestors(s):
        pid = s["parent_id"]
        while pid is not None and pid in by_id:
            yield by_id[pid]
            pid = by_id[pid]["parent_id"]

    # walk the chain bottom-up from every match.stage span
    found_chain = False
    for s in spans:
        if s["name"] != SERVING_CHAIN[-1]:
            continue
        names = [a["name"] for a in ancestors(s)]
        idx = -1
        ok = True
        for want in reversed(SERVING_CHAIN[:-1]):
            try:
                idx = names.index(want, idx + 1)
            except ValueError:
                ok = False
                break
        if ok:
            found_chain = True
            break
    if not found_chain:
        errors.append("no span chain " + " -> ".join(SERVING_CHAIN) +
                      " found (traced route_batch missing?)")

    attributed = [
        ev for s in spans for ev in s["events"]
        if ev["name"] == "cache.attribution" and ev["attrs"].get("hit")
    ]
    if not attributed:
        errors.append("no cache.attribution event with hit=true "
                      "(run enough repeats for a cache hit)")
    elif not any(isinstance(ev["attrs"].get("tokens_saved"), (int, float))
                 for ev in attributed):
        errors.append("cache.attribution hits carry no numeric tokens_saved")


def run(jsonl: str, chrome=None, require_serving_path: bool = True) -> tuple:
    """All checks against the artifact paths; returns (errors, summary).
    The ``trace`` gate of ``python -m tools.analyze`` and the legacy
    script entrypoint both call this."""
    errors: List[str] = []
    spans = check_jsonl(jsonl, errors)
    if not spans:
        errors.append(f"{jsonl}: no spans")
    chrome_x: List[Dict[str, Any]] = []
    if chrome is not None:
        chrome_x = check_chrome(chrome, errors)
        check_cross(spans, chrome_x, errors)
    if require_serving_path:
        check_serving_path(spans, errors)
    n_events = sum(len(s["events"]) for s in spans)
    summary = (f"trace OK: {len(spans)} spans ({n_events} events) in {jsonl}"
               + (f", {len(chrome_x)} complete events in {chrome}"
                  if chrome is not None else ""))
    return errors, summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/check_trace.py",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="trace.jsonl [trace_chrome.json] (default: --dir)")
    ap.add_argument("--dir", default="trace-out",
                    help="directory holding trace.jsonl + trace_chrome.json")
    ap.add_argument("--no-require-serving-path", action="store_true",
                    help="skip the route_batch span-chain acceptance check")
    args = ap.parse_args(argv)

    if args.paths:
        jsonl = args.paths[0]
        chrome = args.paths[1] if len(args.paths) > 1 else None
    else:
        jsonl = os.path.join(args.dir, "trace.jsonl")
        chrome = os.path.join(args.dir, "trace_chrome.json")

    if not os.path.exists(jsonl):
        print(f"FAIL: {jsonl} does not exist")
        return 1
    errors, summary = run(
        jsonl, chrome, require_serving_path=not args.no_require_serving_path)
    if errors:
        for e in errors:
            print(f"FAIL: {e}")
        return 1
    print(summary)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

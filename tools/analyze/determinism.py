"""Clock/seed determinism checker (checker id ``determinism``).

Invariant (PR 4/5's contract): sim-reachable packages — ``core``,
``serving``, ``memory``, ``index``, ``sim``, ``obs`` — are wall-clock
free and seed-deterministic. Concretely:

* no *calls* to ``time.time`` / ``time.monotonic`` / ``time.sleep``.
  Bare references are allowed: ``clock if clock is not None else
  time.time`` is exactly the injectable clock seam — the function object
  is stored as a default and the *call* goes through ``self._clock()``,
  which ``repro.sim`` rebinds to a ``VirtualClock``. A direct call
  bypasses the seam and breaks byte-identical replay.
* no use of the process-global RNGs: ``random.<fn>()`` module calls,
  ``random.Random()`` / ``np.random.RandomState()`` /
  ``np.random.default_rng()`` without a seed argument, ``np.random.<fn>()``
  draws, and ``random.seed``/``np.random.seed`` (global-state mutation).
  Seeded constructions (``random.Random(seed)``,
  ``np.random.RandomState(seed)``) and ``jax.random`` (explicit keys)
  are deterministic and pass.

``launch/*`` is the documented package allowlist (entrypoint scripts
time real work and never run under the sim); per-line suppression is
``# analysis: clock-ok(<reason>)`` / ``# analysis: seed-ok(<reason>)``.
"""

from __future__ import annotations

import ast
import pathlib
from typing import List, Optional, Set

from tools.analyze.common import (
    Finding,
    FindingBuilder,
    PACKAGE_ALLOWLIST,
    SIM_REACHABLE_PACKAGES,
    dotted,
    subpackage_of,
)

ID = "determinism"
PRAGMA = "clock"        # clock half; the seed half uses PRAGMA_SEED
PRAGMA_SEED = "seed"

_WALL_CLOCK = {"time.time", "time.monotonic", "time.sleep"}

# random-module draws/mutators that read the process-global RNG state
_GLOBAL_RANDOM_FNS = {
    "betavariate", "choice", "choices", "expovariate", "gammavariate",
    "gauss", "getrandbits", "lognormvariate", "normalvariate", "paretovariate",
    "randbytes", "randint", "random", "randrange", "sample", "seed",
    "setstate", "shuffle", "triangular", "uniform", "vonmisesvariate",
    "weibullvariate",
}


def _applies(path: pathlib.Path) -> bool:
    sub = subpackage_of(path)
    if sub is None:
        return True  # fixtures / out-of-tree files: full enforcement
    if sub in PACKAGE_ALLOWLIST:
        return False
    return sub in SIM_REACHABLE_PACKAGES


def _local_time_names(tree: ast.Module) -> Set[str]:
    """Names bound by ``from time import time/monotonic/sleep``."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in ("time", "monotonic", "sleep"):
                    out.add(alias.asname or alias.name)
    return out


def _np_aliases(tree: ast.Module) -> Set[str]:
    out = {"numpy"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "numpy":
                    out.add(alias.asname or "numpy")
    return out


def _has_seed_arg(call: ast.Call) -> bool:
    if call.args:
        return True
    return any(kw.arg in ("seed", "x") for kw in call.keywords)


def check(tree: ast.Module, src: str, path: pathlib.Path) -> List[Finding]:
    if not _applies(path):
        return []
    fb = FindingBuilder(path, src)
    out: List[Finding] = []
    time_names = _local_time_names(tree)
    np_names = _np_aliases(tree)

    def np_random_attr(node: ast.AST) -> Optional[str]:
        """'RandomState' for np.random.RandomState etc., else None."""
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "random"
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id in np_names):
            return node.attr
        return None

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)

        # -- wall clock: flag CALLS only (references are the seam default)
        if name in _WALL_CLOCK:
            out.append(fb.at(
                ID, node,
                f"direct {name}() call in a sim-reachable package — route "
                f"wall-clock reads through the injectable clock seam "
                f"(store the function as a default, call self.clock())"))
            continue
        if (isinstance(node.func, ast.Name) and node.func.id in time_names):
            out.append(fb.at(
                ID, node,
                f"direct {node.func.id}() call (imported from time) in a "
                f"sim-reachable package — use the injectable clock seam"))
            continue

        # -- process-global random module
        if name is not None and name.startswith("random."):
            attr = name.split(".", 1)[1]
            if attr in _GLOBAL_RANDOM_FNS:
                out.append(fb.at(
                    ID, node,
                    f"{name}() draws from the process-global RNG — construct "
                    f"a seeded random.Random(seed) instead"))
                continue
            if attr == "Random" and not _has_seed_arg(node):
                out.append(fb.at(
                    ID, node,
                    "random.Random() without a seed is entropy-seeded — pass "
                    "an explicit seed"))
                continue

        # -- numpy global RNG
        nattr = np_random_attr(node.func)
        if nattr is not None:
            if nattr in ("RandomState", "default_rng", "Generator"):
                if not _has_seed_arg(node):
                    out.append(fb.at(
                        ID, node,
                        f"np.random.{nattr}() without a seed is "
                        f"entropy-seeded — pass an explicit seed"))
            else:
                out.append(fb.at(
                    ID, node,
                    f"np.random.{nattr}() uses numpy's process-global RNG — "
                    f"use a seeded np.random.RandomState/default_rng"))
    return out

"""Thread/executor hygiene checker (checker id ``thread-hygiene``).

Invariant: every ``concurrent.futures.ThreadPoolExecutor`` and
``threading.Thread`` constructed in a module has a *reachable
disposition* — some code in the same module can end it:

* executor used as a context manager (``with ThreadPoolExecutor(...)``),
  or bound to a name/attribute on which ``.shutdown(...)`` is called
  somewhere in the module (``self._pool = ThreadPoolExecutor(...)`` +
  ``self._pool.shutdown(wait=True)`` in ``close()``);
* thread constructed with ``daemon=True``, or bound to a key that gets
  ``.join(...)`` called or ``.daemon = True`` assigned somewhere in the
  module.

An unbound construction (``ThreadPoolExecutor().submit(...)``, or a
bare ``return ThreadPoolExecutor(...)``) has no module-local
disposition and is flagged — leaked pools keep worker threads alive
past ``close()`` and hang interpreter shutdown.

Binding is resolved through the *enclosing statement*: the construction
may sit inside a conditional expression
(``self._pool = Executor(...) if async_ else None``) and still count as
bound to the assignment target.

Suppression: ``# analysis: thread-ok(<reason>)``.
"""

from __future__ import annotations

import ast
import pathlib
from typing import List, Optional

from tools.analyze.common import Finding, FindingBuilder, dotted

ID = "thread-hygiene"
PRAGMA = "thread"


def _kind_of(call: ast.Call) -> Optional[str]:
    name = dotted(call.func)
    if name is None:
        return None
    last = name.split(".")[-1]
    if last == "ThreadPoolExecutor":
        return "executor"
    if last == "Thread" and name in ("Thread", "threading.Thread"):
        return "thread"
    return None


def _expr_key(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return dotted(node)
    return None


def _bound_key(stmt: ast.stmt) -> Optional[str]:
    """Assignment target key when the statement binds exactly one
    name/attribute (conditional-expression values included)."""
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
        return _expr_key(stmt.targets[0])
    if isinstance(stmt, ast.AnnAssign):
        return _expr_key(stmt.target)
    return None


def _enclosing_stmt(tree: ast.AST, call: ast.Call) -> Optional[ast.stmt]:
    best = None
    for s in ast.walk(tree):
        if isinstance(s, ast.stmt) and any(sub is call for sub in ast.walk(s)):
            if best is None or s.lineno >= best.lineno:
                best = s
    return best


def _in_with_item(tree: ast.AST, call: ast.Call) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.With):
            for item in node.items:
                if any(sub is call for sub in ast.walk(item.context_expr)):
                    return True
    return False


def _daemon_kwarg(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "daemon" and isinstance(kw.value, ast.Constant) \
                and kw.value.value is True:
            return True
    return False


def _disposed(tree: ast.Module, key: str, kind: str) -> bool:
    methods = ("shutdown",) if kind == "executor" else ("join",)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in methods \
                and _expr_key(node.func.value) == key:
            return True
        if kind == "thread" and isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Attribute) and t.attr == "daemon" \
                        and _expr_key(t.value) == key \
                        and isinstance(node.value, ast.Constant) \
                        and node.value.value is True:
                    return True
    return False


def check(tree: ast.Module, src: str, path: pathlib.Path) -> List[Finding]:
    fb = FindingBuilder(path, src)
    out: List[Finding] = []
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        kind = _kind_of(call)
        if kind is None:
            continue
        if kind == "thread" and _daemon_kwarg(call):
            continue
        if _in_with_item(tree, call):
            continue  # context manager shuts down / scopes the pool
        stmt = _enclosing_stmt(tree, call)
        key = _bound_key(stmt) if stmt is not None else None
        noun = ("ThreadPoolExecutor" if kind == "executor"
                else "threading.Thread")
        if key is None:
            out.append(fb.at(
                ID, call,
                f"{noun} constructed without a binding — no reachable "
                f"shutdown/join/daemon disposition in this module; bind it "
                f"and dispose of it (or use it as a context manager)"))
            continue
        if not _disposed(tree, key, kind):
            want = (".shutdown(...)" if kind == "executor"
                    else ".join(...) or daemon=True")
            out.append(fb.at(
                ID, call,
                f"{noun} bound to `{key}` but no {want} on `{key}` anywhere "
                f"in this module — worker threads outlive the owner and "
                f"hang interpreter shutdown"))
    return out

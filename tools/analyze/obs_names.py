"""Obs-name drift checker (checker id ``obs-names``).

Invariant: every string handed to the metrics registry
(``registry.counter/gauge/histogram``) or the tracer
(``tracer.span`` / ``sp.event`` / module-level ``span``) comes from
``repro.obs.names`` — call sites reference ``_names.ROUTER_HITS``, not
``"router.hits"``. A bare literal at a call site drifts silently: the
docs-coverage gate and the catalog round-trip test
(``tests/test_obs.py``) only see names that flow through the catalog,
so a literal is an unaudited series the dashboards never hear about.

The checker flags string-literal name arguments at instrumentation call
sites. The ``repro.obs`` package itself is exempt — it is the defining
layer (the catalog's literals live there by design, and the registry
forwards ``name`` parameters it received).

Suppression: ``# analysis: obs-name-ok(<reason>)``.
"""

from __future__ import annotations

import ast
import pathlib
from typing import List, Optional

from tools.analyze.common import Finding, FindingBuilder, subpackage_of

ID = "obs-names"
PRAGMA = "obs-name"

# attribute call names that take a metric/span/event name as their first
# argument (or name=)
_SINKS = {
    "counter": "registry",
    "gauge": "registry",
    "histogram": "registry",
    "span": "tracer",
    "event": "span",
}


def _applies(path: pathlib.Path) -> bool:
    return subpackage_of(path) != "obs"


def _name_argument(call: ast.Call) -> Optional[ast.expr]:
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "name":
            return kw.value
    return None


def check(tree: ast.Module, src: str, path: pathlib.Path) -> List[Finding]:
    if not _applies(path):
        return []
    fb = FindingBuilder(path, src)
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        sink = None
        if isinstance(node.func, ast.Attribute) and node.func.attr in _SINKS:
            sink = node.func.attr
        elif isinstance(node.func, ast.Name) and node.func.id == "span":
            sink = "span"  # module-level repro.obs.span(...)
        if sink is None:
            continue
        arg = _name_argument(node)
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            out.append(fb.at(
                ID, arg,
                f"string literal {arg.value!r} passed to .{sink}() — import "
                f"the constant from repro.obs.names so the docs gate and the "
                f"catalog round-trip test can see this series"))
    return out

"""Journal-discipline checker (checker id ``journal-discipline``).

Invariant (the contract speculative plan execution rests on): every
env-side mutation in ``src/repro/core/`` and ``src/repro/envs/`` must be
reversible — a :class:`repro.envs.base.Workspace` ``write``/``delete``
returns its compensation closure, and the call site must hand that
closure STRAIGHT to a journal entry::

    step.applied(ws.write(key, value))      # the one blessed idiom

A workspace mutation whose undo is discarded (bare expression statement)
or parked in a local first is unjournaled as far as the rollback path
can prove, so it is reported. The check is deliberately syntactic and
strict: binding the undo before journaling it needs a
``# analysis: journal-ok(<reason>)`` pragma on the mutation line.

What counts as a workspace mutation: a ``.write(...)`` / ``.delete(...)``
call whose receiver's final name segment looks workspace-like — ``ws``,
``workspace``, ``*_ws``, ``*_workspace`` (so ``task.workspace.write``
and ``spec_ws.delete`` are caught, while ``buf.write`` / file-like
writers are not). Receivers are resolved lexically; the repo's naming
convention is part of the contract and documented in
``docs/static-analysis.md``.

Scope: files under ``src/repro/core/`` and ``src/repro/envs/`` (other
``src/repro`` packages drive envs through those layers); paths outside
``src/repro`` — the golden fixtures — are always in scope.
"""

from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional

from tools.analyze.common import Finding, FindingBuilder, dotted, rel

ID = "journal-discipline"
PRAGMA = "journal"

_MUTATORS = ("write", "delete")
_SCOPED_PREFIXES = ("src/repro/core/", "src/repro/envs/")


def _workspace_like(node: ast.AST) -> bool:
    """True when the receiver's final dotted segment names a workspace."""
    name = dotted(node)
    if name is None:
        return False
    last = name.split(".")[-1]
    return (
        last in ("ws", "workspace")
        or last.endswith("_ws")
        or last.endswith("_workspace")
    )


def _is_journaled(call: ast.Call, parents: Dict[ast.AST, ast.AST]) -> bool:
    """True when ``call`` is a DIRECT argument of ``<entry>.applied(...)``."""
    parent = parents.get(call)
    if isinstance(parent, ast.keyword):
        parent = parents.get(parent)
    return (
        isinstance(parent, ast.Call)
        and isinstance(parent.func, ast.Attribute)
        and parent.func.attr == "applied"
    )


def check(tree: ast.Module, src: str, path: pathlib.Path) -> List[Finding]:
    file = rel(path)
    if file.startswith("src/repro/") and not file.startswith(_SCOPED_PREFIXES):
        return []
    fb = FindingBuilder(path, src)
    parents: Dict[ast.AST, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _MUTATORS
            and _workspace_like(node.func.value)
        ):
            continue
        if _is_journaled(node, parents):
            continue
        receiver: Optional[str] = dotted(node.func.value)
        out.append(fb.at(
            ID, node,
            f"workspace mutation `{receiver}.{node.func.attr}(...)` is not "
            f"journaled — pass its undo straight to a journal entry "
            f"(`step.applied({receiver}.{node.func.attr}(...))`) or add "
            f"`# analysis: journal-ok(<reason>)`"))
    return out

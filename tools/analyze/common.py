"""Shared plumbing for the ``tools.analyze`` invariant checkers.

A checker is a module exposing::

    ID      = "lock-discipline"          # stable checker id (documented)
    PRAGMA  = "unlocked"                 # suppress via  # analysis: unlocked-ok(<reason>)
    def check(tree, src, path) -> List[Finding]

Findings are machine-readable (file:line, checker id, fingerprint); the
runner applies pragma suppression and the committed baseline, then fails
on anything left. Fingerprints hash the checker id, the repo-relative
path, and the *normalized source line* (plus an occurrence index for
duplicate lines) — NOT the line number — so unrelated edits above a
grandfathered finding do not churn the baseline.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Tuple

ROOT = pathlib.Path(__file__).resolve().parent.parent.parent

# sim-reachable packages (the determinism checker's enforcement scope);
# ``launch`` is the documented allowlist — entrypoint scripts time real
# wall-clock work and never run under repro.sim
SIM_REACHABLE_PACKAGES = ("core", "serving", "memory", "index", "sim", "obs")
PACKAGE_ALLOWLIST = ("launch",)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation at a source location."""

    checker: str
    file: str  # repo-relative (or as-given for out-of-repo paths)
    line: int
    col: int
    message: str
    fingerprint: str

    def render(self) -> str:
        return (f"{self.file}:{self.line}:{self.col}: [{self.checker}] "
                f"{self.message}  ({self.fingerprint})")

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def rel(path: pathlib.Path) -> str:
    try:
        return str(path.resolve().relative_to(ROOT))
    except ValueError:
        return str(path)


_WS_RE = re.compile(r"\s+")


def fingerprint(checker: str, file: str, norm_line: str, occurrence: int) -> str:
    h = hashlib.blake2b(
        f"{checker}|{file}|{norm_line}|{occurrence}".encode(), digest_size=8
    )
    return h.hexdigest()


class FindingBuilder:
    """Builds findings for one file, assigning content-stable fingerprints."""

    def __init__(self, path: pathlib.Path, src: str):
        self.path = path
        self.file = rel(path)
        self.lines = src.splitlines()
        self._seen: Dict[Tuple[str, str], int] = {}

    def _norm_line(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return _WS_RE.sub(" ", self.lines[line - 1].strip())
        return ""

    def at(self, checker: str, node: ast.AST, message: str) -> Finding:
        return self.at_line(checker, node.lineno, getattr(node, "col_offset", 0),
                            message)

    def at_line(self, checker: str, line: int, col: int, message: str) -> Finding:
        norm = self._norm_line(line)
        key = (checker, norm)
        occ = self._seen.get(key, 0)
        self._seen[key] = occ + 1
        return Finding(checker, self.file, line, col, message,
                       fingerprint(checker, self.file, norm, occ))


# -- pragmas ----------------------------------------------------------------
#
# Suppression syntax:   # analysis: <kind>-ok(<reason>)
# on the flagged line or the line directly above it. The reason is
# mandatory; a pragma that suppresses nothing is itself a finding
# (pragma-hygiene), so the allowlist can never silently rot.

PRAGMA_RE = re.compile(r"#\s*analysis:\s*([a-z][a-z-]*)-ok\(([^)]*)\)")


@dataclasses.dataclass
class Pragma:
    kind: str
    reason: str
    line: int
    used: bool = False


def parse_pragmas(src: str) -> List[Pragma]:
    out: List[Pragma] = []
    for i, text in enumerate(src.splitlines(), 1):
        m = PRAGMA_RE.search(text)
        if m:
            out.append(Pragma(m.group(1), m.group(2).strip(), i))
    return out


def apply_pragmas(
    findings: List[Finding],
    pragmas: List[Pragma],
    pragma_of_checker: Dict[str, Tuple[str, ...]],
) -> List[Finding]:
    """Drop findings suppressed by a matching pragma on the same line or
    the line directly above; mark those pragmas used."""
    by_line: Dict[Tuple[str, int], List[Pragma]] = {}
    for p in pragmas:
        by_line.setdefault((p.kind, p.line), []).append(p)

    kept: List[Finding] = []
    for f in findings:
        hit = None
        for kind in pragma_of_checker.get(f.checker, ()):
            for ln in (f.line, f.line - 1):
                for p in by_line.get((kind, ln), ()):
                    hit = p
                    break
                if hit:
                    break
            if hit:
                break
        if hit is None:
            kept.append(f)
        else:
            hit.used = True
    return kept


def iter_py_files(root: pathlib.Path) -> Iterable[pathlib.Path]:
    if root.is_file():
        yield root
        return
    for p in sorted(root.rglob("*.py")):
        yield p


def subpackage_of(path: pathlib.Path) -> Optional[str]:
    """First package under ``repro`` for in-repo sources, None otherwise
    (fixture files outside ``src/repro`` get full enforcement)."""
    parts = path.resolve().parts
    if "repro" in parts:
        i = parts.index("repro")
        if i + 1 < len(parts):
            return parts[i + 1].removesuffix(".py")
    return None


# -- small AST helpers ------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[ast.AST]:
    """The base expression of an Attribute/Subscript chain."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node


def is_self_attr(node: ast.AST, names: Optional[set] = None) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (names is None or node.attr in names))

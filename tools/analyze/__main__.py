"""CLI for the unified CI gates.

Usage:
    python -m tools.analyze                         # AST invariant checkers
    python -m tools.analyze --json report.json      # + machine-readable report
    python -m tools.analyze --checker determinism   # one checker only
    python -m tools.analyze --gate docs             # docs hygiene gate
    python -m tools.analyze --gate trace --trace-dir trace-out

Exit status: 0 when the selected gate passes, 1 otherwise.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    from tools.analyze import CHECKER_IDS
    from tools.analyze.gates import GATES

    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description=__doc__.splitlines()[0])
    ap.add_argument("--gate", choices=sorted(GATES), default="analyze",
                    help="which CI gate to run (default: analyze)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to analyze "
                         "(default: src/repro; analyze gate only)")
    ap.add_argument("--checker", choices=sorted(CHECKER_IDS), default=None,
                    help="run a single checker (analyze gate only)")
    ap.add_argument("--json", default=None, metavar="REPORT",
                    help="write a machine-readable findings report "
                         "(analyze gate only)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="baseline file of grandfathered fingerprints "
                         "(default: tools/analyze/baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--trace-dir", default="trace-out",
                    help="trace gate: directory holding trace.jsonl + "
                         "trace_chrome.json")
    ap.add_argument("--no-require-serving-path", action="store_true",
                    help="trace gate: skip the route_batch span-chain "
                         "acceptance check")
    args = ap.parse_args(argv)
    return GATES[args.gate](args)


if __name__ == "__main__":
    sys.exit(main())

"""Gate registry: one runner, three CI gates.

``python -m tools.analyze --gate <name>`` dispatches here. Each gate is
a function ``(args) -> int`` sharing the same fail/report contract:
print ``FAIL: ...`` lines for every problem and return non-zero, or
print a one-line summary and return 0.

* ``analyze`` — the AST invariant checkers in this package (default);
* ``docs``    — ``tools.check_docs`` (docs hygiene), same checks as
  running the script directly;
* ``trace``   — ``tools.check_trace`` (trace artifact schemas), same
  checks as running the script directly.

The legacy entrypoints ``python tools/check_docs.py`` and
``python tools/check_trace.py`` remain as thin aliases over the same
``run()`` functions these gates call.
"""

from __future__ import annotations

import ast
import json
import pathlib
import time
from typing import Callable, Dict, List, Tuple

from tools.analyze import CHECKER_IDS
from tools.analyze.common import (
    Finding,
    FindingBuilder,
    ROOT,
    apply_pragmas,
    iter_py_files,
    parse_pragmas,
    rel,
)
from tools.analyze import (
    determinism,
    jit_safety,
    journal,
    locks,
    obs_names,
    threads,
)

PRAGMA_HYGIENE_ID = "pragma-hygiene"

CHECKERS = (locks, determinism, jit_safety, obs_names, threads, journal)

# checker id -> pragma kinds that may suppress its findings
PRAGMAS_OF_CHECKER: Dict[str, Tuple[str, ...]] = {
    locks.ID: (locks.PRAGMA,),
    determinism.ID: (determinism.PRAGMA, determinism.PRAGMA_SEED),
    jit_safety.ID: (jit_safety.PRAGMA,),
    obs_names.ID: (obs_names.PRAGMA,),
    threads.ID: (threads.PRAGMA,),
    journal.ID: (journal.PRAGMA,),
}

_KNOWN_PRAGMA_KINDS = {k for kinds in PRAGMAS_OF_CHECKER.values()
                       for k in kinds}

DEFAULT_TARGET = ROOT / "src" / "repro"
BASELINE_PATH = pathlib.Path(__file__).resolve().parent / "baseline.json"


def _checker_subset(only: str | None):
    if only is None:
        return CHECKERS
    subset = tuple(c for c in CHECKERS if c.ID == only)
    if not subset and only != PRAGMA_HYGIENE_ID:
        raise SystemExit(
            f"FAIL: unknown checker {only!r} (known: "
            f"{', '.join(sorted(CHECKER_IDS))})")
    return subset


def analyze_paths(paths: List[pathlib.Path],
                  only: str | None = None) -> Tuple[List[Finding], int]:
    """Run the checkers over ``paths``; returns (findings, files checked).

    Per file: parse once, run every checker, then apply pragma
    suppression. Unused pragmas, unknown pragma kinds, and empty pragma
    reasons become ``pragma-hygiene`` findings so the suppression
    surface can never silently rot; so do stale ``LOCK_ALLOWLIST``
    entries.
    """
    checkers = _checker_subset(only)
    findings: List[Finding] = []
    checked_files: set = set()
    n_files = 0
    for root in paths:
        for path in iter_py_files(root):
            try:
                src = path.read_text()
                tree = ast.parse(src)
            except (OSError, SyntaxError) as e:
                fb = FindingBuilder(path, "")
                findings.append(fb.at_line(
                    PRAGMA_HYGIENE_ID, 1, 0, f"unparseable file: {e}"))
                continue
            n_files += 1
            checked_files.add(rel(path))
            fb = FindingBuilder(path, src)
            file_findings: List[Finding] = []
            for checker in checkers:
                file_findings.extend(checker.check(tree, src, path))
            pragmas = parse_pragmas(src)
            file_findings = apply_pragmas(file_findings, pragmas,
                                          PRAGMAS_OF_CHECKER)
            if only is None or only == PRAGMA_HYGIENE_ID:
                for p in pragmas:
                    if p.kind not in _KNOWN_PRAGMA_KINDS:
                        findings.append(fb.at_line(
                            PRAGMA_HYGIENE_ID, p.line, 0,
                            f"unknown pragma kind `{p.kind}-ok` (known: "
                            f"{', '.join(sorted(_KNOWN_PRAGMA_KINDS))})"))
                    elif not p.reason:
                        findings.append(fb.at_line(
                            PRAGMA_HYGIENE_ID, p.line, 0,
                            f"pragma `{p.kind}-ok()` has no reason — the "
                            f"reason is mandatory"))
                    elif not p.used and only is None:
                        findings.append(fb.at_line(
                            PRAGMA_HYGIENE_ID, p.line, 0,
                            f"pragma `{p.kind}-ok({p.reason})` suppresses "
                            f"nothing — the violation is gone; delete the "
                            f"pragma"))
            findings.extend(file_findings)
    if only in (None, locks.ID):
        for entry in locks.stale_allowlist_entries(checked_files):
            findings.append(Finding(
                PRAGMA_HYGIENE_ID, "tools/analyze/locks.py", 1, 0,
                f"LOCK_ALLOWLIST entry {entry!r} matches nothing — the "
                f"violation is gone; delete the entry",
                f"allowlist:{entry}"))
    findings.sort(key=lambda f: (f.file, f.line, f.checker))
    return findings, n_files


def _load_baseline(path: pathlib.Path) -> set:
    if not path.exists():
        return set()
    doc = json.loads(path.read_text())
    return set(doc.get("fingerprints", []))


def run_analyze(args) -> int:
    t0 = time.perf_counter()
    targets = [pathlib.Path(p) for p in (args.paths or [DEFAULT_TARGET])]
    findings, n_files = analyze_paths(targets, only=args.checker)
    baseline_path = pathlib.Path(args.baseline) if args.baseline \
        else BASELINE_PATH

    if args.write_baseline:
        baseline_path.write_text(json.dumps(
            {"fingerprints": sorted(f.fingerprint for f in findings)},
            indent=2) + "\n")
        print(f"wrote {len(findings)} fingerprints to {rel(baseline_path)}")
        return 0

    baseline = _load_baseline(baseline_path)
    new = [f for f in findings if f.fingerprint not in baseline]
    n_baselined = len(findings) - len(new)

    if args.json:
        pathlib.Path(args.json).write_text(json.dumps({
            "gate": "analyze",
            "files_checked": n_files,
            "baselined": n_baselined,
            "findings": [f.to_json() for f in new],
        }, indent=2) + "\n")

    for f in new:
        print(f"FAIL: {f.render()}")
    dt = time.perf_counter() - t0
    if new:
        print(f"analyze: {len(new)} finding(s) in {n_files} files "
              f"({n_baselined} baselined) [{dt:.1f}s]")
        return 1
    which = args.checker if args.checker else f"{len(CHECKERS)} checkers"
    print(f"analyze OK: {n_files} files, {which}, "
          f"{n_baselined} baselined finding(s) [{dt:.1f}s]")
    return 0


def run_docs(args) -> int:
    from tools import check_docs
    errors, summary = check_docs.run()
    for e in errors:
        print(f"FAIL: {e}")
    if errors:
        return 1
    print(summary)
    return 0


def run_trace(args) -> int:
    import os

    from tools import check_trace
    jsonl = os.path.join(args.trace_dir, "trace.jsonl")
    chrome = os.path.join(args.trace_dir, "trace_chrome.json")
    if not os.path.exists(jsonl):
        print(f"FAIL: {jsonl} does not exist")
        return 1
    errors, summary = check_trace.run(
        jsonl, chrome,
        require_serving_path=not args.no_require_serving_path)
    for e in errors:
        print(f"FAIL: {e}")
    if errors:
        return 1
    print(summary)
    return 0


GATES: Dict[str, Callable] = {
    "analyze": run_analyze,
    "docs": run_docs,
    "trace": run_trace,
}

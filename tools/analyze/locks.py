"""Lock-discipline / race checker (checker id ``lock-discipline``).

Invariant (the one PR 6 fixed dynamically and docstrings now promise):
in any class that OWNS a ``threading.Lock``/``RLock``, instance state
mutated outside ``__init__`` must be written under ``with self.<lock>``.

The analysis is call-graph-local per class:

* a *write* is any assignment / augmented assignment / ``del`` whose
  target is rooted at ``self`` (``self.x = ...``, ``self.x += 1``,
  ``self.store[k] = v``, ``self.stats.hits += 1``) in a method other
  than ``__init__``/``__post_init__`` (construction is single-threaded);
* a write is *held* when it is lexically inside ``with self.<lock>``
  for any lock the class owns (multi-item ``with`` statements count;
  nested functions inherit the lock state of their definition site);
* a private helper with unheld writes is fine when every intra-class
  call site holds the lock (``EmbeddingBank._grow`` is only called from
  ``add`` under ``bank.lock``) — the requirement propagates through
  unheld call sites by fixed point, and a method that ends up
  lock-requiring while being publicly callable is reported.

Suppression: ``# analysis: unlocked-ok(<reason>)`` on the write line,
plus the checked ``LOCK_ALLOWLIST`` below (entries are
``"<file>::<Class>.<method>"``; an entry that matches nothing is itself
reported, so the allowlist cannot rot).
"""

from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional, Set, Tuple

from tools.analyze.common import Finding, FindingBuilder, dotted, rel, root_name

ID = "lock-discipline"
PRAGMA = "unlocked"

# checked allowlist: "file::Class.method" entries whose unheld writes are
# accepted wholesale (prefer the per-line pragma; this exists for
# grandfathering a whole method). Ships empty — the tree is clean.
LOCK_ALLOWLIST: Tuple[str, ...] = ()

_LOCK_FACTORIES = {"Lock", "RLock"}


def _is_lock_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted(node.func)
    return name is not None and name.split(".")[-1] in _LOCK_FACTORIES


def _is_lock_factory_ref(node: ast.AST) -> bool:
    name = dotted(node)
    return name is not None and name.split(".")[-1] in _LOCK_FACTORIES


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attribute names holding a lock this class constructs."""
    locks: Set[str] = set()
    # dataclass fields: x: threading.Lock = field(default_factory=threading.Lock)
    for stmt in cls.body:
        value = None
        target = None
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            target, value = stmt.target.id, stmt.value
        elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            target, value = stmt.targets[0].id, stmt.value
        if target is None or value is None:
            continue
        if _is_lock_call(value):
            locks.add(target)
        elif isinstance(value, ast.Call) and dotted(value.func) in ("field",
                                                                   "dataclasses.field"):
            for kw in value.keywords:
                if kw.arg == "default_factory" and _is_lock_factory_ref(kw.value):
                    locks.add(target)
    # __init__-assigned: self.x = threading.Lock()
    for stmt in cls.body:
        if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(stmt):
            if isinstance(node, ast.Assign) and _is_lock_call(node.value):
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        locks.add(t.attr)
    return locks


class _MethodScan(ast.NodeVisitor):
    """Collect (write, held) and (self-call, held) facts for one method."""

    def __init__(self, locks: Set[str]):
        self.locks = locks
        self.held = False
        # (node, field, held)
        self.writes: List[Tuple[ast.AST, str, bool]] = []
        # callee -> list of (call node, held)
        self.calls: Dict[str, List[Tuple[ast.AST, bool]]] = {}

    # -- lock regions --

    def _with_holds(self, node: ast.With) -> bool:
        for item in node.items:
            ctx = item.context_expr
            if (isinstance(ctx, ast.Attribute)
                    and isinstance(ctx.value, ast.Name)
                    and ctx.value.id == "self" and ctx.attr in self.locks):
                return True
        return False

    def visit_With(self, node: ast.With) -> None:
        if self._with_holds(node):
            prev, self.held = self.held, True
            for stmt in node.body:
                self.visit(stmt)
            self.held = prev
        else:
            self.generic_visit(node)

    # -- writes --

    def _record_target(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._record_target(elt)
            return
        base = root_name(target)
        if isinstance(base, ast.Name) and base.id == "self":
            # field = first attribute hop above `self`
            node = target
            field = None
            while isinstance(node, (ast.Attribute, ast.Subscript)):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.value, ast.Name) and node.value.id == "self":
                    field = node.attr
                node = node.value
            if field is not None and field not in self.locks:
                self.writes.append((target, field, self.held))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_target(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_target(node.target)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_target(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record_target(t)
        self.generic_visit(node)

    # -- intra-class calls --

    def visit_Call(self, node: ast.Call) -> None:
        if (isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"):
            self.calls.setdefault(node.func.attr, []).append((node, self.held))
        self.generic_visit(node)

    # nested defs/lambdas inherit the lock state of their definition site
    # (the pattern in PlanCache.insert_batch: helpers defined inside the
    # locked region); their bodies are visited with self.held unchanged.

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        for stmt in node.body:
            self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


_CONSTRUCTORS = ("__init__", "__post_init__", "__new__")


def _check_class(cls: ast.ClassDef, fb: FindingBuilder,
                 allow: Set[str], file: str) -> List[Finding]:
    locks = _lock_attrs(cls)
    if not locks:
        return []
    scans: Dict[str, _MethodScan] = {}
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan = _MethodScan(locks)
            if stmt.name in _CONSTRUCTORS:
                # construction is single-threaded: writes are safe and its
                # call sites count as held
                scan.held = True
            for s in stmt.body:
                scan.visit(s)
            scans[stmt.name] = scan

    # fixed point: a method REQUIRES the lock if it has an unheld write,
    # or an unheld call to a method that requires the lock
    requires: Set[str] = {
        m for m, s in scans.items()
        if m not in _CONSTRUCTORS and any(not held for _, _, held in s.writes)
    }
    changed = True
    while changed:
        changed = False
        for m, s in scans.items():
            if m in requires or m in _CONSTRUCTORS:
                continue
            for callee, sites in s.calls.items():
                if callee in requires and any(not held for _, held in sites):
                    requires.add(m)
                    changed = True
                    break

    # a lock-requiring method is SAFE when it is private and every
    # intra-class call site is held or sits in a method that is itself
    # called only with the lock held (i.e. not exposed)
    callers_of: Dict[str, List[Tuple[str, bool]]] = {}
    for m, s in scans.items():
        for callee, sites in s.calls.items():
            for _, held in sites:
                callers_of.setdefault(callee, []).append((m, held))

    def exposed(m: str, seen: Set[str]) -> bool:
        if not m.startswith("_") or (m.startswith("__") and m.endswith("__")):
            return True  # publicly callable: external callers hold no lock
        sites = callers_of.get(m)
        if not sites:
            return True  # private but never called in-class: unverifiable
        for caller, held in sites:
            if held or caller in _CONSTRUCTORS:
                continue
            if caller in seen:
                continue  # cycle: optimistic (the cycle entry is checked)
            if exposed(caller, seen | {m}):
                return True
        return False

    out: List[Finding] = []
    for m in sorted(requires):
        if f"{file}::{cls.name}.{m}" in allow:
            allow_used.add(f"{file}::{cls.name}.{m}")
            continue
        if not exposed(m, set()):
            continue
        s = scans[m]
        reported = False
        for node, fieldname, held in s.writes:
            if not held:
                out.append(fb.at(
                    ID, node,
                    f"{cls.name}.{m} writes self.{fieldname} without holding "
                    f"any of {sorted('self.' + l for l in locks)} "
                    f"(class owns a lock; guard the write or add "
                    f"`# analysis: unlocked-ok(<reason>)`)"))
                reported = True
        if not reported:
            # requirement came from an unheld call to a lock-requiring helper
            for callee, sites in s.calls.items():
                if callee in requires:
                    for node, held in sites:
                        if not held:
                            out.append(fb.at(
                                ID, node,
                                f"{cls.name}.{m} calls self.{callee}() — which "
                                f"mutates instance state expecting the lock — "
                                f"without holding any of "
                                f"{sorted('self.' + l for l in locks)}"))
    return out


allow_used: Set[str] = set()


def check(tree: ast.Module, src: str, path: pathlib.Path) -> List[Finding]:
    fb = FindingBuilder(path, src)
    file = rel(path)
    allow = {e for e in LOCK_ALLOWLIST if e.startswith(f"{file}::")}
    out: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            out.extend(_check_class(node, fb, allow, file))
    return out


def stale_allowlist_entries(checked_files: Set[str]) -> List[str]:
    """Allowlist entries whose method no longer tripped the checker (or
    whose file was scanned and the entry never matched)."""
    return [e for e in LOCK_ALLOWLIST
            if e.split("::")[0] in checked_files and e not in allow_used]

"""``tools.analyze`` — AST-based invariant checkers for ``src/repro``.

Run with ``python -m tools.analyze`` (see ``docs/static-analysis.md``).

``CHECKER_IDS`` below is the canonical catalog of checker ids. It must
stay a pure literal: ``tools/check_docs.py`` (docs gate, check 6) reads
it via the AST — every id listed here must be documented in
``docs/static-analysis.md`` or the docs gate fails.
"""

from __future__ import annotations

CHECKER_IDS = (
    "lock-discipline",
    "determinism",
    "jit-safety",
    "obs-names",
    "thread-hygiene",
    "journal-discipline",
    "pragma-hygiene",
)

"""Jit purity & donation-safety checker (checker id ``jit-safety``).

Two invariants from the accelerator layer:

1. **Donation safety** — a function jitted with ``donate_argnums=...``
   *deletes* its donated input buffers (on TPU the old arena is gone,
   not stale). At every caller site in the same module, the expression
   passed in a donated position must not be READ again later in the
   calling function unless it was rebound first — the safe idiom is the
   call's own statement rebinding it, as in
   ``self._arena = _set_row(self._arena, ...)`` (``index/device.py``).
   Calls through a forwarding helper whose first argument is the jitted
   function (``_donated(fn, *args)``) shift the donated positions by
   one; ``functools.partial(fn, kw=...)`` wrappers resolve to ``fn``.

2. **Kernel/jit body purity** — functions decorated ``jax.jit`` (or
   ``functools.partial(jax.jit, ...)``) and kernel bodies handed to
   ``pl.pallas_call`` run under trace: no ``print``, no
   ``global``/``nonlocal`` declarations, no writes to captured Python
   state (targets whose base name is neither a parameter nor a local
   binding). Subscript stores into *parameters* are the Pallas
   ref-write idiom (``o_ref[...] = acc``) and pass.

Suppression: ``# analysis: jit-ok(<reason>)``.
"""

from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional, Set, Tuple

from tools.analyze.common import Finding, FindingBuilder, dotted, root_name

ID = "jit-safety"
PRAGMA = "jit"


def _literal_argnums(node: ast.AST) -> Optional[Tuple[int, ...]]:
    try:
        val = ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return None
    if isinstance(val, int):
        return (val,)
    if isinstance(val, (tuple, list)) and all(isinstance(v, int) for v in val):
        return tuple(val)
    return None


def _donated_argnums_of_decorator(dec: ast.AST) -> Optional[Tuple[int, ...]]:
    """donate_argnums for ``@jax.jit(...)`` / ``@functools.partial(jax.jit,
    ...)`` decorators (literal values only); () when jitted without
    donation, None when not a jit decorator."""
    if not isinstance(dec, ast.Call):
        return None
    fn = dotted(dec.func)
    is_jit = fn in ("jax.jit", "jit")
    if not is_jit and fn in ("functools.partial", "partial") and dec.args:
        is_jit = dotted(dec.args[0]) in ("jax.jit", "jit")
    if not is_jit:
        return None
    for kw in dec.keywords:
        if kw.arg == "donate_argnums":
            return _literal_argnums(kw.value) or ()
    return ()


def _is_jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        if dotted(dec) in ("jax.jit", "jit"):
            return True
        if _donated_argnums_of_decorator(dec) is not None:
            return True
    return False


def _expr_key(node: ast.AST) -> Optional[str]:
    """Stable key for a Name ('arena') or dotted chain ('self._arena')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return dotted(node)
    return None


# -- purity ------------------------------------------------------------------


class _PurityScan(ast.NodeVisitor):
    def __init__(self, fn: ast.FunctionDef, fb: FindingBuilder, kind: str):
        self.fb = fb
        self.kind = kind
        self.findings: List[Finding] = []
        args = fn.args
        self.locals: Set[str] = {
            a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)
        }
        for a in (args.vararg, args.kwarg):
            if a is not None:
                self.locals.add(a.arg)
        def bind(t: ast.AST) -> None:
            # only NAMES become locals — a Subscript/Attribute target
            # (STATE["k"] = v) binds nothing, it mutates captured state
            if isinstance(t, ast.Name):
                self.locals.add(t.id)
            elif isinstance(t, ast.Starred):
                bind(t.value)
            elif isinstance(t, (ast.Tuple, ast.List)):
                for elt in t.elts:
                    bind(elt)

        for node in ast.walk(fn):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            elif isinstance(node, (ast.For, ast.comprehension)):
                targets = [node.target]
            elif isinstance(node, ast.With):
                targets = [i.optional_vars for i in node.items
                           if i.optional_vars is not None]
            for t in targets:
                bind(t)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and node.func.id == "print":
            self.findings.append(self.fb.at(
                ID, node,
                f"print() inside a {self.kind} body — traced code must be "
                f"side-effect free (runs at trace time, not per call)"))
        self.generic_visit(node)

    def visit_Global(self, node: ast.Global) -> None:
        self.findings.append(self.fb.at(
            ID, node,
            f"`global {', '.join(node.names)}` inside a {self.kind} body — "
            f"traced code must not mutate captured Python state"))

    def visit_Nonlocal(self, node: ast.Nonlocal) -> None:
        self.findings.append(self.fb.at(
            ID, node,
            f"`nonlocal {', '.join(node.names)}` inside a {self.kind} body — "
            f"traced code must not mutate captured Python state"))

    def _flag_captured_write(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._flag_captured_write(elt)
            return
        if isinstance(target, (ast.Attribute, ast.Subscript)):
            base = root_name(target)
            if isinstance(base, ast.Name) and base.id not in self.locals:
                self.findings.append(self.fb.at(
                    ID, target,
                    f"write to captured state `{ast.unparse(target)}` inside "
                    f"a {self.kind} body — happens once at trace time; "
                    f"traced code must be pure"))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._flag_captured_write(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._flag_captured_write(node.target)
        self.generic_visit(node)


# -- donation ----------------------------------------------------------------


def _donating_functions(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                argnums = _donated_argnums_of_decorator(dec)
                if argnums:
                    out[node.name] = argnums
    return out


def _resolve_donated_call(
    node: ast.Call, donating: Dict[str, Tuple[int, ...]]
) -> Optional[Tuple[str, Dict[int, ast.AST]]]:
    """(callee name, {donated position -> argument expr}) for a call that
    reaches a donating function — directly, through a
    ``functools.partial`` wrapper, or through a forwarding helper whose
    FIRST argument is the donating function (donated positions shift
    by one)."""

    def target_of(expr: ast.AST) -> Optional[str]:
        name = _expr_key(expr)
        if name in donating:
            return name
        if isinstance(expr, ast.Call) and \
                dotted(expr.func) in ("functools.partial", "partial") and \
                expr.args:
            return target_of(expr.args[0])
        return None

    direct = target_of(node.func)
    if direct is not None:
        argmap = {i: node.args[i] for i in donating[direct]
                  if i < len(node.args)}
        return direct, argmap
    if node.args:
        fwd = target_of(node.args[0])
        if fwd is not None:
            argmap = {i: node.args[i + 1] for i in donating[fwd]
                      if i + 1 < len(node.args)}
            return fwd, argmap
    return None


def _stmt_rebinds(stmt: ast.stmt, key: str) -> bool:
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        for elt in (t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]):
            if _expr_key(elt) == key:
                return True
    return False


def _enclosing_stmt(fn: ast.AST, call: ast.Call) -> Optional[ast.stmt]:
    best = None
    for s in ast.walk(fn):
        if isinstance(s, ast.stmt) and s is not fn and \
                any(sub is call for sub in ast.walk(s)):
            if best is None or s.lineno >= best.lineno:
                best = s  # innermost enclosing statement
    return best


def _first_read_after(fn: ast.AST, after: ast.stmt, key: str) -> Optional[ast.AST]:
    """First Load of ``key`` in a statement after ``after`` (by line),
    stopping once a statement rebinds it without reading it."""
    later = sorted(
        (s for s in ast.walk(fn)
         if isinstance(s, ast.stmt)
         and s.lineno > (after.end_lineno or after.lineno)),
        key=lambda s: s.lineno,
    )
    for s in later:
        reads = [
            sub for sub in ast.walk(s)
            if isinstance(sub, (ast.Name, ast.Attribute))
            and isinstance(getattr(sub, "ctx", None), ast.Load)
            and _expr_key(sub) == key
        ]
        if reads:
            return reads[0]
        if _stmt_rebinds(s, key):
            return None
    return None


def _check_donation_sites(tree: ast.Module, fb: FindingBuilder,
                          donating: Dict[str, Tuple[int, ...]]) -> List[Finding]:
    out: List[Finding] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name in donating:
            continue  # the jitted body itself
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            hit = _resolve_donated_call(call, donating)
            if hit is None:
                continue
            callee, argmap = hit
            stmt = _enclosing_stmt(fn, call)
            if stmt is None:
                continue
            for pos, arg in argmap.items():
                key = _expr_key(arg)
                if key is None:
                    continue  # non-trivial expression: nothing to track
                if _stmt_rebinds(stmt, key):
                    continue  # x = donating(x, ...) — the safe idiom
                reader = _first_read_after(fn, stmt, key)
                if reader is not None:
                    out.append(fb.at(
                        ID, reader,
                        f"`{key}` was donated to {callee}() (donate_argnums "
                        f"position {pos}, line {call.lineno}) and is read "
                        f"again here — the donated buffer is deleted on "
                        f"device; rebind it from the call's result first"))
    return out


# -- pallas kernels ----------------------------------------------------------


def _pallas_kernel_names(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = dotted(node.func)
            if fn is not None and fn.split(".")[-1] == "pallas_call" and node.args:
                first = node.args[0]
                if isinstance(first, ast.Call) and \
                        dotted(first.func) in ("functools.partial", "partial") \
                        and first.args:
                    first = first.args[0]
                if isinstance(first, ast.Name):
                    out.add(first.id)
    return out


def check(tree: ast.Module, src: str, path: pathlib.Path) -> List[Finding]:
    fb = FindingBuilder(path, src)
    out: List[Finding] = []
    donating = _donating_functions(tree)
    kernels = _pallas_kernel_names(tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            kind = None
            if node.name in kernels:
                kind = "pallas kernel"
            elif _is_jit_decorated(node):
                kind = "jax.jit"
            if kind is not None:
                scan = _PurityScan(node, fb, kind)
                for stmt in node.body:
                    scan.visit(stmt)
                out.extend(scan.findings)
    out.extend(_check_donation_sites(tree, fb, donating))
    return out

"""Docs hygiene gate (run by the CI docs job and ``make docs-check``).

Six checks, all against the working tree:

1. ``README.md`` exists at the repo root.
2. Every *internal* markdown link in ``README.md`` and ``docs/*.md``
   resolves to a real file (anchors are stripped; external schemes —
   http/https/mailto — are skipped).
3. Every ``python -m <module> ...`` and ``make <target>`` command quoted
   in those documents still parses: ``python -m <module> --help`` must
   exit 0 (argparse wiring intact, imports clean) and ``make -n
   <target>`` must exit 0 (target exists). This keeps the docs from
   drifting into quoting commands that no longer run.
4. The operational surface is documented: every fault plan registered in
   ``repro.sim.faults`` (``FAULT_PLANS``, minus ``none``), every guard
   ablation key, and every public ``DistributedPlanCache`` method must
   appear in a code span/fence somewhere in the docs corpus — adding a
   fault plan or a control-plane method without documenting it fails CI.
5. The observability surface is documented: every metric name, span kind,
   and span-event kind catalogued in ``repro.obs.names``
   (``METRIC_NAMES``/``SPAN_NAMES``/``EVENT_NAMES``) must appear in a code
   span/fence in the docs corpus — instrumenting a new name without adding
   it to ``docs/observability.md`` fails CI.
6. The static-analysis surface is documented: every checker id catalogued
   in ``tools.analyze`` (``CHECKER_IDS``) must appear in a code span/fence
   in the docs corpus — adding a checker without documenting it in
   ``docs/static-analysis.md`` fails CI.

Usage:  PYTHONPATH=src python tools/check_docs.py
        PYTHONPATH=src python -m tools.analyze --gate docs   (same checks)
"""

from __future__ import annotations

import ast
import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PY_M_RE = re.compile(r"\bpython\s+-m\s+([A-Za-z_][\w.]*)")
MAKE_RE = re.compile(r"\bmake\s+([a-z][\w-]*)")
FENCE_RE = re.compile(r"```[^\n]*\n(.*?)```", re.S)
SPAN_RE = re.compile(r"`([^`\n]+)`")


def code_regions(text: str) -> str:
    """Fenced blocks + inline code spans, newline-joined.

    Commands are only extracted from these — prose like "make sure jax is
    installed" must not be executed as ``make -n sure``.
    """
    fenced = FENCE_RE.findall(text)
    stripped = FENCE_RE.sub("", text)  # keep spans outside fences only
    return "\n".join(fenced + SPAN_RE.findall(stripped))


def fail(errors: list) -> None:
    for e in errors:
        print(f"FAIL: {e}")
    raise SystemExit(1)


def doc_files() -> list:
    docs = [ROOT / "README.md"]
    docs += sorted((ROOT / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def check_links(errors: list) -> int:
    n = 0
    for doc in doc_files():
        for target in LINK_RE.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n += 1
            rel = target.split("#", 1)[0]
            if not (doc.parent / rel).exists():
                errors.append(f"{doc.relative_to(ROOT)}: broken link -> {target}")
    return n


def check_commands(errors: list) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    py_mods, make_targets = set(), set()
    for doc in doc_files():
        code = code_regions(doc.read_text())
        py_mods.update(PY_M_RE.findall(code))
        make_targets.update(MAKE_RE.findall(code))
    for mod in sorted(py_mods):
        r = subprocess.run(
            [sys.executable, "-m", mod, "--help"],
            capture_output=True, cwd=ROOT, env=env, timeout=120,
        )
        if r.returncode != 0:
            errors.append(
                f"`python -m {mod} --help` exited {r.returncode}: "
                f"{r.stderr.decode(errors='replace').strip()[-300:]}"
            )
    for tgt in sorted(make_targets):
        r = subprocess.run(
            ["make", "-n", tgt], capture_output=True, cwd=ROOT, timeout=60,
        )
        if r.returncode != 0:
            errors.append(f"`make -n {tgt}` exited {r.returncode} (missing target?)")
    return len(py_mods) + len(make_targets)


def public_store_methods() -> list:
    """Public method names of DistributedPlanCache, from the AST (no
    import needed, so this works even when runtime deps are missing)."""
    src = (ROOT / "src/repro/core/distributed_cache.py").read_text()
    for node in ast.parse(src).body:
        if isinstance(node, ast.ClassDef) and node.name == "DistributedPlanCache":
            return sorted(
                n.name for n in node.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                and not n.name.startswith("_")
            )
    raise SystemExit("FAIL: DistributedPlanCache not found in distributed_cache.py")


def _module_literal(path: pathlib.Path, name: str):
    """Value of a module-level literal assignment, via the AST (like
    public_store_methods, no import — the docs gate must not require the
    runtime deps)."""
    for node in ast.parse(path.read_text()).body:
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == name for t in node.targets
        ):
            return ast.literal_eval(node.value)
    raise SystemExit(f"FAIL: literal {name} not found in {path}")


def check_coverage(errors: list) -> int:
    """Fault-plan + control-plane documentation coverage (check 4)."""
    faults_py = ROOT / "src/repro/sim/faults.py"
    fault_plans = _module_literal(faults_py, "FAULT_PLANS")
    ablations = sorted(
        set(_module_literal(faults_py, "ABLATION_OF").values())
        | set(_module_literal(faults_py, "SCENARIO_ABLATION_OF").values())
        | set(_module_literal(faults_py, "EXTRA_PLAN_ABLATIONS").values())
    )

    corpus = "\n".join(code_regions(d.read_text()) for d in doc_files())
    required = {
        "fault plan": [p for p in fault_plans if p != "none"],
        "guard-ablation key": ablations,
        "DistributedPlanCache method": public_store_methods(),
    }
    n = 0
    for kind, names in required.items():
        for name in names:
            n += 1
            if not re.search(rf"\b{re.escape(name)}\b", corpus):
                errors.append(
                    f"{kind} `{name}` is not documented in README.md/docs/*.md "
                    "(mention it in a code span or fenced block)"
                )
    return n


def check_obs_coverage(errors: list) -> int:
    """Metric/span/event catalog documentation coverage (check 5)."""
    names_py = ROOT / "src/repro/obs/names.py"
    corpus = "\n".join(code_regions(d.read_text()) for d in doc_files())
    required = {
        "metric": _module_literal(names_py, "METRIC_NAMES"),
        "span kind": _module_literal(names_py, "SPAN_NAMES"),
        "span event": _module_literal(names_py, "EVENT_NAMES"),
    }
    n = 0
    for kind, names in required.items():
        for name in names:
            n += 1
            if not re.search(rf"(?<![\w.]){re.escape(name)}(?![\w.])", corpus):
                errors.append(
                    f"{kind} `{name}` (repro/obs/names.py) is not documented "
                    "in README.md/docs/*.md — add it to docs/observability.md"
                )
    return n


def check_checker_ids(errors: list) -> int:
    """Static-analysis checker-id documentation coverage (check 6)."""
    ids = _module_literal(ROOT / "tools/analyze/__init__.py", "CHECKER_IDS")
    corpus = "\n".join(code_regions(d.read_text()) for d in doc_files())
    n = 0
    for cid in ids:
        n += 1
        if not re.search(rf"(?<![\w-]){re.escape(cid)}(?![\w-])", corpus):
            errors.append(
                f"checker id `{cid}` (tools/analyze/__init__.py) is not "
                "documented in README.md/docs/*.md — add it to "
                "docs/static-analysis.md"
            )
    return n


def run() -> tuple:
    """All checks; returns (errors, summary). The ``docs`` gate of
    ``python -m tools.analyze`` and the legacy script entrypoint both
    call this."""
    errors: list = []
    if not (ROOT / "README.md").exists():
        return ["README.md does not exist at the repo root"], ""
    n_links = check_links(errors)
    n_cmds = check_commands(errors)
    n_names = check_coverage(errors)
    n_obs = check_obs_coverage(errors)
    n_ids = check_checker_ids(errors)
    summary = (
        f"docs OK: {len(doc_files())} documents, {n_links} internal links "
        f"resolve, {n_cmds} quoted commands parse, {n_names} operational "
        f"names covered, {n_obs} metric/span names covered, {n_ids} checker "
        f"ids covered"
    )
    return errors, summary


def main() -> None:
    errors, summary = run()
    if errors:
        fail(errors)
    print(summary)


if __name__ == "__main__":
    main()

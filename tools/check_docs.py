"""Docs hygiene gate (run by the CI docs job and ``make docs-check``).

Three checks, all against the working tree:

1. ``README.md`` exists at the repo root.
2. Every *internal* markdown link in ``README.md`` and ``docs/*.md``
   resolves to a real file (anchors are stripped; external schemes —
   http/https/mailto — are skipped).
3. Every ``python -m <module> ...`` and ``make <target>`` command quoted
   in those documents still parses: ``python -m <module> --help`` must
   exit 0 (argparse wiring intact, imports clean) and ``make -n
   <target>`` must exit 0 (target exists). This keeps the docs from
   drifting into quoting commands that no longer run.

Usage:  PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PY_M_RE = re.compile(r"\bpython\s+-m\s+([A-Za-z_][\w.]*)")
MAKE_RE = re.compile(r"\bmake\s+([a-z][\w-]*)")
FENCE_RE = re.compile(r"```[^\n]*\n(.*?)```", re.S)
SPAN_RE = re.compile(r"`([^`\n]+)`")


def code_regions(text: str) -> str:
    """Fenced blocks + inline code spans, newline-joined.

    Commands are only extracted from these — prose like "make sure jax is
    installed" must not be executed as ``make -n sure``.
    """
    fenced = FENCE_RE.findall(text)
    stripped = FENCE_RE.sub("", text)  # keep spans outside fences only
    return "\n".join(fenced + SPAN_RE.findall(stripped))


def fail(errors: list) -> None:
    for e in errors:
        print(f"FAIL: {e}")
    raise SystemExit(1)


def doc_files() -> list:
    docs = [ROOT / "README.md"]
    docs += sorted((ROOT / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def check_links(errors: list) -> int:
    n = 0
    for doc in doc_files():
        for target in LINK_RE.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n += 1
            rel = target.split("#", 1)[0]
            if not (doc.parent / rel).exists():
                errors.append(f"{doc.relative_to(ROOT)}: broken link -> {target}")
    return n


def check_commands(errors: list) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    py_mods, make_targets = set(), set()
    for doc in doc_files():
        code = code_regions(doc.read_text())
        py_mods.update(PY_M_RE.findall(code))
        make_targets.update(MAKE_RE.findall(code))
    for mod in sorted(py_mods):
        r = subprocess.run(
            [sys.executable, "-m", mod, "--help"],
            capture_output=True, cwd=ROOT, env=env, timeout=120,
        )
        if r.returncode != 0:
            errors.append(
                f"`python -m {mod} --help` exited {r.returncode}: "
                f"{r.stderr.decode(errors='replace').strip()[-300:]}"
            )
    for tgt in sorted(make_targets):
        r = subprocess.run(
            ["make", "-n", tgt], capture_output=True, cwd=ROOT, timeout=60,
        )
        if r.returncode != 0:
            errors.append(f"`make -n {tgt}` exited {r.returncode} (missing target?)")
    return len(py_mods) + len(make_targets)


def main() -> None:
    errors: list = []
    if not (ROOT / "README.md").exists():
        fail(["README.md does not exist at the repo root"])
    n_links = check_links(errors)
    n_cmds = check_commands(errors)
    if errors:
        fail(errors)
    print(
        f"docs OK: {len(doc_files())} documents, {n_links} internal links "
        f"resolve, {n_cmds} quoted commands parse"
    )


if __name__ == "__main__":
    main()

# Local mirror of .github/workflows/smoke.yml
PYTHONPATH := src

.PHONY: smoke test bench-fast docs-check sim-check

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-fast:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --fast --only t1,t5,f3,s1 --json-dir bench-json

docs-check:
	PYTHONPATH=$(PYTHONPATH) python tools/check_docs.py

# 5-seed deterministic-simulation matrix (scenarios x fault plans, guards
# on, plus the guard-ablation oracle audit); failure seeds land in
# sim-repro/ as replayable JSON (python -m repro.sim --replay <file>)
sim-check:
	PYTHONPATH=$(PYTHONPATH) python -m repro.sim --check --seeds 5 --dump-dir sim-repro

smoke: test bench-fast sim-check docs-check

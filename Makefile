# Local mirror of .github/workflows/smoke.yml
PYTHONPATH := src

.PHONY: smoke test bench-fast

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-fast:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --fast --only t5,f3

smoke: test bench-fast

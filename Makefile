# Local mirror of .github/workflows/smoke.yml
PYTHONPATH := src

.PHONY: smoke test bench-fast analyze docs-check sim-check trace-check

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-fast:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --fast --only t1,t4,t5,t8,t10,f3,s1 --json-dir bench-json

# AST invariant linter over src/repro (lock discipline, determinism,
# jit/donation safety, obs-name drift, thread hygiene) — pure stdlib,
# needs no runtime deps; see docs/static-analysis.md
analyze:
	PYTHONPATH=$(PYTHONPATH) python -m tools.analyze --json analysis-report.json

docs-check:
	PYTHONPATH=$(PYTHONPATH) python -m tools.analyze --gate docs

# 5-seed deterministic-simulation matrix (scenarios x fault plans, guards
# on, plus the guard-ablation oracle audit); failure seeds land in
# sim-repro/ as replayable JSON (python -m repro.sim --replay <file>)
sim-check:
	PYTHONPATH=$(PYTHONPATH) python -m repro.sim --check --seeds 5 --dump-dir sim-repro

# traced quickstart (python -m repro.obs) + artifact schema validation:
# trace.jsonl must be canonical span JSONL with a complete route_batch ->
# lookup -> match-stage chain and tokens_saved attribution on hits;
# trace_chrome.json must load in chrome://tracing / perfetto
trace-check:
	PYTHONPATH=$(PYTHONPATH) python -m repro.obs --out-dir trace-out
	PYTHONPATH=$(PYTHONPATH) python -m tools.analyze --gate trace --trace-dir trace-out

smoke: analyze test bench-fast sim-check docs-check trace-check

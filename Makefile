# Local mirror of .github/workflows/smoke.yml
PYTHONPATH := src

.PHONY: smoke test bench-fast docs-check

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-fast:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --fast --only t1,t5,f3 --json-dir bench-json

docs-check:
	PYTHONPATH=$(PYTHONPATH) python tools/check_docs.py

smoke: test bench-fast docs-check

# Local mirror of .github/workflows/smoke.yml
PYTHONPATH := src

.PHONY: smoke test bench-fast docs-check

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-fast:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --fast --only t5,f3

docs-check:
	PYTHONPATH=$(PYTHONPATH) python tools/check_docs.py

smoke: test bench-fast docs-check

"""Deterministic hash tokenizer (offline container: no external vocabs).

Word-level with hashed ids + byte fallback; reversibility is not required by
the serving stack (the APC control plane owns semantics), but token COUNTS
and boundaries behave like a real BPE within ~10%, which is what the
serving/cost measurements need.
"""

from __future__ import annotations

import hashlib
import re
from typing import List

_WORD_RE = re.compile(r"\w+|[^\w\s]")

BOS = 1
EOS = 2
PAD = 0
_RESERVED = 16


class HashTokenizer:
    def __init__(self, vocab_size: int = 50_304):
        self.vocab_size = vocab_size

    def _hash(self, piece: str) -> int:
        h = int.from_bytes(
            hashlib.blake2b(piece.encode(), digest_size=4).digest(), "little"
        )
        return _RESERVED + h % (self.vocab_size - _RESERVED)

    def encode(self, text: str, *, add_bos: bool = True) -> List[int]:
        ids = [BOS] if add_bos else []
        for w in _WORD_RE.findall(text):
            # long words split into 4-char pieces (BPE-ish length behavior)
            if len(w) <= 6:
                ids.append(self._hash(w.lower()))
            else:
                for i in range(0, len(w), 4):
                    ids.append(self._hash(w[i : i + 4].lower()))
        return ids

    def count(self, text: str) -> int:
        return len(self.encode(text, add_bos=False))

"""Roofline analysis from compiled HLO.

``compiled.cost_analysis()`` counts a ``lax.scan`` (while-loop) body ONCE —
verified empirically — so a scan-over-layers model under-reports FLOPs by
~L x. This module therefore builds its own cost model from
``compiled.as_text()``:

  * per-computation symbol tables (op name -> shape) so dot FLOPs can be
    computed as 2 * |out| * contracted_extent from the operand shapes;
  * a recursive walk of the call graph (while/fusion/call/conditional) that
    multiplies while-body costs by the trip count parsed from the loop
    condition's comparison constant;
  * collective bytes per op kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute, including -start variants), with the
    replica-group size captured for ring-cost refinement;
  * HBM-byte estimates per op (operands + outputs at fusion boundaries).

All numbers are PER DEVICE (the compiled module is the post-SPMD per-device
program), so roofline terms divide by per-chip peaks directly:

    compute    = flops / 197e12        (TPU v5e bf16)
    memory     = bytes / 819e9         (HBM BW)
    collective = coll_bytes / 50e9     (ICI per link)
"""

from __future__ import annotations

import argparse
import gzip
import json
import math
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Opcodes whose operands/outputs are charged as HBM traffic. The CPU backend
# barely fuses, so charging every elementwise op would grossly overstate what
# the TPU compiler (aggressive fusion) actually moves; this whitelist keeps
# the materialization-forcing ops only (fusion boundaries, matmuls, copies,
# slicing/gather/scatter, reductions, sorts, physical relayouts).
_BYTE_OPS = frozenset(
    {
        "dot", "convolution", "fusion", "copy", "dynamic-update-slice",
        "dynamic-slice", "gather", "scatter", "reduce", "sort", "transpose",
        "concatenate", "pad", "reduce-window", "select-and-scatter", "rng",
        "cholesky", "triangular-solve",
    }
    | set(COLLECTIVES)
    | {c + "-start" for c in COLLECTIVES}
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(type_str: str) -> int:
    """bytes of 'f32[16,2048]{1,0}' or tuple '(f32[2], s32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # args + attrs

    def operands(self) -> List[str]:
        depth = 0
        args = []
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    args = _OPERAND_RE.findall(self.rest[:i])
                    break
                depth -= 1
        return args

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)  # name -> type
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + int(v * mult)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = self._parse(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # -- parsing ----------------------------------------------------------

    @staticmethod
    def _parse(text: str) -> Dict[str, Computation]:
        comps: Dict[str, Computation] = {}
        cur: Optional[Computation] = None
        for line in text.splitlines():
            if cur is None:
                if line.rstrip().endswith("{") and not line.startswith(" "):
                    m = _COMP_HDR_RE.match(line)
                    if m:
                        cur = Computation(m.group(1))
                        for p in m.group(2).split(","):
                            p = p.strip()
                            if ":" in p:
                                pname, ptype = p.split(":", 1)
                                pname = pname.strip().lstrip("%")
                                cur.params[pname] = ptype.strip()
                                cur.symbols[pname] = ptype.strip()
                continue
            if line.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
                cur.ops.append(op)
                cur.symbols[op.name] = op.type_str
        return comps

    @staticmethod
    def _find_entry(text: str) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else None

    # -- trip counts ------------------------------------------------------

    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for op in comp.ops:
            if op.opcode == "constant":
                m = re.match(r"\s*(\d+)\s*\)", op.rest)
                if m:
                    consts.append(int(m.group(1)))
        # jax scans compare the induction var LT bound; take the max constant
        return max(consts) if consts else 1

    # -- op costs ---------------------------------------------------------

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out = shape_dims(op.type_str)
        out_n = math.prod(out) if out else 1
        operands = op.operands()
        if not operands:
            return 0.0
        lhs_type = comp.symbols.get(operands[0], "")
        lhs = shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        contract = 1
        if m and lhs:
            for d in m.group(1).split(","):
                if d:
                    contract *= lhs[int(d)]
        return 2.0 * out_n * contract

    def _group_size(self, op: Op) -> int:
        # replica_groups=[16,16]<=[256] or {{0,1},{2,3}}
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.rest)
        if m:
            return len(m.group(1).split(","))
        return 1

    # -- computation walk ---------------------------------------------------

    def cost_of(self, comp_name: str, *, inside_fusion: bool = False) -> Cost:
        key = comp_name + ("#f" if inside_fusion else "")
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        c = Cost()
        if comp is None:
            return c
        self._memo[key] = c  # placeholder breaks cycles
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "iota"):
                continue
            if oc == "while":
                body = op.attr("body")
                cond = op.attr("condition")
                trips = self.trip_count(cond) if cond else 1
                if body:
                    c.add(self.cost_of(body), trips)
                if cond:
                    c.add(self.cost_of(cond), trips)
                continue
            if oc in ("call", "custom-call", "async-start"):
                callee = op.attr("to_apply") or op.attr("called_computations") or op.attr("calls")
                if callee:
                    c.add(self.cost_of(callee))
                continue
            if oc == "conditional":
                for key_attr in ("true_computation", "false_computation"):
                    callee = op.attr(key_attr)
                    if callee:
                        c.add(self.cost_of(callee))
                # branch_computations={%a, %b}
                m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if m:
                    for nm in _OPERAND_RE.findall(m.group(1)):
                        c.add(self.cost_of(nm))
                continue
            if oc == "fusion":
                callee = op.attr("calls")
                if callee:
                    inner = self.cost_of(callee, inside_fusion=True)
                    c.flops += inner.flops
                    c.transcendentals += inner.transcendentals
                if not inside_fusion:
                    c.bytes += self._fusion_bytes(comp, op, callee)
                continue
            if oc == "dynamic-update-slice":
                # in-place: traffic = slice read+write, NOT the aliased buffer
                c.bytes += 2.0 * self._non_buffer_operand_bytes(comp, op)
                continue
            if oc == "dynamic-slice":
                c.bytes += 2.0 * shape_bytes(op.type_str)
                continue
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                if oc.endswith("-done"):
                    continue
                operand_bytes = sum(
                    shape_bytes(comp.symbols.get(o, "")) for o in op.operands()
                )
                c.coll_bytes[base] = c.coll_bytes.get(base, 0.0) + operand_bytes
                c.coll_count[base] = c.coll_count.get(base, 0) + 1
                if not inside_fusion:
                    c.bytes += self._io_bytes(comp, op)
                continue
            if oc in ("dot", "convolution"):
                c.flops += self._dot_flops(comp, op)
            elif oc in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                        "logistic", "sine", "cosine"):
                c.transcendentals += math.prod(shape_dims(op.type_str) or [1])
            if not inside_fusion and oc in _BYTE_OPS:
                c.bytes += self._io_bytes(comp, op)
        self._memo[key] = c
        return c

    def _io_bytes(self, comp: Computation, op: Op) -> float:
        b = shape_bytes(op.type_str)
        for o in op.operands():
            b += shape_bytes(comp.symbols.get(o, ""))
        return float(b)

    def _non_buffer_operand_bytes(self, comp: Computation, op: Op) -> float:
        """Operand bytes excluding ONE operand that matches the output shape
        (the aliased in-place buffer of a dynamic-update-slice pattern)."""
        out_b = shape_bytes(op.type_str)
        sizes = [shape_bytes(comp.symbols.get(o, "")) for o in op.operands()]
        if out_b in sizes:
            sizes.remove(out_b)
        return float(sum(sizes))

    def _fusion_bytes(self, comp: Computation, op: Op, callee: Optional[str]) -> float:
        """Fusion boundary traffic; DUS-rooted fusions alias their buffer, so
        only the slice-sized operands move."""
        root_oc = None
        cc = self.comps.get(callee) if callee else None
        if cc is not None and cc.ops:
            root_oc = cc.ops[-1].opcode
        if root_oc == "dynamic-update-slice":
            return 2.0 * self._non_buffer_operand_bytes(comp, op)
        return self._io_bytes(comp, op)

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


# ---------------------------------------------------------------------------
# Roofline terms per dry-run cell
# ---------------------------------------------------------------------------


def model_flops_per_device(arch: str, shape_name: str, mesh_shape: Dict[str, int]) -> float:
    """Analytic MODEL_FLOPS (param-math only): 6ND train / 2ND inference,
    MoE counts active params. Per device = global / chips."""
    from repro.configs import registry
    from repro.configs.base import SHAPES

    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    chips = math.prod(mesh_shape.values())
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / chips


def analytic_memory_bytes(
    arch: str, shape_name: str, mesh_shape: Dict[str, int]
) -> Dict[str, float]:
    """First-order per-device HBM traffic model (bytes/step).

    The CPU-compiled HLO barely fuses, so parsed byte counts overstate TPU
    HBM traffic by the number of unfused hops; this structural model is the
    primary memory term (components itemized for the perf loop), with the
    parsed bytes reported as an upper bound.
    """
    from repro.configs import registry
    from repro.configs.base import SHAPES

    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    profile = registry.get_sharding(arch, shape.kind)
    chips = math.prod(mesh_shape.values())
    tp = mesh_shape.get("model", 1)
    P = cfg.param_count()
    P_active = cfg.active_param_count()
    B_loc = max(1, shape.global_batch // (chips // tp))
    S = shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers
    out: Dict[str, float] = {}

    # weights: each device reads its 1/tp slice of the *touched* params per
    # pass (EP MoE: routed experts only ~ active + local share)
    touched = P_active if cfg.moe is not None else P
    w_read = 2.0 * touched / tp  # bf16
    if shape.kind == "train":
        passes = 3.0 if profile.remat == "full" else 2.0
        out["weights"] = w_read * passes
        opt_b = 10.0 if profile.optimizer_dtype == "bfloat16" else 20.0
        n_opt_shards = tp
        for ax in profile.fsdp_axes:
            n_opt_shards *= mesh_shape.get(ax, 1)
        out["optimizer"] = P * (opt_b + 8.0) / n_opt_shards  # m,v r/w + grad r/w
        act_unit = B_loc * S * d * 2.0
        hops = 16.0 if profile.remat == "full" else 24.0
        out["activations"] = act_unit * L * hops
        out["logits"] = B_loc * S * (cfg.vocab_size / tp) * 6.0
    elif shape.kind == "prefill":
        out["weights"] = w_read
        act_unit = B_loc * S * d * 2.0
        out["activations"] = act_unit * L * 8.0
        out["kv_write"] = (
            2.0 * cfg.num_attn_layers * B_loc * S * cfg.kv_dim * 2.0 / max(1, tp)
        )
        out["logits"] = B_loc * 1 * (cfg.vocab_size / tp) * 6.0  # last-token only
    else:  # decode
        out["weights"] = w_read
        cache_elems = 2.0 * cfg.num_attn_layers * shape.global_batch * S * cfg.kv_dim
        out["kv_read"] = cache_elems * 2.0 / chips  # bf16 cache, fully sharded
        if cfg.ssm is not None:
            s = cfg.ssm
            if s.kind == "rwkv6":
                H = d // s.head_dim
                st = L * shape.global_batch * H * s.head_dim * s.head_dim * 4.0
            else:
                d_in = s.expand * d
                st = L * shape.global_batch * (d_in // s.head_dim) * s.head_dim * s.state_dim * 4.0
            out["ssm_state"] = 2.0 * st / chips  # read + write
        out["activations"] = shape.global_batch * d * L * 2.0 * 4.0 / (chips // tp)
        out["logits"] = shape.global_batch * (cfg.vocab_size / tp) * 6.0 / max(1, chips // tp)
    return out


def analytic_resident_bytes(
    arch: str, shape_name: str, mesh_shape: Dict[str, int]
) -> Dict[str, float]:
    """Per-device HBM *residency* estimate for the real TPU target.

    The CPU backend has no native bf16 matmul, so XLA:CPU materializes f32
    copies of weights/activations — memory_analysis() therefore OVERSTATES
    TPU residency by ~2-3x for bf16 models (verified on the kimi prefill
    HLO: 15 f32 copies of the stacked expert weights). This structural
    estimate is the TPU-realistic number; both are reported.
    """
    from repro.configs import registry
    from repro.configs.base import SHAPES

    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    profile = registry.get_sharding(arch, shape.kind)
    chips = math.prod(mesh_shape.values())
    tp = mesh_shape.get("model", 1) if profile.tp_axis else 1
    n_shards = tp
    for ax in profile.fsdp_axes:
        n_shards *= mesh_shape.get(ax, 1)
    n_shards = min(n_shards, chips)
    P = cfg.param_count()
    out: Dict[str, float] = {"params": 2.0 * P / n_shards}
    dp = max(1, chips // tp)
    B_loc = max(1, shape.global_batch // dp)
    S = shape.seq_len
    act_unit = B_loc * S * cfg.d_model * 2.0
    if shape.kind == "train":
        opt_b = 4.0 if profile.optimizer_dtype == "bfloat16" else 8.0
        out["optimizer"] = P * opt_b / n_shards
        out["grads"] = 2.0 * P / n_shards
        # remat=full keeps ~1 activation per layer + working set
        out["activations"] = act_unit * (cfg.num_layers + 8)
        out["logits"] = B_loc * S * cfg.vocab_size / tp * 6.0
    elif shape.kind == "prefill":
        out["activations"] = act_unit * 10
        out["kv_cache"] = (
            2.0 * cfg.num_attn_layers * B_loc * S * cfg.kv_dim * 2.0 / tp
        )
    else:
        out["kv_cache"] = (
            2.0 * cfg.num_attn_layers * shape.global_batch * S * cfg.kv_dim * 2.0 / chips
        )
        out["activations"] = shape.global_batch * cfg.d_model * 2.0 * 8 / dp
    return out


def analyze_cell(json_path: Path) -> Dict:
    rec = json.loads(json_path.read_text())
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "ok": rec.get("ok", False),
    }
    if not rec.get("ok"):
        out["error"] = rec.get("error")
        return out
    hlo_path = Path(rec["hlo"])
    text = gzip.open(hlo_path, "rt").read()
    model = HloCostModel(text)
    cost = model.entry_cost()

    mem = rec.get("memory", {})
    hbm_resident = (
        mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
    )
    resident_est = analytic_resident_bytes(rec["arch"], rec["shape"], rec["mesh_shape"])
    mem_parts = analytic_memory_bytes(rec["arch"], rec["shape"], rec["mesh_shape"])
    mem_bytes = sum(mem_parts.values())
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    coll_s = cost.total_coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["mesh_shape"])
    step_s = max(terms.values())
    out.update(
        {
            "hlo_flops": cost.flops,
            "hlo_bytes_upper": cost.bytes,  # unfused CPU-HLO upper bound
            "memory_bytes": mem_bytes,
            "memory_parts": {k: round(v) for k, v in mem_parts.items()},
            "collective_bytes": cost.total_coll_bytes,
            "coll_breakdown": {k: round(v) for k, v in cost.coll_bytes.items()},
            "coll_counts": cost.coll_count,
            "raw_cost_analysis": rec.get("cost_analysis", {}),
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dominant,
            "model_flops_per_device": mf,
            "useful_flops_ratio": mf / cost.flops if cost.flops else 0.0,
            "roofline_fraction": (mf / PEAK_FLOPS) / step_s if step_s else 0.0,
            "hbm_resident_bytes": hbm_resident,  # raw CPU memory_analysis
            "fits_hbm_16g": hbm_resident <= 16 * 2**30,
            "resident_est_bytes": sum(resident_est.values()),  # TPU estimate
            "resident_est_parts": {k: round(v) for k, v in resident_est.items()},
            "fits_hbm_16g_est": sum(resident_est.values()) <= 16 * 2**30,
            "compile_s": rec.get("compile_s"),
        }
    )
    return out


_RECOMMEND = {
    "compute": "reduce recompute (remat policy) or shift FLOPs to lower-"
               "precision paths; compute-bound is the good end state",
    "memory": "shrink the working set (KV dtype, fused loss, activation "
              "layout) or raise arithmetic intensity via larger tiles",
    "collective": "re-shard to cut gathered bytes (FSDP axis choice, EP "
                  "capacity, KV head vs seq sharding) or overlap via async "
                  "collectives",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    results = Path(args.results) if args.results else (
        Path(__file__).resolve().parents[3] / "results" / "dryrun"
    )
    rows = []
    for f in sorted(results.glob(f"*__{args.mesh}{args.tag}.json")):
        try:
            rows.append(analyze_cell(f))
        except Exception as e:  # pragma: no cover
            rows.append({"file": str(f), "error": f"{type(e).__name__}: {e}"})
    outdir = results.parent / "roofline"
    outdir.mkdir(parents=True, exist_ok=True)
    out = Path(args.out) if args.out else outdir / f"roofline_{args.mesh}{args.tag}.json"
    out.write_text(json.dumps(rows, indent=2))
    # markdown table
    print(f"| arch | shape | compute_s | memory_s | collective_s | dominant | "
          f"useful% | roofline% | fits16G |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if "compute_s" not in r:
            print(f"| {r.get('arch','?')} | {r.get('shape','?')} | FAILED: "
                  f"{str(r.get('error'))[:40]} | | | | | | |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{100*r['useful_flops_ratio']:.0f}% | "
            f"{100*r['roofline_fraction']:.1f}% | "
            f"{'Y' if r['fits_hbm_16g_est'] else 'N'} |"
        )
    print(f"\nwritten: {out}")


if __name__ == "__main__":
    main()

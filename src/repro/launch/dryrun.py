import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are appended to results/dryrun/<arch>__<shape>__<mesh>.json and the
compiled HLO text is gzipped next to it (consumed by launch/roofline.py).
"""

import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.distributed import mesh_compat
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import DECODE_HEADROOM, input_specs
from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# Named perf-iteration variants (EXPERIMENTS.md §Perf): each maps
# (cfg, profile) -> (cfg, profile) for a hypothesis under test.
def _v_seq_parallel(cfg, profile):
    import dataclasses

    return cfg, dataclasses.replace(profile, seq_parallel=True)


def _v_model_as_dp(cfg, profile):
    import dataclasses

    return cfg, dataclasses.replace(
        profile, tp_axis="", extra_dp_axes=("model",),
        fsdp_axes=("data", "model"),
    )


def _v_fp8_dispatch(cfg, profile):
    import dataclasses

    assert cfg.moe is not None
    moe = dataclasses.replace(
        cfg.moe, a2a_dtype="float8_e4m3fn", capacity_factor=1.0,
        dispatch_chunks=4,
    )
    return dataclasses.replace(cfg, moe=moe), profile


def _v_fp8_dispatch_nochunk(cfg, profile):
    import dataclasses

    assert cfg.moe is not None
    moe = dataclasses.replace(cfg.moe, a2a_dtype="float8_e4m3fn",
                              capacity_factor=1.0)
    return dataclasses.replace(cfg, moe=moe), profile


def _v_granite_ep(cfg, profile):
    import dataclasses

    moe = dataclasses.replace(cfg.moe, mode="ep")
    return dataclasses.replace(cfg, moe=moe), profile


def _v_pad_heads(cfg, profile):
    import dataclasses

    # pad q heads to the next multiple of tp (28 -> 32) so head sharding is
    # clean, and replicate the (cheap) K/V projections instead of splitting
    # them within heads.
    return (
        dataclasses.replace(cfg, num_heads=32),
        dataclasses.replace(profile, shard_kv_proj=False),
    )


def _v_kimi_iter2(cfg, profile):
    import dataclasses

    cfg, profile = _v_fp8_dispatch(cfg, profile)
    return dataclasses.replace(cfg, attn_chunk=1024), profile


def _v_kv_seq(cfg, profile):
    import dataclasses

    return cfg, dataclasses.replace(profile, shard_kv_seq=True)


VARIANTS = {
    "seqpar": _v_seq_parallel,
    "padheads": _v_pad_heads,
    "kimi2": _v_kimi_iter2,
    "kvseq": _v_kv_seq,
    "modeldp": _v_model_as_dp,
    "fp8a2a": _v_fp8_dispatch,
    "fp8a2a_nochunk": _v_fp8_dispatch_nochunk,
    "graniteep": _v_granite_ep,
}


def build_cell(arch: str, shape_name: str, mesh, *, remat_override=None,
               profile_override=None, variant: str = ""):
    """Returns (jit_fn, example_args_sds, in_shardings) for one cell."""
    cfg = registry.get(arch)
    shape_kind = SHAPES[shape_name].kind
    profile = profile_override or registry.get_sharding(arch, shape_kind)
    if remat_override is not None:
        import dataclasses

        profile = dataclasses.replace(profile, remat=remat_override)
    if variant:
        cfg, profile = VARIANTS[variant](cfg, profile)
    shape = SHAPES[shape_name]
    dp = shd.dp_axes_for_mesh(mesh, profile)
    ctx = lm.ParallelCtx(mesh=mesh, dp_axes=dp, tp_axis=profile.tp_axis,
                         ep_axis=profile.ep_axis, remat=profile.remat,
                         seq_parallel=profile.seq_parallel)

    params_sds = lm.abstract_params(cfg)
    param_sh = shd.to_shardings(shd.param_pspecs(params_sds, profile, mesh), mesh)
    batch_sds, cache_sds = input_specs(cfg, shape)
    batch_sh = shd.to_shardings(shd.batch_pspecs(batch_sds, mesh, profile), mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=profile.optimizer_dtype)
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        opt_sh = {
            "m": param_sh,
            "v": param_sh,
            "step": shd.to_shardings(jax.sharding.PartitionSpec(), mesh),
        }
        step_fn = make_train_step(cfg, opt_cfg, ctx)
        fn = jax.jit(
            step_fn,
            in_shardings=(param_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1),
        )
        return fn, (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            logits, cache = lm.prefill(cfg, params, batch, ctx)
            return logits[:, -1], cache

        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        return fn, (params_sds, batch_sds)

    # decode
    cache_sh = shd.to_shardings(shd.cache_pspecs(cache_sds, cfg, profile, mesh), mesh)

    def decode_fn(params, cache, batch):
        return lm.decode_step(cfg, params, cache, batch["tokens"], ctx)

    fn = jax.jit(
        decode_fn, in_shardings=(param_sh, cache_sh, batch_sh), donate_argnums=(1,)
    )
    return fn, (params_sds, cache_sds, batch_sds)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, save_hlo: bool = True,
             tag: str = "", remat_override=None, variant: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "tag": tag, "variant": variant, "ok": False,
    }
    try:
        fn, args = build_cell(arch, shape_name, mesh,
                              remat_override=remat_override, variant=variant)
        with mesh_compat.set_mesh(mesh):
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
            }
            print("memory_analysis:", rec["memory"])
        except Exception as e:  # pragma: no cover
            rec["memory_error"] = str(e)
        try:
            ca = compiled.cost_analysis()
            rec["cost_analysis"] = {
                k: float(v)
                for k, v in ca.items()
                if isinstance(v, (int, float)) and k in (
                    "flops", "bytes accessed", "transcendentals",
                    "bytes accessed0{}", "bytes accessedout{}",
                )
            }
            print("cost_analysis:", rec["cost_analysis"])
        except Exception as e:  # pragma: no cover
            rec["cost_error"] = str(e)
        if save_hlo:
            RESULTS.mkdir(parents=True, exist_ok=True)
            hlo_path = RESULTS / f"{arch}__{shape_name}__{mesh_kind}{tag}.hlo.gz"
            with gzip.open(hlo_path, "wt") as f:
                f.write(compiled.as_text())
            rec["hlo"] = str(hlo_path)
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    out.write_text(json.dumps(rec, indent=2))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error')})"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}{tag}: {status} "
          f"in {rec['total_s']}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--variant", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = registry.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            if args.skip_existing:
                f = RESULTS / f"{arch}__{shape}__{mk}{args.tag}.json"
                if f.exists() and json.loads(f.read_text()).get("ok"):
                    print(f"[dryrun] skip existing {arch} x {shape} x {mk}")
                    n_ok += 1
                    continue
            rec = run_cell(arch, shape, mk, tag=args.tag,
                           remat_override=args.remat, variant=args.variant)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first use,
and smoke tests must see 1 device while the dry-run sees 512.

Mesh construction goes through ``repro.distributed.mesh_compat`` so the
same code runs on jax 0.4.37 (this container) and jax>=0.6 (the
``axis_types`` surface).
"""

from __future__ import annotations

import jax

from repro.distributed import mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return mesh_compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples)."""
    return mesh_compat.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (CPU smoke scale)."""
    n = len(jax.devices())
    return mesh_compat.make_mesh((n,), ("data",))

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first use,
and smoke tests must see 1 device while the dry-run sees 512.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (CPU smoke scale)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))

"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

CPU-scale by default (reduced config, synthetic LM data); pass --full for
the production config under the real mesh (TPU). Fault tolerance is on:
periodic atomic checkpoints + auto-resume via FaultTolerantRunner.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.distributed.fault import FaultPolicy, FaultTolerantRunner
from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def synthetic_batches(cfg, batch: int, seq: int):
    """Deterministic synthetic LM stream (shifted-token next-token task —
    learnable, so loss decreasing is a meaningful signal)."""

    def get(step: int):
        rng = np.random.RandomState(step)
        toks = rng.randint(16, min(cfg.vocab_size, 4096), size=(batch, seq + 1))
        # inject copy structure so the model can learn something
        toks[:, 1::2] = toks[:, 0:-1:2]
        b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg.family == "vlm":
            b = {"tokens": b["tokens"], "labels": b["labels"]}
        if cfg.family == "audio":
            fr = rng.randn(batch, cfg.encoder.num_frames, cfg.d_model)
            b["frames"] = jnp.asarray(fr, jnp.float32)
        return b

    return get


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--full", action="store_true", help="full config (TPU)")
    args = ap.parse_args()

    cfg = registry.get(args.arch) if args.full else registry.get_smoke(args.arch)
    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    def wrapped(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        return (params, opt_state), {
            k: float(np.asarray(v)) for k, v in metrics.items()
        }

    store = CheckpointStore(args.ckpt_dir, keep_last=2)
    runner = FaultTolerantRunner(
        wrapped, store, FaultPolicy(checkpoint_every=args.ckpt_every)
    )
    batches = synthetic_batches(cfg, args.batch, args.seq)

    t0 = time.time()
    losses = []

    def logged(state, b):
        s, m = wrapped(state, b)
        losses.append(m.get("loss", m.get("nll", 0.0)))
        if len(losses) % 10 == 1:
            print(f"[train] step={len(losses):4d} loss={losses[-1]:.4f} "
                  f"({time.time()-t0:.1f}s)")
        return s, m

    runner.step_fn = logged
    state, completed, events = runner.run(
        (params, opt_state), batches, args.steps
    )
    print(f"[train] done: {completed} steps, first loss {losses[0]:.4f} -> "
          f"last {losses[-1]:.4f}, fault events: {len(events)}")
    if losses[-1] >= losses[0]:
        print("[train] WARNING: loss did not decrease")


if __name__ == "__main__":
    main()

"""Serving driver: APC two-tier agent serving with batched requests.

    python -m repro.launch.serve --env financebench --n 40 --method apc

Runs the paper's pipeline end-to-end: keyword extraction -> plan-cache
routing -> small/large planner tier -> actor, with REAL JAX engines
(reduced configs on CPU; production configs + mesh on TPU via --full) and
prints the paper's headline metrics (cost, accuracy, latency, hit rate).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.configs.apc_minion import DEFAULT
from repro.core.agent_loop import AgentConfig, PlanActAgent
from repro.core.cost_model import CostLedger
from repro.envs.workloads import get_env
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.jax_backend import JaxBackend


def build_engines(deployment, *, full: bool = False, max_len: int = 192):
    roles = {
        "large_planner": deployment.large_planner,
        "small_planner": deployment.small_planner,
        "actor": deployment.actor,
        "keyword_extractor": deployment.keyword_extractor,
    }
    engines = {}
    cache = {}
    for role, arch in roles.items():
        if arch not in cache:
            cfg = registry.get(arch) if full else registry.get_smoke(arch)
            params = lm.init_params(cfg, jax.random.PRNGKey(hash(arch) % 2**31))
            cache[arch] = Engine(cfg, params, max_len=max_len)
        engines[role] = cache[arch]
    return engines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="financebench")
    ap.add_argument("--n", type=int, default=40)
    ap.add_argument("--method", default="apc")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--cache-capacity", type=int, default=100)
    args = ap.parse_args()

    deployment = DEFAULT
    print(f"[serve] tiers: large={deployment.large_planner} "
          f"small={deployment.small_planner} actor={deployment.actor}")
    engines = build_engines(deployment, full=args.full)
    backend = JaxBackend(engines, seed=0)
    ledger = CostLedger(pricing_map=dict(deployment.pricing))
    agent = PlanActAgent(
        backend, ledger,
        AgentConfig(method=args.method, cache_capacity=args.cache_capacity),
    )

    env = get_env(args.env)
    tasks = env.generate(args.n, seed=0)
    t0 = time.time()
    correct = hits = 0
    for i, t in enumerate(tasks):
        rec = agent.run_task(t)
        correct += rec.correct
        hits += rec.hit
        if (i + 1) % 10 == 0:
            print(f"[serve] {i+1}/{args.n} acc={correct/(i+1):.2f} "
                  f"hit={hits/(i+1):.2f} cost=${ledger.total_cost():.3f}")
    wall = time.time() - t0
    print(f"[serve] method={args.method} n={args.n}")
    print(f"  accuracy      {correct/args.n:.3f}")
    print(f"  hit rate      {hits/args.n:.3f}")
    print(f"  cost          ${ledger.total_cost():.4f}  (paper Table 8 prices)")
    print(f"  modeled lat.  {ledger.total_latency():.1f}s")
    print(f"  wall (CPU)    {wall:.1f}s")
    print(f"  engine rates  { {r: {k: round(v,1) for k,v in e.measured_rates().items()} for r, e in engines.items()} }")
    print(f"  cache entries {len(agent.cache)}")


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation — the dry-run lowers/compiles against these. The
modality frontends are stubs per the assignment: VLM cells get precomputed
patch/token embeddings + M-RoPE position ids; audio cells get precomputed
frame embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm

SDS = jax.ShapeDtypeStruct

DECODE_HEADROOM = 16  # extra KV slots beyond the prefilled seq_len (TP-aligned)


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig
) -> Tuple[Dict[str, Any], Optional[Any]]:
    """Returns (batch_specs, cache_specs_or_None)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if shape.kind == "decode":
        batch = {"tokens": SDS((B, 1), i32)}
        cache = lm.abstract_cache(cfg, B, S + DECODE_HEADROOM)
        return batch, cache

    if cfg.family == "vlm":
        batch: Dict[str, Any] = {
            "embeds": SDS((B, S, cfg.d_model), act),
            "positions": SDS((3, B, S), i32),
        }
    elif cfg.family == "audio":
        batch = {
            "frames": SDS((B, cfg.encoder.num_frames, cfg.d_model), act),
            "tokens": SDS((B, S), i32),
        }
    else:
        batch = {"tokens": SDS((B, S), i32)}

    if shape.kind == "train":
        batch["labels"] = SDS((B, S), i32)
    return batch, None


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, key=None):
    """Materialize a random batch matching input_specs (smoke scale only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs, cache = input_specs(cfg, shape)

    def mk(k, s):
        if s.dtype == jnp.int32:
            return jax.random.randint(k, s.shape, 0, max(2, cfg.vocab_size - 1))
        return jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)

    keys = jax.random.split(key, len(specs))
    batch = {name: mk(k, s) for k, (name, s) in zip(keys, specs.items())}
    return batch, cache

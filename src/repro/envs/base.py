"""Executable agent environments.

Each env mirrors one of the paper's five workloads with *machine-checkable*
tasks: a task carries a context document (field -> value), an intent (which
canonical multi-round plan solves it), slot bindings (entity names, years),
and a ground-truth answer computed by the same interpreter the actor uses.
Accuracy in every benchmark is therefore measured, not assumed.

The plan DSL the actor interprets:
    {"retrieve": [field, ...], "scope": {slot: value}}   -> {"values": {...}}
    {"compute": "<arithmetic over names a,b,c...>"}       -> final answer
"""

from __future__ import annotations

import hashlib
import math
import random
import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


class Workspace:
    """Mutable env-side state with a compensating-write protocol.

    Every mutation returns the compensation closure that restores the
    prior state (previous value, or absence), which callers hand to a
    :class:`repro.core.journal.StepJournal` entry — the contract that
    makes speculative plan execution reversible. The ``journal-discipline``
    static checker (tools.analyze) holds ``core/``/``envs/`` call sites to
    exactly that idiom: ``entry.applied(ws.write(key, value))``.

    Single-owner like the journal (one workspace per task, driven from
    one logical thread), so it takes no lock.
    """

    _ABSENT = object()

    def __init__(self) -> None:
        self._data: Dict[str, Any] = {}
        self.writes = 0
        self.compensations_run = 0

    def _restore(self, key: str, prior: Any) -> Callable[[], None]:
        def compensation() -> None:
            if prior is Workspace._ABSENT:
                self._data.pop(key, None)
            else:
                self._data[key] = prior
            self.compensations_run += 1

        return compensation

    def write(self, key: str, value: Any) -> Callable[[], None]:
        """Apply ``key = value`` eagerly; return the undo closure."""
        prior = self._data.get(key, Workspace._ABSENT)
        self._data[key] = value
        self.writes += 1
        return self._restore(key, prior)

    def delete(self, key: str) -> Callable[[], None]:
        """Remove ``key`` eagerly (no-op if absent); return the undo."""
        prior = self._data.get(key, Workspace._ABSENT)
        self._data.pop(key, None)
        self.writes += 1
        return self._restore(key, prior)

    def read(self, key: str, default: Any = None) -> Any:
        return self._data.get(key, default)

    def keys(self) -> List[str]:
        return sorted(self._data)

    def snapshot(self) -> Dict[str, Any]:
        """Deterministic copy for byte-identical state comparison."""
        return dict(sorted(self._data.items()))

    def __contains__(self, key: str) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)


@dataclass(frozen=True)
class IntentSpec:
    id: str
    keyword: str  # canonical intent keyword (cache key)
    query_template: str  # with {slot} placeholders
    rounds: List[List[str]]  # per Plan round: fields to retrieve
    expr: str  # final computation over names a,b,c,... in retrieval order
    paraphrase_keywords: Tuple[str, ...] = ()  # miss-extraction variants

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def all_fields(self) -> List[str]:
        return [f for r in self.rounds for f in r]


@dataclass
class Task:
    id: str
    env: str
    query: str
    intent: IntentSpec
    slots: Dict[str, str]
    context: Dict[str, float]  # the document/table the ACTOR sees
    distractors: List[str]  # plausible wrong field names
    gt_answer: float
    context_tokens: int  # token length of the context document
    # env-side effect surface: actor rounds record their retrieved values
    # here through the journal, so speculative rounds can be rolled back
    workspace: Workspace = field(default_factory=Workspace)


def det_rng(*parts: Any) -> random.Random:
    """Deterministic RNG from arbitrary key parts (reproducible runs)."""
    h = hashlib.blake2b("|".join(str(p) for p in parts).encode(), digest_size=8)
    return random.Random(int.from_bytes(h.digest(), "little"))


# ---------------------------------------------------------------------------
# Plan interpreter (the actor's execution semantics)
# ---------------------------------------------------------------------------

_EXPR_RE = re.compile(r"^[\sa-z0-9+\-*/().,_]*$")


def execute_retrieve(op: Dict[str, Any], context: Dict[str, float]) -> Dict[str, float]:
    vals = {}
    for f in op.get("retrieve", []):
        if f in context:
            vals[f] = context[f]
    return vals


def execute_compute(expr: str, bindings: Dict[str, float]) -> Optional[float]:
    if not _EXPR_RE.match(expr):
        return None
    env = {k: float(v) for k, v in bindings.items()}
    env.update({"abs": abs, "min": min, "max": max, "sqrt": math.sqrt})
    try:
        return float(eval(expr, {"__builtins__": {}}, env))  # noqa: S307 sandboxed
    except Exception:
        return None


def gt_for(intent: IntentSpec, context: Dict[str, float]) -> Optional[float]:
    names = "abcdefghij"
    bindings = {}
    for i, f in enumerate(intent.all_fields):
        if f not in context:
            return None
        bindings[names[i]] = context[f]
    return execute_compute(intent.expr, bindings)


# ---------------------------------------------------------------------------
# Judge (paper B.4.2 tolerance rules, deterministic)
# ---------------------------------------------------------------------------


def judge(answer: Optional[float], gt: float) -> bool:
    """Paper-style numeric grading: small rounding errors and unit slips
    (x1000 / x0.001 / percent-vs-fraction) are accepted; sign errors and
    order-of-magnitude mistakes are not."""
    if answer is None or not math.isfinite(answer):
        return False
    for scale in (1.0, 100.0, 0.01, 1000.0, 0.001):
        a = answer * scale
        if gt == 0:
            if abs(a) < 1e-6:
                return True
            continue
        if (a >= 0) == (gt >= 0) and abs(a - gt) / max(abs(gt), 1e-12) < 0.02:
            return True
    return False


# ---------------------------------------------------------------------------
# Env base
# ---------------------------------------------------------------------------


class AgentEnv:
    """Base: subclasses define intents(), entities, and context generation."""

    name = "base"
    context_tokens_range = (400, 1200)
    value_range = (10.0, 50_000.0)
    n_distractor_fields = 12

    def intents(self) -> List[IntentSpec]:
        raise NotImplementedError

    def entities(self) -> Dict[str, List[str]]:
        """slot name -> possible values."""
        raise NotImplementedError

    # -- task generation ----------------------------------------------------

    def generate(self, n: int, seed: int = 0) -> List[Task]:
        intents = self.intents()
        ents = self.entities()
        tasks = []
        for i in range(n):
            rng = det_rng(self.name, seed, i)
            intent = rng.choice(intents)
            slots = {k: rng.choice(v) for k, v in ents.items()}
            context, distractors = self._make_context(intent, rng)
            gt = gt_for(intent, context)
            # regenerate degenerate contexts (div-by-~0 etc.)
            tries = 0
            while (gt is None or not math.isfinite(gt) or abs(gt) > 1e12) and tries < 5:
                context, distractors = self._make_context(intent, rng)
                gt = gt_for(intent, context)
                tries += 1
            query = intent.query_template.format(**slots)
            ctok = rng.randint(*self.context_tokens_range)
            tasks.append(
                Task(
                    id=f"{self.name}-{seed}-{i}",
                    env=self.name,
                    query=query,
                    intent=intent,
                    slots=slots,
                    context=context,
                    distractors=distractors,
                    gt_answer=gt,
                    context_tokens=ctok,
                )
            )
        return tasks

    def _make_context(self, intent: IntentSpec, rng: random.Random):
        context: Dict[str, float] = {}
        for f in intent.all_fields:
            context[f] = round(rng.uniform(*self.value_range), 2)
        distractors = []
        for j in range(self.n_distractor_fields):
            name = f"{self.name}_aux_metric_{rng.randint(0, 999)}_{j}"
            context[name] = round(rng.uniform(*self.value_range), 2)
            distractors.append(name)
        return context, distractors

"""The other four paper workloads: TabMWP, QASPER, AIME, GAIA.

Each differs along the axes that matter to APC:
  * TabMWP  — short tabular contexts, ~30 recurring intents (high hit rate).
  * QASPER  — paper-QA, medium contexts, ~35 intents.
  * AIME    — competition math, few tasks, multi-round, moderate reuse.
  * GAIA    — heterogeneous open-domain tasks: most intents are UNIQUE
    (keyword rarely recurs), reproducing the paper's finding that initial
    planning rarely hits but re-planning still benefits.

This module also hosts the seeded sim-traffic generators
(:func:`sim_traffic`): per-client op streams the ``repro.sim``
deterministic-simulation harness interleaves against the plan store under
injected faults. Scenarios cover the cache's adversarial corners — skewed
reuse (zipf over recurring intents), paraphrase bursts (fuzzy-tier
pressure), and evict-then-hit floods (admission waves racing eviction).
"""

from __future__ import annotations

import random
from typing import Any, Dict, List

from repro.envs.base import AgentEnv, IntentSpec


def _mk(prefix, kw, tmpl, rounds, expr, para=()):
    return IntentSpec(
        id=f"{prefix}-{kw.replace(' ', '-')}",
        keyword=kw,
        query_template=tmpl,
        rounds=rounds,
        expr=expr,
        paraphrase_keywords=tuple(para),
    )


class TabMWPEnv(AgentEnv):
    name = "tabmwp"
    context_tokens_range = (300, 900)

    def intents(self) -> List[IntentSpec]:
        specs = [
            ("mean calculation", [["col_sum", "col_count"]], "a / b"),
            ("column total", [["col_sum"]], "a"),
            ("difference of entries", [["entry_x", "entry_y"]], "a - b"),
            ("max minus min", [["col_max", "col_min"]], "a - b"),
            ("unit price", [["total_price", "quantity"]], "a / b"),
            ("total cost", [["unit_price", "quantity"]], "a * b"),
            ("change in stock", [["stock_end", "stock_start"]], "a - b"),
            ("rate per hour", [["distance", "hours"]], "a / b"),
            ("median proxy", [["mid_low", "mid_high"]], "(a + b) / 2"),
            ("range of column", [["col_max", "col_min"]], "a - b"),
            ("percent of total", [["part_value", "col_sum"]], "a / b * 100"),
            ("remaining budget", [["budget", "spent"]], "a - b"),
            ("items affordable", [["budget", "unit_price"]], "a / b"),
            ("combined weight", [["weight_x", "weight_y"]], "a + b"),
            ("average of two rows", [["row_x_sum", "row_y_sum"]], "(a + b) / 2"),
            ("tax amount", [["subtotal", "tax_rate"]], "a * b / 100"),
            ("tip total", [["bill", "tip_rate"]], "a * (1 + b / 100)"),
            ("profit from sales", [["revenue_v", "cost_v"]], "a - b"),
            ("ratio of columns", [["col_a_sum", "col_b_sum"]], "a / b"),
            ("weekly total", [["daily_avg"]], "a * 7"),
            ("dozen price", [["unit_price"]], "a * 12"),
            ("split evenly", [["total_price", "people"]], "a / b"),
            ("speed difference", [["speed_x", "speed_y"]], "a - b"),
            ("area of table grid", [["rows_n", "cols_n"]], "a * b"),
            ("fraction simplified", [["numer", "denom"]], "a / b"),
            ("discounted price", [["list_price", "discount_pct"]], "a * (1 - b / 100)"),
            ("total pages read", [["pages_per_day", "days_n"]], "a * b"),
            ("savings goal weeks", [["goal_amt", "weekly_save"]], "a / b"),
            (
                "two step budget",
                [["budget", "spent"], ["unit_price"]],
                "(a - b) / c",
            ),
            (
                "table then rate",
                [["col_sum", "col_count"], ["hours"]],
                "(a / b) / c",
            ),
        ]
        return [
            _mk(
                "tab",
                kw,
                "Using the table for {student} from {month}: what is the %s? "
                "Answer with a number." % kw,
                r,
                e,
                (kw + " from table",),
            )
            for kw, r, e in specs
        ]

    def entities(self) -> Dict[str, List[str]]:
        return {
            "student": ["Ava", "Ben", "Caleb", "Dina", "Eli", "Fern", "Gus",
                        "Hana", "Ira", "Jude", "Kira", "Liam", "Mona", "Nico"],
            "month": ["January", "February", "March", "April", "May", "June",
                      "July", "August", "September", "October"],
        }


class QasperEnv(AgentEnv):
    name = "qasper"
    context_tokens_range = (4_000, 8_000)

    def intents(self) -> List[IntentSpec]:
        specs = [
            ("dataset size", [["train_examples"]], "a"),
            ("improvement over baseline", [["model_score", "baseline_score"]], "a - b"),
            ("relative gain", [["model_score", "baseline_score"]], "(a - b) / b"),
            ("parameter count", [["param_millions"]], "a"),
            ("training epochs", [["epochs_n"]], "a"),
            ("f1 average", [["f1_dev", "f1_test"]], "(a + b) / 2"),
            ("ablation drop", [["full_score", "ablated_score"]], "a - b"),
            ("annotation agreement", [["kappa_score"]], "a"),
            ("corpus token count", [["corpus_tokens_m"]], "a"),
            ("layers used", [["layers_n"]], "a"),
            ("learning rate scaled", [["lr_base", "batch_scale"]], "a * b"),
            ("compute budget", [["gpu_hours", "gpu_cost"]], "a * b"),
            ("accuracy delta across langs", [["acc_lang_x", "acc_lang_y"]], "a - b"),
            ("human eval mean", [["human_score_sum", "human_raters"]], "a / b"),
            ("error rate", [["errors_n", "total_examples"]], "a / b"),
            ("speedup factor", [["latency_base", "latency_new"]], "a / b"),
            ("memory saving", [["mem_base", "mem_new"]], "(a - b) / a"),
            ("dev test gap", [["f1_dev", "f1_test"]], "a - b"),
            ("citations per year", [["citations_n", "years_since"]], "a / b"),
            ("vocab coverage", [["covered_tokens", "corpus_tokens_m"]], "a / b"),
            ("throughput", [["examples_n", "seconds_n"]], "a / b"),
            ("pretrain finetune ratio", [["pretrain_steps", "finetune_steps"]], "a / b"),
            ("agreement minus chance", [["raw_agreement", "chance_agreement"]],
             "(a - b) / (1 - b)"),
            ("mean sentence length", [["token_count", "sentence_count"]], "a / b"),
            ("oov rate", [["oov_n", "token_count"]], "a / b"),
            (
                "two section synthesis",
                [["model_score", "baseline_score"], ["param_millions"]],
                "(a - b) / c",
            ),
            (
                "efficiency normalized gain",
                [["model_score", "baseline_score"], ["gpu_hours"]],
                "(a - b) / c",
            ),
        ]
        return [
            _mk(
                "qas",
                kw,
                "From the paper '{paper}' ({venue}): report the %s as a single "
                "number, citing the relevant section." % kw,
                r,
                e,
                (kw + " lookup",),
            )
            for kw, r, e in specs
        ]

    def entities(self) -> Dict[str, List[str]]:
        return {
            "paper": [f"Study-{i:03d}" for i in range(60)],
            "venue": ["ACL", "EMNLP", "NAACL", "ICLR", "NeurIPS", "ICML"],
        }


class AimeEnv(AgentEnv):
    name = "aime"
    context_tokens_range = (100, 300)
    value_range = (2.0, 60.0)

    def intents(self) -> List[IntentSpec]:
        specs = [
            ("remainder computation", [["big_n", "mod_m"]], "a - b * (a // b) if False else a % b"),
            ("triangle area", [["base_len", "height_len"]], "a * b / 2"),
            ("arithmetic series sum", [["first_term", "last_term"], ["terms_n"]],
             "(a + b) * c / 2"),
            ("geometric mean", [["val_x", "val_y"]], "sqrt(a * b)"),
            ("quadratic vertex", [["coef_a", "coef_b"]], "-b / (2 * a)"),
            ("distance formula", [["dx_sq", "dy_sq"]], "sqrt(a + b)"),
            ("combinatorial ratio", [["ways_total", "ways_valid"]], "b / a"),
            ("digit sum proxy", [["num_tens", "num_ones"]], "a + b"),
            ("probability product", [["p_first", "p_second"]], "a * b"),
            ("expected value two outcome", [["p_win", "payoff"], ["loss_amt"]],
             "a * b - (1 - a) * c"),
            ("circle sector area", [["radius_r", "angle_frac"]], "3.14159265 * a * a * b"),
            ("work rate combined", [["rate_x", "rate_y"]], "1 / (1 / a + 1 / b)"),
        ]
        out = []
        for kw, r, e in specs:
            if "%" in e or "//" in e:
                e = "a - b * 3"  # keep DSL arithmetic simple & closed-form
            out.append(
                _mk(
                    "aime",
                    kw,
                    "AIME {year} problem {pnum}: compute the %s given the stated "
                    "quantities. Provide the numeric answer." % kw,
                    r,
                    e,
                    (kw + " problem",),
                )
            )
        return out

    def entities(self) -> Dict[str, List[str]]:
        return {
            "year": ["2024", "2025"],
            "pnum": [str(i) for i in range(1, 16)],
        }


class GaiaEnv(AgentEnv):
    """Open-domain assistant tasks — intent space is nearly unique per task,
    so keyword reuse is rare (paper §4.2 GAIA analysis). Implemented by
    generating a large intent pool relative to typical run sizes."""

    name = "gaia"
    context_tokens_range = (1_500, 5_000)

    _VERBS = ["total", "difference", "ratio", "average", "share"]
    _DOMAINS = [
        "museum visitor logs", "olympic medal tables", "arxiv submission stats",
        "wikipedia edit history", "sales ledgers", "video dialogue transcripts",
        "census snapshots", "github release notes", "weather station records",
        "shipping manifests", "conference schedules", "music chart archives",
        "patent filings", "menu price lists", "train timetables",
        "library catalogs", "football season stats", "satellite pass logs",
        "power grid reports", "vaccine trial tables", "movie box office",
        "crypto order books", "air quality sensors", "court docket summaries",
        "grocery inventories", "marathon splits", "telescope observation logs",
        "podcast episode stats", "startup funding rounds", "energy futures",
    ]

    def intents(self) -> List[IntentSpec]:
        out = []
        i = 0
        for dom in self._DOMAINS:
            for verb in self._VERBS:
                kw = f"{verb} from {dom}"
                expr = {
                    "total": "a + b",
                    "difference": "a - b",
                    "ratio": "a / b",
                    "average": "(a + b) / 2",
                    "share": "a / (a + b)",
                }[verb]
                out.append(
                    _mk(
                        "gaia",
                        kw,
                        "Research task {tag}: using %s, determine the %s of the two "
                        "relevant quantities and answer numerically." % (dom, verb),
                        [["metric_alpha", "metric_beta"]],
                        expr,
                    )
                )
                i += 1
        return out  # 150 intents -> rarely recur within a 165-task run

    def entities(self) -> Dict[str, List[str]]:
        return {"tag": [f"G{i:04d}" for i in range(400)]}


ENVS = {
    "financebench": None,  # filled lazily below (avoid circular import)
    "tabmwp": TabMWPEnv,
    "qasper": QasperEnv,
    "aime": AimeEnv,
    "gaia": GaiaEnv,
}


def get_env(name: str) -> AgentEnv:
    if name == "financebench":
        from repro.envs.finance import FinanceEnv

        return FinanceEnv()
    cls = ENVS[name]
    return cls()


ALL_ENVS = ["financebench", "tabmwp", "qasper", "aime", "gaia"]


# -- seeded sim traffic (repro.sim) -----------------------------------------

SIM_SCENARIOS = ("skewed_reuse", "paraphrase_burst", "evict_then_hit", "uniform")


def _zipf_pick(rng: random.Random, n: int, s: float = 1.2) -> int:
    """Zipf-skewed index in [0, n): rank r with weight 1/(r+1)^s."""
    weights = [1.0 / (r + 1) ** s for r in range(n)]
    return rng.choices(range(n), weights=weights, k=1)[0]


def sim_traffic(
    scenario: str,
    seed: int,
    *,
    n_ops: int = 60,
    n_clients: int = 4,
    batch: int = 4,
    env: str = "tabmwp",
) -> List[List[Dict[str, Any]]]:
    """One seeded op stream per logical client for the ``repro.sim`` harness.

    Every op is a plain dict the harness applies against the store under
    test AND its sequential model, so generation must be fully determined
    by ``(scenario, seed, sizes)``:

    * ``{"op": "lookup", "kws": [...]}`` — one ``lookup_batch`` wave;
    * ``{"op": "insert", "kws": [...]}`` — one ``insert_batch`` admission
      wave (the harness assigns versioned payloads);
    * ``{"op": "remove", "kw": ...}`` / ``{"op": "autotune"}`` — sprinkled
      maintenance traffic;
    * ``{"op": "keys"}`` / ``{"op": "len"}`` — control-plane scans
      (``skewed_reuse`` only): they pay one interceptor RPC per shard and
      are checked against the model's reachable-key union, so a crashed or
      churned shard's visibility is oracle-verified too.

    Scenarios:

    * ``skewed_reuse`` — zipf-skewed draws over the env's recurring
      intents: a hot head that re-hits constantly plus a long cold tail.
    * ``paraphrase_burst`` — inserts a canonical keyword, then bursts
      lookups of its paraphrase variants (fuzzy/semantic tier pressure).
    * ``evict_then_hit`` — adversarial floods of one-shot keys that force
      eviction churn, interleaved with immediate lookups of the newest
      wave (catches evict-during-wave and index-desync bugs).
    * ``uniform`` — uniform reference traffic.
    """
    if scenario not in SIM_SCENARIOS:
        raise ValueError(f"unknown sim scenario {scenario!r}; one of {SIM_SCENARIOS}")
    rng = random.Random((seed, scenario).__repr__())
    intents = get_env(env).intents()
    kws = [it.keyword for it in intents]
    paras = {it.keyword: list(it.paraphrase_keywords) for it in intents}

    clients: List[List[Dict[str, Any]]] = [[] for _ in range(n_clients)]
    for ci in range(n_clients):
        ops = clients[ci]
        fresh = 0  # per-client unique-key counter (evict_then_hit floods)
        while len(ops) < n_ops:
            r = rng.random()
            if scenario == "skewed_reuse":
                wave = [kws[_zipf_pick(rng, len(kws))] for _ in range(batch)]
                if r < 0.30:
                    ops.append({"op": "insert", "kws": wave})
                elif r < 0.93:
                    ops.append({"op": "lookup", "kws": wave})
                elif r < 0.955:
                    ops.append({"op": "remove", "kw": wave[0]})
                elif r < 0.97:
                    ops.append({"op": "autotune"})
                elif r < 0.985:
                    ops.append({"op": "keys"})
                else:
                    ops.append({"op": "len"})
            elif scenario == "paraphrase_burst":
                canon = kws[_zipf_pick(rng, len(kws))]
                variants = paras.get(canon) or [canon]
                if r < 0.35:
                    ops.append({"op": "insert", "kws": [canon]})
                else:
                    burst = [rng.choice([canon] + variants) for _ in range(batch)]
                    ops.append({"op": "lookup", "kws": burst})
            elif scenario == "evict_then_hit":
                if r < 0.5:
                    flood = [f"c{ci}-one-shot-{fresh + j}" for j in range(batch)]
                    fresh += batch
                    # re-insert a (likely resident) hot key MID-wave: the
                    # case where evict-during-wave diverges from the
                    # evict-after-wave contract (the hot key can be chosen
                    # as victim before its own re-insert lands, costing an
                    # extra eviction that kills a key the policy says
                    # should survive)
                    hot = kws[_zipf_pick(rng, min(8, len(kws)))]
                    flood.insert(len(flood) // 2, hot)
                    ops.append({"op": "insert", "kws": flood})
                    # adversarial: immediately demand the newest wave back
                    ops.append({"op": "lookup", "kws": list(reversed(flood))})
                else:
                    hot = kws[_zipf_pick(rng, min(8, len(kws)))]
                    ops.append({"op": "insert" if r < 0.6 else "lookup",
                                "kws": [hot]})
            else:  # uniform
                wave = [rng.choice(kws) for _ in range(batch)]
                ops.append({"op": "insert" if r < 0.4 else "lookup", "kws": wave})
        del ops[n_ops:]  # evict_then_hit appends in pairs; trim to size
    return clients

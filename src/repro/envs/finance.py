"""FinanceBench-like env: long-context financial numeric reasoning.

~46 intents so that 200-query runs land near the paper's cache occupancy
(Table 7: 46 entries at the 100th percentile) and ~46-48% hit rate.
"""

from __future__ import annotations

from typing import Dict, List

from repro.envs.base import AgentEnv, IntentSpec

_COMPANIES = [
    "Costco", "Best Buy", "Walmart", "Target", "Kroger", "Home Depot",
    "Lowes", "Amazon", "Apple", "Microsoft", "Nvidia", "Intel", "AMD",
    "Oracle", "Salesforce", "Adobe", "Netflix", "Disney", "Comcast",
    "Verizon", "ATT", "TMobile", "Boeing", "Lockheed", "Caterpillar",
    "Deere", "3M", "GE", "Honeywell", "UPS", "FedEx", "Nike", "Starbucks",
    "McDonalds", "PepsiCo", "CocaCola",
]
_YEARS = [str(y) for y in range(2015, 2024)]

_RATIOS = [
    ("working capital ratio", ["total_current_assets", "total_current_liabilities"], "a / b"),
    ("quick ratio", ["quick_assets", "total_current_liabilities"], "a / b"),
    ("debt to equity ratio", ["total_debt", "shareholder_equity"], "a / b"),
    ("gross margin", ["gross_profit", "total_revenue"], "a / b"),
    ("operating margin", ["operating_income", "total_revenue"], "a / b"),
    ("net profit margin", ["net_income", "total_revenue"], "a / b"),
    ("asset turnover", ["total_revenue", "total_assets"], "a / b"),
    ("inventory turnover", ["cost_of_goods_sold", "average_inventory"], "a / b"),
    ("return on assets", ["net_income", "total_assets"], "a / b"),
    ("return on equity", ["net_income", "shareholder_equity"], "a / b"),
    ("current asset share", ["total_current_assets", "total_assets"], "a / b"),
    ("capex intensity", ["capital_expenditure", "total_revenue"], "a / b"),
    ("rnd intensity", ["research_and_development", "total_revenue"], "a / b"),
    ("sga ratio", ["selling_general_admin", "total_revenue"], "a / b"),
    ("interest coverage", ["operating_income", "interest_expense"], "a / b"),
    ("dividend payout ratio", ["dividends_paid", "net_income"], "a / b"),
    ("cash ratio", ["cash_and_equivalents", "total_current_liabilities"], "a / b"),
    ("goodwill share", ["goodwill", "total_assets"], "a / b"),
    ("effective tax rate", ["income_tax_expense", "pretax_income"], "a / b"),
    ("fcf margin", ["free_cash_flow", "total_revenue"], "a / b"),
]

_DELTAS = [
    ("revenue growth", ["total_revenue_y2", "total_revenue_y1"], "(a - b) / b"),
    ("net income growth", ["net_income_y2", "net_income_y1"], "(a - b) / b"),
    ("opex change", ["operating_expense_y2", "operating_expense_y1"], "a - b"),
    ("headcount change", ["employees_y2", "employees_y1"], "a - b"),
    ("eps growth", ["eps_y2", "eps_y1"], "(a - b) / b"),
    ("debt change", ["total_debt_y2", "total_debt_y1"], "a - b"),
    ("margin expansion", ["gross_margin_y2", "gross_margin_y1"], "a - b"),
    ("capex growth", ["capex_y2", "capex_y1"], "(a - b) / b"),
]

_TWO_ROUND = [
    ("dupont roe decomposition",
     [["net_income", "total_revenue"], ["total_assets", "shareholder_equity"]],
     "(a / b) * ((b / c) * (c / d)) * 0 + (a / d)"),
    ("working capital change",
     [["total_current_assets", "total_current_liabilities"],
      ["prior_current_assets", "prior_current_liabilities"]],
     "(a - b) - (c - d)"),
    ("net debt position",
     [["total_debt"], ["cash_and_equivalents", "short_term_investments"]],
     "a - (b + c)"),
    ("ebitda margin bridge",
     [["operating_income", "depreciation_amortization"], ["total_revenue"]],
     "(a + b) / c"),
    ("liquidity runway",
     [["cash_and_equivalents"], ["monthly_operating_expense"]],
     "a / b"),
    ("leverage headroom",
     [["total_debt", "ebitda"], ["covenant_max_leverage"]],
     "c - (a / b)"),
    ("fcf conversion",
     [["operating_cash_flow", "capital_expenditure"], ["net_income"]],
     "(a - b) / c"),
    ("buyback capacity",
     [["free_cash_flow", "dividends_paid"], ["authorized_buyback"]],
     "min(a - b, c)"),
    ("inventory days",
     [["average_inventory", "cost_of_goods_sold"]],
     "a / b * 365"),
    ("receivable days",
     [["accounts_receivable", "total_revenue"]],
     "a / b * 365"),
    ("payable days",
     [["accounts_payable", "cost_of_goods_sold"]],
     "a / b * 365"),
    ("cash conversion cycle",
     [["inventory_days_val", "receivable_days_val"], ["payable_days_val"]],
     "a + b - c"),
    ("altman z proxy",
     [["working_capital", "total_assets"], ["retained_earnings", "ebit"]],
     "1.2 * (a / b) + 1.4 * (c / b) + 3.3 * (d / b)"),
    ("piotroski cash component",
     [["operating_cash_flow", "total_assets"], ["net_income"]],
     "(a / b) - (c / b)"),
    ("gross profit per employee",
     [["gross_profit"], ["employees"]],
     "a / b"),
    ("revenue per store",
     [["total_revenue"], ["store_count"]],
     "a / b"),
    ("same store sales delta",
     [["same_store_sales_y2", "same_store_sales_y1"]],
     "(a - b) / b"),
    ("segment mix shift",
     [["segment_a_revenue", "total_revenue"], ["prior_segment_a_share"]],
     "(a / b) - c"),
]


# Long-tail metric-pair intents: FinanceBench's question space is wider than
# the named ratios above; these generated intents bring the distinct-keyword
# density to the paper's observed regime (~46% exact-match hit rate over 200
# queries; Table 4/7).
_TAIL_METRICS = [
    "deferred_revenue", "lease_liabilities", "pension_obligation",
    "stock_compensation", "marketing_spend", "fx_impact", "warranty_reserve",
    "restructuring_charge", "impairment_loss", "minority_interest",
    "treasury_stock", "unearned_premium", "loan_loss_provision",
    "net_interest_income", "trading_revenue", "fee_income", "fuel_cost",
    "labor_cost", "occupancy_cost", "royalty_income", "licensing_revenue",
    "subscription_revenue", "hardware_revenue", "services_revenue",
    "backlog_value", "bookings_total", "deferred_tax_asset",
    "contingent_liability", "legal_reserve", "environmental_reserve",
    "insurance_float", "reinsurance_recoverable", "catastrophe_loss",
    "premium_growth", "claims_ratio",
]


def _tail_intents() -> List[IntentSpec]:
    out = []
    ops = [("share of revenue", "a / b"), ("net of", "a - b")]
    for i, m in enumerate(_TAIL_METRICS):
        op_name, expr = ops[i % len(ops)]
        kw = f"{m.replace('_', ' ')} {op_name}"
        out.append(
            IntentSpec(
                id=f"fin-tail-{i}",
                keyword=kw,
                query_template=(
                    "For {company} in FY{year}: compute the %s using the "
                    "figures disclosed in the annual report." % kw
                ),
                rounds=[[m, "total_revenue" if expr == "a / b" else f"{m}_offset"]],
                expr=expr,
                paraphrase_keywords=(kw + " analysis",),
            )
        )
    # second tail family: yoy changes for the same metrics
    for i, m in enumerate(_TAIL_METRICS):
        kw = f"{m.replace('_', ' ')} yoy change"
        out.append(
            IntentSpec(
                id=f"fin-tailyoy-{i}",
                keyword=kw,
                query_template=(
                    "How did {company}'s %s change from the prior year to FY{year}?"
                    % m.replace("_", " ")
                ),
                rounds=[[f"{m}_y2", f"{m}_y1"]],
                expr="(a - b) / b",
                paraphrase_keywords=(kw + " trend",),
            )
        )
    return out


class FinanceEnv(AgentEnv):
    name = "financebench"
    context_tokens_range = (6_000, 11_000)  # long filings

    def intents(self) -> List[IntentSpec]:
        out = _tail_intents()
        for kw, fields, expr in _RATIOS:
            out.append(
                IntentSpec(
                    id=f"fin-{kw.replace(' ', '-')}",
                    keyword=kw,
                    query_template=(
                        "What is FY{year} %s for {company}? Round your answer to two "
                        "decimal places, relying on the statement of financial position." % kw
                    ),
                    rounds=[fields],
                    expr=expr,
                    paraphrase_keywords=(kw + " calculation", "compute " + kw),
                )
            )
        for kw, fields, expr in _DELTAS:
            out.append(
                IntentSpec(
                    id=f"fin-{kw.replace(' ', '-')}",
                    keyword=kw,
                    query_template=(
                        "By how much did {company}'s %s move between FY{year} and the prior "
                        "fiscal year, based on the annual report?" % kw
                    ),
                    rounds=[fields],
                    expr=expr,
                    paraphrase_keywords=(kw + " yoy", kw + " analysis"),
                )
            )
        for kw, rounds, expr in _TWO_ROUND:
            out.append(
                IntentSpec(
                    id=f"fin-{kw.replace(' ', '-')}",
                    keyword=kw,
                    query_template=(
                        "Derive the %s for {company} in FY{year} from its filings; show the "
                        "final number only." % kw
                    ),
                    rounds=rounds,
                    expr=expr,
                    paraphrase_keywords=(kw + " derivation",),
                )
            )
        return out

    def entities(self) -> Dict[str, List[str]]:
        return {"company": _COMPANIES, "year": _YEARS}

"""Training step: loss, grads, optimizer update — with optional
vocab-chunked cross-entropy (memory) and gradient accumulation.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_update


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, *, chunk_vocab: int = 0
) -> jnp.ndarray:
    """Mean token NLL. logits (B,S,V) any dtype; labels (B,S) int32.

    ``chunk_vocab`` > 0 computes logsumexp in vocab chunks to bound the fp32
    temp footprint (perf knob used by the hillclimb).
    """
    lg = logits.astype(jnp.float32)
    if chunk_vocab and logits.shape[-1] > chunk_vocab:
        V = logits.shape[-1]
        n = -(-V // chunk_vocab)
        m = jnp.full(lg.shape[:-1], -jnp.inf, jnp.float32)
        for i in range(n):
            m = jnp.maximum(m, jnp.max(lg[..., i * chunk_vocab : (i + 1) * chunk_vocab], -1))
        s = jnp.zeros(lg.shape[:-1], jnp.float32)
        for i in range(n):
            s = s + jnp.sum(
                jnp.exp(lg[..., i * chunk_vocab : (i + 1) * chunk_vocab] - m[..., None]), -1
            )
        lse = m + jnp.log(s)
    else:
        lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(cfg, params, batch: Dict[str, jnp.ndarray], ctx=None) -> Tuple[jnp.ndarray, Dict]:
    logits, aux, _ = lm.forward(cfg, params, batch, ctx)
    nll = cross_entropy(logits, batch["labels"])
    return nll + aux, {"nll": nll, "aux": aux}


def make_train_step(cfg, opt_cfg: Optional[AdamWConfig] = None, ctx=None, microbatch: int = 0):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            # gradient accumulation over microbatches via scan
            def split(x):
                b = x.shape[0] if x.ndim and x.shape[0] != 3 else None
                return x

            B = batch["labels"].shape[0]
            mb = B // microbatch

            def reshard(x):
                if x.ndim >= 1 and x.shape[0] == B:
                    return x.reshape(microbatch, mb, *x.shape[1:])
                if x.ndim == 3 and x.shape[0] == 3:  # vlm positions (3,B,S)
                    return x.reshape(3, microbatch, mb, x.shape[2]).transpose(1, 0, 2, 3)
                return jnp.broadcast_to(x, (microbatch,) + x.shape)

            mbatches = jax.tree.map(reshard, batch)

            def accum(carry, mb_batch):
                if "positions" in mb_batch and mb_batch["positions"].shape[0] == 3:
                    pass
                (l, m), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mb_batch, ctx), has_aux=True
                )(params)
                carry = jax.tree.map(lambda a, b: a + b, carry, g)
                return carry, l

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(accum, zero, mbatches)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = jnp.mean(losses)
            metrics = {"loss": loss}
        else:
            (loss, m), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, ctx), has_aux=True
            )(params)
            metrics = {"loss": loss, **m}
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step

"""Optimizers: AdamW (sharded states) and Adafactor-mini.

Pure-pytree implementation (no optax dependency): state is a pytree with the
same structure/sharding as the params, so FSDP sharding of optimizer state
falls out of ``param_pspecs`` for free (ZeRO-style).

``optimizer_dtype`` from the ShardingProfile controls m/v precision —
bf16 states for trillion-param MoE (kimi-k2) to fit v5e HBM.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"


def adamw_init(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = p.astype(jnp.float32) - cfg.lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor-mini: factored second moment (memory-lean alternative)
# ---------------------------------------------------------------------------


def adafactor_init(params: Any) -> Dict[str, Any]:
    def fac(p):
        if p.ndim >= 2:
            return {
                "r": jnp.zeros(p.shape[:-1], jnp.float32),
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "vs": jax.tree.map(fac, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, lr: float = 1e-3, eps: float = 1e-30):
    step = state["step"] + 1
    beta = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if p.ndim >= 2:
            r = beta * v["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
            c = beta * v["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = (
                r[..., None]
                * c[..., None, :]
                / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True)[..., None], eps)
            )
            upd_ = g * jax.lax.rsqrt(denom + eps)
            newv = {"r": r, "c": c}
        else:
            nv = beta * v["v"] + (1 - beta) * g2
            upd_ = g * jax.lax.rsqrt(nv + eps)
            newv = {"v": nv}
        newp = p.astype(jnp.float32) - lr * upd_
        return newp.astype(p.dtype), newv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    vs_list = treedef.flatten_up_to(state["vs"])
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, vs_list)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_vs = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_p, {"vs": new_vs, "step": step}

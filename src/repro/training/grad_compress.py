"""Gradient compression with error feedback (int8 quantized all-reduce).

At multi-pod scale the inter-pod (DCN) all-reduce of gradients dominates;
int8 block-quantization cuts those bytes 4x vs fp32 (2x vs bf16). Error
feedback accumulates the quantization residual locally and re-injects it
next step, preserving convergence (Seide et al.; Karimireddy et al.).

``compress -> (all-reduce int8 payload) -> decompress`` — here the
all-reduce itself is whatever the caller uses (psum inside pjit); we expose
quantize/dequantize + the EF state threading, and a convenience wrapper
``ef_allreduce`` for shard_map code.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (per-block scale)


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def quantize_int8(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Block-wise symmetric int8 quantization. Returns payload pytree."""
    flat, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32), "pad": pad, "shape": x.shape}


def dequantize_int8(payload: Dict[str, jnp.ndarray], dtype=jnp.float32) -> jnp.ndarray:
    deq = payload["q"].astype(jnp.float32) * payload["scale"]
    flat = deq.reshape(-1)
    n = 1
    for d in payload["shape"]:
        n *= d
    return flat[:n].reshape(payload["shape"]).astype(dtype)


def compress_with_ef(
    grad: jnp.ndarray, ef: Optional[jnp.ndarray]
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Returns (payload to reduce, new error-feedback residual)."""
    g = grad.astype(jnp.float32)
    if ef is not None:
        g = g + ef
    payload = quantize_int8(g)
    recon = dequantize_int8(payload)
    return payload, (g - recon)


def ef_state_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_allreduce(
    grads: Any, ef_state: Any, axis_name: str
) -> Tuple[Any, Any]:
    """shard_map-side: int8-quantize (+EF), psum the int payload, dequantize.

    A SHARED per-block scale (pmax over the axis) makes the int32 sum an
    exact fixed-point sum: err <= shared_scale/2 per element. The cheap
    pmax of scales (4 bytes/block) precedes the int8 psum (1 byte/elem) —
    ~3.8x fewer reduced bytes than fp32. Error feedback accumulates the
    local quantization residual for the next step.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, ef):
        gq = g.astype(jnp.float32) + (ef if ef is not None else 0.0)
        flat, pad = _pad_to_block(gq)
        blocks = flat.reshape(-1, BLOCK)
        local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jax.lax.pmax(jnp.maximum(local_scale, 1e-12), axis_name)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        new_ef = (blocks - q.astype(jnp.float32) * scale).reshape(-1)
        size = 1
        for d in g.shape:
            size *= d
        new_ef = new_ef[:size].reshape(g.shape)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq = qsum.astype(jnp.float32) * scale / n  # mean gradient
        out = deq.reshape(-1)[:size].reshape(g.shape).astype(g.dtype)
        return out, new_ef

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


def compression_ratio(x: jnp.ndarray) -> float:
    """bytes(int8+scales) / bytes(fp32)."""
    n = x.size
    blocks = -(-n // BLOCK)
    return (n * 1 + blocks * 4) / (n * 4)

"""Continuous-batching scheduler with straggler hedging.

Serving model: requests arrive asynchronously; the scheduler packs them into
fixed-size decode slots (continuous batching — a finished request's slot is
immediately re-assigned), and hedges stragglers: a request exceeding the
p95-deadline is duplicated onto a second replica and the first finisher wins
(standard tail-latency mitigation at scale; the duplicate's work is wasted
by design).

The scheduler is engine-agnostic: it drives any callable ``step(batch) ->
done_mask`` so tests can run it against a fake engine with a simulated clock.
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass(order=True)
class Request:
    arrival: float
    id: str = field(compare=False)
    prompt_tokens: int = field(compare=False, default=0)
    max_new: int = field(compare=False, default=32)
    tier: str = field(compare=False, default="actor")
    # runtime state
    generated: int = field(compare=False, default=0)
    started: Optional[float] = field(compare=False, default=None)
    finished: Optional[float] = field(compare=False, default=None)
    hedged: bool = field(compare=False, default=False)
    replica: int = field(compare=False, default=0)


@dataclass
class SchedulerConfig:
    max_batch: int = 8
    hedge_after_s: float = 5.0  # straggler deadline
    n_replicas: int = 2
    step_time_fn: Optional[Callable[[int], float]] = None  # batch -> seconds/step


class ContinuousBatcher:
    """Slot-based continuous batching over one engine tier."""

    def __init__(self, cfg: SchedulerConfig, clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.clock = clock or time.monotonic
        self.queue: List[Request] = []
        self.active: List[Request] = []
        self.done: List[Request] = []
        self.hedges = 0
        self.wasted_steps = 0

    def submit(self, req: Request) -> None:
        heapq.heappush(self.queue, req)

    def _fill_slots(self) -> None:
        while self.queue and len(self.active) < self.cfg.max_batch:
            r = heapq.heappop(self.queue)
            r.started = self.clock()
            self.active.append(r)

    def step(self) -> int:
        """One decode step across active slots; returns #completed."""
        self._fill_slots()
        if not self.active:
            return 0
        now = self.clock()
        # hedging: re-dispatch stragglers to another replica
        for r in self.active:
            if (
                not r.hedged
                and self.cfg.n_replicas > 1
                and r.started is not None
                and now - r.started > self.cfg.hedge_after_s
            ):
                r.hedged = True
                r.replica = (r.replica + 1) % self.cfg.n_replicas
                self.hedges += 1
                self.wasted_steps += r.generated  # first replica's work dropped
                r.generated = max(0, r.generated - 1)  # restart near the end
        completed = 0
        still: List[Request] = []
        for r in self.active:
            r.generated += 1
            if r.generated >= r.max_new:
                r.finished = self.clock()
                self.done.append(r)
                completed += 1
            else:
                still.append(r)
        self.active = still
        return completed

    def run_until_idle(self, max_steps: int = 100_000) -> Dict[str, float]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        lat = [
            (r.finished - r.arrival)
            for r in self.done
            if r.finished is not None and r.arrival is not None
        ]
        lat.sort()
        return {
            "completed": len(self.done),
            "steps": steps,
            "hedges": self.hedges,
            "wasted_steps": self.wasted_steps,
            "p50_s": lat[len(lat) // 2] if lat else 0.0,
            "p99_s": lat[int(len(lat) * 0.99)] if lat else 0.0,
        }

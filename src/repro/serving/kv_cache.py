"""Paged KV prefix cache keyed by plan template id.

The APC insight is that a plan-cache hit re-serves a *known prefix*: the
cached plan template is rendered verbatim ahead of the per-request
adaptation prompt. The serving engine therefore re-prefills the same
template tokens on every hit. This module keeps that prefix's KV around —
vLLM-style — in a shared refcounted page pool so a hit prefills only the
adaptation suffix:

  * :class:`PagePool` — per-layer K/V slabs of ``(num_pages, page_size,
    Hkv, hd)`` pages with refcounts and a free list. Device writes are
    donated jit scatters (the ``DeviceBank`` idiom) so slab updates don't
    double the pool's footprint.
  * :class:`KVPrefixCache` — template-id -> page-list map with LRU
    eviction on pool exhaustion, copy-on-write suffix extension
    (:meth:`KVPrefixCache.extend` shares full pages with the parent and
    copies only the partial tail page), and lease-based pinning so a
    prefix can't be evicted out from under an in-flight prefill.
  * :class:`CachePoint` / :func:`plan_cache_point` — the single cache
    point discipline: exactly one prefix/suffix split per request, placed
    after the template and before the adaptation prompt. Anything
    volatile ahead of the split would fork the KV and defeat sharing.

Lifecycle is tied to the plan cache: ``TwoTierRouter`` registers
:meth:`KVPrefixCache.release` as a ``PlanCache`` eviction listener, so a
template's pages are freed exactly when the template leaves the plan
cache — no second eviction policy to tune, no leaked pages.

Thread-safety: ``KVPrefixCache`` owns the lock; ``PagePool`` is not
independently thread-safe and must only be mutated by its owning cache
(or a single-threaded test). Recency is a monotonic integer sequence, not
wall-clock time, so eviction order is deterministic under repro.sim.
"""

from __future__ import annotations

import functools
import threading
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry
from repro.obs import names as _names


class PagePoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every idle (lease-free) prefix."""


def _donated(fn, *args):
    """Call a donating jit'd helper with the CPU donation notice silenced
    (CPU jax cannot honor donation and warns per call; see index/device.py)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return fn(*args)


@functools.partial(jax.jit, donate_argnums=(0,))
def _slab_write(slab, rows, data):
    """slab (L, N, ps, Hkv, hd); rows (n,) i32; data (L, n, ps, Hkv, hd)."""
    return slab.at[:, rows].set(data.astype(slab.dtype))


@jax.jit
def _slab_gather(slab, rows):
    """slab (L, N, ps, Hkv, hd); rows (n,) i32 -> (L, n, ps, Hkv, hd)."""
    return jnp.take(slab, rows, axis=1)


class PagePool:
    """Refcounted per-layer K/V page slabs shared by every cached prefix.

    One pool row = one page of ``page_size`` tokens across all layers.
    Refcounts make copy-on-write sharing safe: a row is recycled onto the
    free list only when its last owner (prefix entry or lease) releases
    it. NOT independently thread-safe — the owning :class:`KVPrefixCache`
    serializes access under its lock.
    """

    def __init__(
        self,
        num_layers: int,
        num_pages: int,
        page_size: int,
        num_kv_heads: int,
        head_dim: int,
        dtype=jnp.bfloat16,
    ):
        shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
        self._k = jnp.zeros(shape, dtype)
        self._v = jnp.zeros(shape, dtype)
        self.num_pages = num_pages
        self.page_size = page_size
        self.refcount = np.zeros((num_pages,), np.int32)
        # pop() from the tail allocates low rows first (stable test order)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` free rows at refcount 1."""
        if len(self._free) < n:
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free of {self.num_pages}"
            )
        rows = [self._free.pop() for _ in range(n)]
        for r in rows:
            self.refcount[r] = 1
        return rows

    def retain(self, rows: Sequence[int]) -> None:
        for r in rows:
            self.refcount[r] += 1

    def release(self, rows: Sequence[int]) -> None:
        for r in rows:
            self.refcount[r] -= 1
            assert self.refcount[r] >= 0, f"page {r} over-released"
            if self.refcount[r] == 0:
                self._free.append(r)

    def write(self, rows: Sequence[int], k_data, v_data) -> None:
        """Scatter page data into the slabs (donated: no transient copy)."""
        idx = jnp.asarray(list(rows), jnp.int32)
        self._k = _donated(_slab_write, self._k, idx, k_data)
        self._v = _donated(_slab_write, self._v, idx, v_data)

    def gather(self, rows: Sequence[int]):
        """-> (k, v) each (L, n, page_size, Hkv, hd)."""
        idx = jnp.asarray(list(rows), jnp.int32)
        return _slab_gather(self._k, idx), _slab_gather(self._v, idx)

    def kernel_view(self, layer: int):
        """The (N, page_size, Hkv, hd) slabs one layer of
        ``kernels.paged_attention`` streams through its page table."""
        return self._k[layer], self._v[layer]


def pool_for_config(cfg, *, num_pages: int = 64,
                    page_size: int = 16) -> PagePool:
    """Size a pool to a model config (dense-family cache geometry)."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.dtype(cfg.dtype)
    return PagePool(
        cfg.num_layers, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim,
        dtype=dt,
    )


@dataclass(frozen=True)
class PrefixLease:
    """A pinned view of one prefix: holds its own refcount on every page,
    so the prefix stays gatherable even if the entry is evicted mid-use."""

    template_id: str
    pages: Tuple[int, ...]
    length: int


@dataclass
class _Prefix:
    pages: List[int]
    length: int
    last_used: int
    leases: int = 0


class KVPrefixCache:
    """template_id -> prefix pages, with plan-cache-coupled lifecycle.

    ``put`` chops a prefix's per-layer K/V into pool pages; ``acquire`` +
    ``gather`` re-materialize it for a suffix-only prefill; ``extend``
    derives a child prefix copy-on-write; ``release`` (the plan-cache
    eviction listener) frees the pages when the template is evicted.

    Owns the lock for itself AND its pool: every pool mutation happens
    under ``self._lock``.
    """

    def __init__(
        self,
        pool: PagePool,
        *,
        obs: Optional[MetricsRegistry] = None,
        obs_labels: Optional[Dict[str, str]] = None,
    ):
        self.pool = pool
        self._lock = threading.Lock()
        self._entries: Dict[str, _Prefix] = {}
        self._seq = 0  # monotonic recency counter (deterministic LRU)
        self.obs = obs if obs is not None else MetricsRegistry()
        labels = dict(obs_labels or {})
        self._pages_hit = self.obs.counter(_names.KV_PAGES_HIT, **labels)
        self._pages_built = self.obs.counter(_names.KV_PAGES_BUILT, **labels)
        self._tokens_prefetched = self.obs.counter(
            _names.KV_TOKENS_PREFETCHED, **labels
        )
        self._prefix_evictions = self.obs.counter(
            _names.KV_PREFIX_EVICTIONS, **labels
        )

    # -- internals (call with the lock held) -------------------------------

    def _release_locked(self, template_id: str) -> None:
        entry = self._entries.pop(template_id)
        self.pool.release(entry.pages)
        self._prefix_evictions.inc()

    def _alloc_locked(self, n: int) -> List[int]:
        """Allocate ``n`` pages, LRU-evicting idle prefixes to make room."""
        if n > self.pool.num_pages:
            # unsatisfiable even by evicting everything: refuse up front
            # rather than destroy the whole cache before failing anyway
            raise PagePoolExhausted(
                f"need {n} pages but the pool holds only "
                f"{self.pool.num_pages} total"
            )
        while self.pool.free_pages < n:
            victim = None
            for tid, e in self._entries.items():
                if e.leases:
                    continue
                if victim is None or e.last_used < self._entries[victim].last_used:
                    victim = tid
            if victim is None:
                leased = sum(1 for e in self._entries.values() if e.leases)
                raise PagePoolExhausted(
                    f"need {n} pages, {self.pool.free_pages} free of "
                    f"{self.pool.num_pages}; no evictable prefix left "
                    f"({leased} leased, remaining pages pinned by "
                    f"outstanding leases or COW shares)"
                )
            self._release_locked(victim)
        return self.pool.alloc(n)

    def _paginate(self, k_prefix, v_prefix, length: int, n_pages: int):
        """(L, S, Hkv, hd) arrays -> (L, n_pages, ps, Hkv, hd) page data."""
        ps = self.pool.page_size
        L, _, H, hd = k_prefix.shape
        pad = n_pages * ps - length

        def chop(x):
            x = x[:, :length]
            if pad:
                x = jnp.concatenate(
                    [x, jnp.zeros((L, pad, H, hd), x.dtype)], axis=1
                )
            return x.reshape(L, n_pages, ps, H, hd)

        return chop(k_prefix), chop(v_prefix)

    # -- public API --------------------------------------------------------

    def put(self, template_id: str, k_prefix, v_prefix, *,
            length: Optional[int] = None) -> int:
        """Store a template prefix. k/v: (L, S, Hkv, hd) post-RoPE cache
        rows; ``length`` valid tokens (default S). Returns pages used."""
        S = int(k_prefix.shape[1])
        length = S if length is None else int(length)
        assert 0 < length <= S, (length, S)
        ps = self.pool.page_size
        n = -(-length // ps)
        with self._lock:
            if template_id in self._entries:
                if self._entries[template_id].leases:
                    raise PagePoolExhausted(
                        f"prefix {template_id!r} is leased; cannot replace"
                    )
                self._release_locked(template_id)
            rows = self._alloc_locked(n)
            kp, vp = self._paginate(k_prefix, v_prefix, length, n)
            self.pool.write(rows, kp, vp)
            self._seq += 1
            self._entries[template_id] = _Prefix(rows, length, self._seq)
            self._pages_built.inc(n)
        return n

    def acquire(self, template_id: str) -> Optional[PrefixLease]:
        """Pin a prefix for use; None on miss. Pair with release_lease."""
        with self._lock:
            entry = self._entries.get(template_id)
            if entry is None:
                return None
            entry.leases += 1
            self._seq += 1
            entry.last_used = self._seq
            self.pool.retain(entry.pages)
            self._pages_hit.inc(len(entry.pages))
            return PrefixLease(template_id, tuple(entry.pages), entry.length)

    def gather(self, lease: PrefixLease, *, batch: int = 1):
        """-> (k, v, length): (L, B, Sp, Hkv, hd) dense prefix views
        (Sp = pages * page_size >= length; positions past length are the
        zero padding the extend mask discards)."""
        with self._lock:
            kg, vg = self.pool.gather(lease.pages)
            self._tokens_prefetched.inc(batch * lease.length)
        L, n, ps, H, hd = kg.shape
        k = jnp.broadcast_to(kg.reshape(L, 1, n * ps, H, hd),
                             (L, batch, n * ps, H, hd))
        v = jnp.broadcast_to(vg.reshape(L, 1, n * ps, H, hd),
                             (L, batch, n * ps, H, hd))
        return k, v, lease.length

    def release_lease(self, lease: PrefixLease) -> None:
        with self._lock:
            self.pool.release(lease.pages)
            entry = self._entries.get(lease.template_id)
            if entry is not None and entry.leases > 0:
                entry.leases -= 1

    def page_table(self, leases: Sequence[PrefixLease]):
        """Batch leases into the paged-attention calling convention:
        -> (page_table (B, P) i32 with -1 past each prefix's last page,
        lengths (B,) i32)."""
        P = max(len(l.pages) for l in leases)
        table = np.full((len(leases), P), -1, np.int32)
        for i, l in enumerate(leases):
            table[i, : len(l.pages)] = l.pages
        lengths = np.asarray([l.length for l in leases], np.int32)
        return jnp.asarray(table), jnp.asarray(lengths)

    def extend(self, parent_id: str, child_id: str, k_suffix, v_suffix,
               *, length: Optional[int] = None) -> int:
        """Copy-on-write suffix extension: the child shares every FULL
        parent page (refcount bump, no copy) and copies only the parent's
        partial tail page before appending the suffix K/V.

        k/v_suffix: (L, S, Hkv, hd); ``length`` valid suffix tokens
        (default S). Returns the number of NEW pages written."""
        S = int(k_suffix.shape[1])
        length = S if length is None else int(length)
        assert 0 < length <= S, (length, S)
        ps = self.pool.page_size
        with self._lock:
            parent = self._entries.get(parent_id)
            if parent is None:
                raise KeyError(f"unknown parent prefix {parent_id!r}")
            # Pin the parent for the duration: with leases == 0 it would be
            # a legal victim for _alloc_locked's LRU sweep, whose eviction
            # would free the parent's pages and let the child's new rows be
            # carved out of them — retain(shared) below would then re-pin
            # freed/overwritten rows and the child would silently hold
            # corrupted KV. (This also makes a child_id == parent_id
            # replace fail loudly instead of freeing the pages mid-read.)
            parent.leases += 1
            try:
                if child_id in self._entries:
                    if self._entries[child_id].leases:
                        raise PagePoolExhausted(
                            f"prefix {child_id!r} is leased; cannot replace"
                        )
                    self._release_locked(child_id)
                n_full, tail = divmod(parent.length, ps)
                new_len = parent.length + length
                n_new = -(-new_len // ps) - n_full
                shared = list(parent.pages[:n_full])
                rows = self._alloc_locked(n_new)
                # tail-page data precedes the suffix in the first new page
                if tail:
                    tk, tv = self.pool.gather(parent.pages[n_full : n_full + 1])
                    tk, tv = tk[:, 0, :tail], tv[:, 0, :tail]  # (L, tail, H, hd)
                    k_data = jnp.concatenate([tk.astype(k_suffix.dtype),
                                              k_suffix[:, :length]], axis=1)
                    v_data = jnp.concatenate([tv.astype(v_suffix.dtype),
                                              v_suffix[:, :length]], axis=1)
                else:
                    k_data, v_data = k_suffix[:, :length], v_suffix[:, :length]
                kp, vp = self._paginate(k_data, v_data, tail + length, n_new)
                self.pool.write(rows, kp, vp)
                self.pool.retain(shared)
                self._seq += 1
                self._entries[child_id] = _Prefix(shared + rows, new_len,
                                                  self._seq)
                self._pages_built.inc(n_new)
            finally:
                parent.leases -= 1
        return n_new

    def release(self, template_id: str) -> bool:
        """Free a prefix's pages (refcount-decrement; COW children and
        outstanding leases keep shared rows alive). This is the plan-cache
        eviction listener: wired via ``PlanCache.add_evict_listener``, it
        runs for every hot-tier delete, so unknown ids are a no-op."""
        with self._lock:
            if template_id not in self._entries:
                return False
            self._release_locked(template_id)
            return True

    def clear(self) -> None:
        with self._lock:
            for tid in list(self._entries):
                self._release_locked(tid)

    def __contains__(self, template_id: str) -> bool:
        with self._lock:
            return template_id in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def length_of(self, template_id: str) -> Optional[int]:
        with self._lock:
            entry = self._entries.get(template_id)
            return None if entry is None else entry.length


# ---------------------------------------------------------------------------
# The single cache point
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CachePoint:
    """One prefix/suffix split for a request batch: the first
    ``prefix_len`` prompt columns are the plan template (shared KV, keyed
    ``template_id``); everything after is the per-request adaptation
    prompt (fresh prefill). Exactly one cache point per request — a
    second split, or anything volatile ahead of this one, would fork the
    shared prefix and defeat caching."""

    template_id: str
    prefix_len: int


def plan_cache_point(template_id: str, template_tokens,
                     prompt_tokens) -> Optional[CachePoint]:
    """Place the single cache point after the template and before the
    adaptation prompt. Returns None when the placement is unsafe: the
    prompt doesn't literally start with the template tokens (on every
    batch row), or there is no adaptation suffix left to prefill."""
    t = np.asarray(template_tokens).reshape(-1)
    p = np.atleast_2d(np.asarray(prompt_tokens))
    if t.size == 0 or t.size >= p.shape[1]:
        return None
    if not np.array_equal(p[:, : t.size],
                          np.broadcast_to(t, (p.shape[0], t.size))):
        return None
    return CachePoint(template_id=template_id, prefix_len=int(t.size))


__all__ = [
    "CachePoint",
    "KVPrefixCache",
    "PagePool",
    "PagePoolExhausted",
    "PrefixLease",
    "plan_cache_point",
    "pool_for_config",
]

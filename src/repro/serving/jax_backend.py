"""JaxBackend: the APC control plane driving real JAX model engines.

Semantics (which plan/keyword/answer is produced) come from the simulated
behavioral layer — random-weight models emit no usable text — while every
control-plane LM call is *executed* on the data plane with a token count
matching the call (prefill prompt tokens, decode output tokens). This is the
standard synthetic-workload methodology: real compute, synthetic content.
Measured engine rates feed the cost model, replacing the remote-API latency
defaults.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.backends import SimulatedBackend
from repro.data.tokenizer import HashTokenizer
from repro.envs.base import Task
from repro.serving.engine import Engine


class JaxBackend(SimulatedBackend):
    """SimulatedBackend + real data-plane execution per role."""

    def __init__(self, engines: Dict[str, Engine], *, max_exec_tokens: int = 32, **kw):
        super().__init__(**kw)
        self.engines = engines
        self.tok = HashTokenizer()
        self.max_exec = max_exec_tokens

    def _exec(self, role: str, prompt_text: str, out_tokens: int) -> None:
        eng = self.engines.get(role)
        if eng is None:
            return
        ids = self.tok.encode(prompt_text)[: eng.max_len - self.max_exec - 8]
        if not ids:
            ids = [1]
        arr = np.asarray([ids], np.int32)
        eng.generate(arr, max_new=min(out_tokens, self.max_exec))

    # -- overridden role calls (same returns, + real execution) ----------

    def extract_keyword(self, task: Task):
        kw, i, o = super().extract_keyword(task)
        self._exec("keyword_extractor", task.query, o)
        return kw, i, o

    def plan(self, task: Task, responses, *, large: bool, round_idx: int):
        msg, i, o = super().plan(task, responses, large=large, round_idx=round_idx)
        role = "large_planner" if large else "small_planner"
        self._exec(role, task.query + " " + str(responses)[-512:], o)
        return msg, i, o

    def adapt(self, task: Task, template, responses, *, round_idx: int,
              full_history: bool = False):
        msg, i, o = super().adapt(
            task, template, responses, round_idx=round_idx, full_history=full_history
        )
        self._exec("small_planner", task.query, o)
        return msg, i, o

    def act(self, task: Task, plan):
        resp, i, o = super().act(task, plan)
        self._exec("actor", plan.text, o)
        return resp, i, o

    def measured_rates(self) -> Dict[str, Dict[str, float]]:
        return {
            role: eng.measured_rates() for role, eng in self.engines.items()
        }

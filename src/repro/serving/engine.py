"""Serving engine: jitted prefill + decode with donated KV buffers.

One Engine instance = one model deployment (a planner tier or the actor
pool). The engine exposes:

  * ``generate(tokens, max_new)`` — batched greedy/temperature generation
  * ``measured_rates()`` — tokens/s observed, fed into the APC cost model so
    control-plane latency numbers come from the actual data plane

On CPU this runs the reduced configs; on TPU the same code runs the full
configs under the production mesh (in_shardings from distributed/sharding).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShardingProfile
from repro.distributed import sharding as shd
from repro.models import lm
from repro.obs import trace_span
from repro.obs.names import SPAN_ENGINE_GENERATE
from repro.serving.sampler import sample_token


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    def rates(self) -> Dict[str, float]:
        return {
            "prefill": self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0,
            "decode": self.decode_tokens / self.decode_s if self.decode_s else 0.0,
        }


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        mesh=None,
        profile: Optional[ShardingProfile] = None,
        max_len: int = 512,
        donate_cache: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self.stats = EngineStats()
        ctx = None
        if mesh is not None:
            profile = profile or ShardingProfile()
            ctx = lm.ParallelCtx(
                mesh=mesh,
                dp_axes=shd.dp_axes_for_mesh(mesh),
                tp_axis=profile.tp_axis,
                ep_axis=profile.ep_axis,
            )
        self.ctx = ctx

        def prefill_fn(params, batch):
            logits, cache = lm.prefill(cfg, params, batch, ctx, cache_len=max_len)
            return logits[:, -1], cache

        def decode_fn(params, cache, tokens):
            logits, cache = lm.decode_step(cfg, params, cache, tokens, ctx)
            return logits[:, -1], cache

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn, donate_argnums=donate)

    # ------------------------------------------------------------------

    def prefill(self, tokens: np.ndarray) -> Tuple[np.ndarray, Any]:
        """tokens: (B, S) int32 -> (last logits (B, V), cache)."""
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        logits.block_until_ready()
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += int(tokens.size)
        return np.asarray(logits), cache

    def decode(self, cache: Any, tokens: np.ndarray) -> Tuple[np.ndarray, Any]:
        t0 = time.perf_counter()
        logits, cache = self._decode(self.params, cache, jnp.asarray(tokens))
        logits.block_until_ready()
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decode_tokens += int(tokens.shape[0])
        return np.asarray(logits), cache

    def generate(
        self,
        tokens: np.ndarray,
        max_new: int = 32,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        eos_id: Optional[int] = None,
    ) -> np.ndarray:
        """Batched generation. Returns (B, <=max_new) generated ids."""
        B, S = tokens.shape
        assert S + max_new <= self.max_len + 8, "increase engine max_len"
        with trace_span(SPAN_ENGINE_GENERATE, batch=B, prompt_len=S,
                        max_new=max_new) as sp:
            logits, cache = self.prefill(tokens)
            out = []
            key = jax.random.PRNGKey(seed)
            tok = sample_token(logits, temperature, key)
            done = np.zeros((B,), bool)
            for i in range(max_new):
                out.append(tok)
                if eos_id is not None:
                    done |= tok[:, 0] == eos_id
                    if done.all():
                        break
                logits, cache = self.decode(cache, tok)
                key, sub = jax.random.split(key)
                tok = sample_token(logits, temperature, sub)
            sp.set(new_tokens=len(out))
            return np.concatenate(out, axis=1)

    def measured_rates(self) -> Dict[str, float]:
        r = self.stats.rates()
        r["rtt"] = 0.0  # local serving: no API round-trip
        return r

"""Serving engine: jitted prefill + decode with donated KV buffers.

One Engine instance = one model deployment (a planner tier or the actor
pool). The engine exposes:

  * ``generate(tokens, max_new)`` — batched greedy/temperature generation
  * ``prefill_with_prefix(template_id, suffix)`` — suffix-only prefill
    against a template prefix held in the paged KV pool
    (``serving/kv_cache.py``): a plan-cache hit re-serves a known prefix,
    so only the adaptation prompt pays prefill compute
  * ``measured_rates()`` — tokens/s observed, fed into the APC cost model so
    control-plane latency numbers come from the actual data plane

On CPU this runs the reduced configs; on TPU the same code runs the full
configs under the production mesh (in_shardings from distributed/sharding).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShardingProfile
from repro.distributed import sharding as shd
from repro.models import lm
from repro.obs import trace_span
from repro.obs.names import SPAN_ENGINE_GENERATE
from repro.serving.kv_cache import CachePoint, KVPrefixCache, PagePoolExhausted
from repro.serving.sampler import sample_token

# families whose cache is pure KV (no recurrent state): the only ones a
# stored prefix can be re-entered into mid-stream
_PREFIX_FAMILIES = ("dense", "moe", "vlm")


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefix_tokens_reused: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    def rates(self) -> Dict[str, float]:
        return {
            "prefill": self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0,
            "decode": self.decode_tokens / self.decode_s if self.decode_s else 0.0,
        }


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        mesh=None,
        profile: Optional[ShardingProfile] = None,
        max_len: int = 512,
        donate_cache: bool = True,
        kv_prefix: Optional[KVPrefixCache] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self.stats = EngineStats()
        self.kv_prefix = kv_prefix if cfg.family in _PREFIX_FAMILIES else None
        ctx = None
        if mesh is not None:
            profile = profile or ShardingProfile()
            ctx = lm.ParallelCtx(
                mesh=mesh,
                dp_axes=shd.dp_axes_for_mesh(mesh),
                tp_axis=profile.tp_axis,
                ep_axis=profile.ep_axis,
            )
        self.ctx = ctx

        def prefill_fn(params, batch):
            logits, cache = lm.prefill(cfg, params, batch, ctx, cache_len=max_len)
            return logits[:, -1], cache

        def decode_fn(params, cache, tokens):
            logits, cache = lm.decode_step(cfg, params, cache, tokens, ctx)
            return logits[:, -1], cache

        def extend_fn(params, prefix_k, prefix_v, batch, *, prefix_len):
            logits, cache = lm.prefill_extend(
                cfg, params, batch, prefix_k, prefix_v, prefix_len, ctx,
                cache_len=max_len,
            )
            return logits[:, -1], cache

        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn, donate_argnums=donate)
        self._extend = jax.jit(extend_fn, static_argnames=("prefix_len",))

    # ------------------------------------------------------------------

    def prefill(self, tokens: np.ndarray, *,
                n_valid: Optional[int] = None) -> Tuple[np.ndarray, Any]:
        """tokens: (B, S) int32 -> (last logits (B, V), cache).

        ``n_valid`` is the number of REAL tokens in the batch; without it
        every element counts, padding included — callers that right-pad
        ragged prompts should pass the true count or the prefill tokens/s
        rate (and the APC cost model downstream of it) reads high.
        """
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        logits.block_until_ready()
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += int(tokens.size if n_valid is None else n_valid)
        return np.asarray(logits), cache

    def decode(self, cache: Any, tokens: np.ndarray, *,
               active: Optional[int] = None) -> Tuple[np.ndarray, Any]:
        """One decode step. ``active`` counts the rows still generating;
        finished (post-EOS) rows ride along in the dense batch but must
        not inflate the decode tokens/s rate."""
        t0 = time.perf_counter()
        logits, cache = self._decode(self.params, cache, jnp.asarray(tokens))
        logits.block_until_ready()
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decode_tokens += int(
            tokens.shape[0] if active is None else active
        )
        return np.asarray(logits), cache

    # -- paged KV prefix path ------------------------------------------

    def register_prefix(self, template_id: str, cache: Any,
                        prefix_len: int) -> bool:
        """Distill the first ``prefix_len`` cached positions into the page
        pool under ``template_id`` (batch row 0 — the template prefix is
        identical across rows by construction). Call right after the full
        prefill that built ``cache``, before decode donates its buffers."""
        if self.kv_prefix is None or "kv_k" not in cache:
            return False
        k = cache["kv_k"][:, 0]  # (L, M, Hkv, hd)
        v = cache["kv_v"][:, 0]
        try:
            self.kv_prefix.put(template_id, k, v, length=prefix_len)
        except PagePoolExhausted:
            # registration is best-effort: the full prefill already
            # served this request; a pool too small (or a still-leased
            # stale entry) just means the next hit pays prefill again
            return False
        return True

    def prefill_with_prefix(
        self, template_id: str, suffix_tokens: np.ndarray,
        *, n_valid: Optional[int] = None,
        expected_len: Optional[int] = None,
    ) -> Optional[Tuple[np.ndarray, Any]]:
        """Prefill only the adaptation suffix; the template prefix K/V is
        gathered from the page pool. Returns None when the prefix isn't
        cached (caller falls back to a full prefill + register_prefix).

        ``expected_len`` is the prefix length the caller split the prompt
        at (the cache point). The pooled prefix MUST be exactly that long
        — the extend kernel derives RoPE positions and the attention mask
        from it — so a mismatched entry (stale registration, re-tokenized
        template) is treated as a miss, never served.
        """
        if self.kv_prefix is None:
            return None
        lease = self.kv_prefix.acquire(template_id)
        if lease is None:
            return None
        if expected_len is not None and lease.length != expected_len:
            # wrong-length prefix: serving it would silently shift every
            # suffix position; fall back so the caller re-registers
            self.kv_prefix.release_lease(lease)
            return None
        try:
            B, S = suffix_tokens.shape
            pk, pv, plen = self.kv_prefix.gather(lease, batch=B)
            t0 = time.perf_counter()
            logits, cache = self._extend(
                self.params, pk, pv,
                {"tokens": jnp.asarray(suffix_tokens)}, prefix_len=plen,
            )
            logits.block_until_ready()
            self.stats.prefill_s += time.perf_counter() - t0
            self.stats.prefill_tokens += int(
                suffix_tokens.size if n_valid is None else n_valid
            )
            self.stats.prefix_tokens_reused += B * plen
            return np.asarray(logits), cache
        finally:
            self.kv_prefix.release_lease(lease)

    # ------------------------------------------------------------------

    def generate(
        self,
        tokens: np.ndarray,
        max_new: int = 32,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        eos_id: Optional[int] = None,
        pad_id: int = 0,
        prompt_lengths: Optional[np.ndarray] = None,
        cache_point: Optional[CachePoint] = None,
    ) -> np.ndarray:
        """Batched generation. Returns (B, <=max_new) generated ids.

        Rows that hit ``eos_id`` emit ``pad_id`` from the next step on and
        stop counting toward decode throughput. ``prompt_lengths`` ((B,)
        valid prompt token counts) keeps right-padding out of the prefill
        rate. ``cache_point`` routes the prefill through the paged KV
        prefix cache: suffix-only prefill on a pool hit, full prefill +
        prefix registration on a pool miss.
        """
        B, S = tokens.shape
        if S + max_new > self.max_len:
            raise ValueError(
                f"prompt ({S}) + max_new ({max_new}) exceeds the engine's "
                f"KV capacity (max_len={self.max_len}); decode would write "
                f"past the cache"
            )
        n_valid = None if prompt_lengths is None else int(np.sum(prompt_lengths))
        with trace_span(SPAN_ENGINE_GENERATE, batch=B, prompt_len=S,
                        max_new=max_new) as sp:
            res = None
            if cache_point is not None and self.kv_prefix is not None:
                suffix = tokens[:, cache_point.prefix_len:]
                n_suf = (None if n_valid is None
                         else n_valid - B * cache_point.prefix_len)
                res = self.prefill_with_prefix(
                    cache_point.template_id, suffix, n_valid=n_suf,
                    expected_len=cache_point.prefix_len,
                )
            if res is None:
                res = self.prefill(tokens, n_valid=n_valid)
                if cache_point is not None and self.kv_prefix is not None:
                    self.register_prefix(
                        cache_point.template_id, res[1], cache_point.prefix_len
                    )
            logits, cache = res
            out = []
            key = jax.random.PRNGKey(seed)
            tok = sample_token(logits, temperature, key)
            done = np.zeros((B,), bool)
            for i in range(max_new):
                if eos_id is not None and done.any():
                    # finished rows keep a slot in the dense batch but must
                    # emit padding, not whatever the sampler drew for them
                    tok = np.where(done[:, None], pad_id, tok).astype(tok.dtype)
                out.append(tok)
                if eos_id is not None:
                    done |= tok[:, 0] == eos_id
                    if done.all():
                        break
                if i + 1 == max_new:
                    break  # the last token is emitted; skip the wasted decode
                logits, cache = self.decode(
                    cache, tok, active=int(B - done.sum())
                )
                key, sub = jax.random.split(key)
                tok = sample_token(logits, temperature, sub)
            sp.set(new_tokens=len(out))
            return np.concatenate(out, axis=1)

    def measured_rates(self) -> Dict[str, float]:
        r = self.stats.rates()
        r["rtt"] = 0.0  # local serving: no API round-trip
        return r

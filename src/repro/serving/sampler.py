"""Token sampling: greedy / temperature / top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_token(
    logits: np.ndarray, temperature: float = 0.0, key=None, top_k: int = 0
) -> np.ndarray:
    """logits: (B, V) -> (B, 1) int32."""
    lg = jnp.asarray(logits, jnp.float32)
    if temperature <= 0.0:
        tok = jnp.argmax(lg, axis=-1)
    else:
        lg = lg / temperature
        if top_k:
            kth = jnp.sort(lg, axis=-1)[:, -top_k][:, None]
            lg = jnp.where(lg < kth, -jnp.inf, lg)
        key = key if key is not None else jax.random.PRNGKey(0)
        tok = jax.random.categorical(key, lg, axis=-1)
    return np.asarray(tok[:, None].astype(jnp.int32))

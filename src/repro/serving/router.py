"""Two-tier serving router — the APC cache as a *routing policy*.

This is the system-level embodiment of the paper: a cache hit routes the
planning request to the cheap tier (small planner pool) and skips the
expensive tier entirely; a miss goes to the large planner pool, and the
completed execution is distilled into the plan cache (optionally async so
cache generation never blocks the response path — the paper lists this as
future work in §4.3; implemented here).

The router is deployment-scale aware: the plan cache is any
``repro.memory.protocol.PlanStore`` — a local PlanCache or a
DistributedPlanCache (consistent-hash sharded across serving frontends) —
consumed through the protocol's batch primitives (no ``hasattr``
capability probing), and each tier is a pool of engines with hedged
dispatch.
``route_batch`` admits a whole arrival wave through a single
``lookup_batch`` pass — with a ``device``-backend fuzzy cache that is one
resident-bank device call for the entire batch — and distills the wave's
misses back into the cache through one ``insert_batch`` (one donated
multi-slot device scatter) rather than one insert per request.

Thread-safety contract: the router itself holds ``self._lock`` only around
the ``_pending`` futures list. Cache reads/writes need no router-side lock
— PlanCache/DistributedPlanCache serialize internally (their RLock nests
the embedding bank's lock, so host arena, LSH buckets, and device arena
mutate atomically). ``route``/``route_batch`` may be called concurrently
from many request threads while async cache-generation workers insert;
``RouterMetrics`` counters are lock-safe ``repro.obs`` registry counters
(the historical bare-int struct raced: ``async_cachegens`` /
``cachegen_dropped`` / ``sync_cachegen_fallbacks`` were ``+=``'d while
``route_batch`` mutated the same fields from request threads).

Observability: with a tracer installed (``repro.obs.use_tracer``) every
``route``/``route_batch`` opens a span tree — router → cache lookup →
per-shard/per-tier fan-out → match-pipeline stage → index backend — and
emits one ``cache.attribution`` event per request (hit tier, matched
stage/key, §4.4 ``tokens_saved``) plus a ``cachegen.fate`` event per
admission wave (async | sync_fallback | dropped).
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.cache import PlanCache
from repro.core.speculative import PlanSpeculator
from repro.obs import (
    MetricsRegistry,
    collect,
    current_span,
    get_tracer,
    tokens_saved_estimate,
    trace_span,
)
from repro.obs import names as _names


@dataclass
class TierPool:
    """A pool of interchangeable engine replicas for one role."""

    name: str
    replicas: List[Any] = field(default_factory=list)
    _rr: int = 0
    hedge_timeout_s: float = 30.0
    # GUARD — hedged failover: a hedged dispatch serves the first
    # SUCCESSFUL replica and only raises when every replica failed, so one
    # timed-out/crashed engine never surfaces to the request. False is the
    # repro.sim ablation: the dispatch goes to a single replica and its
    # timeout propagates (the dropped-response bug the completeness oracle
    # catches).
    hedge_failover: bool = True
    _executor: Optional[cf.ThreadPoolExecutor] = field(
        default=None, repr=False, compare=False
    )
    _executor_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def pick(self) -> Any:
        # return-then-increment so the rotation starts at replica 0 and
        # visits every replica (increment-first skipped slot 0 forever).
        # Locked: concurrent dispatches racing the read-increment would
        # hand the same replica to both and skip another entirely.
        with self._executor_lock:
            eng = self.replicas[self._rr % len(self.replicas)]
            self._rr = (self._rr + 1) % len(self.replicas)
        return eng

    def dispatch(self, fn: Callable[[Any], Any], *, hedge: bool = False) -> Any:
        """Run fn(engine); optionally hedge onto a second replica.

        Hedged calls share ONE executor per pool (lazily created) instead
        of paying thread-pool construction + teardown per request. The
        winner is the first replica to SUCCEED (completion order), not an
        arbitrary member of the first-completed set — a replica that fails
        fast must not beat one that succeeds slowly."""
        if not hedge or len(self.replicas) < 2 or not self.hedge_failover:
            return fn(self.pick())
        if self._executor is None:
            # locked lazy init: concurrent first dispatches must not each
            # build an executor (the loser's threads would leak). Sized
            # above 2 because a hedge loser that is already running cannot
            # be cancelled and holds its worker until it finishes — a hard
            # cap of 2 would let one straggler serialize (or block) every
            # later hedged dispatch on this pool.
            with self._executor_lock:
                if self._executor is None:
                    self._executor = cf.ThreadPoolExecutor(
                        max_workers=max(4, 2 * len(self.replicas)),
                        thread_name_prefix=f"tier-{self.name}",
                    )
        futs = [self._executor.submit(fn, self.pick()) for _ in range(2)]
        last_err: Optional[BaseException] = None
        try:
            for f in cf.as_completed(futs, timeout=self.hedge_timeout_s):
                try:
                    result = f.result()
                except Exception as e:  # noqa: BLE001 - replica failure
                    last_err = e
                    continue
                for other in futs:
                    if other is not f:
                        other.cancel()
                return result
        except cf.TimeoutError as e:
            # reclaim what can be reclaimed: queued-but-unstarted calls are
            # cancelled so a hung replica can't brick the pool by pinning
            # every worker (a RUNNING call is uncancellable and holds its
            # worker until it returns — that is why the executor is sized
            # above 2x the hedge width)
            for f in futs:
                f.cancel()
            last_err = TimeoutError(
                f"hedged dispatch on pool {self.name!r} exceeded "
                f"{self.hedge_timeout_s}s on every replica"
            )
            last_err.__cause__ = e
        assert last_err is not None
        raise last_err

    def close(self) -> None:
        with self._executor_lock:
            if self._executor is not None:
                self._executor.shutdown(wait=True)
                self._executor = None


def _metric_prop(field: str):
    def get(self):
        v = self._c[field].value
        return v if field == "lookup_s" else int(v)

    return property(get)


class RouterMetrics:
    """Router accounting as a view over a ``repro.obs`` registry.

    Every counter is a lock-safe :class:`repro.obs.Counter` — the fix for
    the historical data race where cachegen bookkeeping was ``+=``'d from
    pool threads against ``route_batch``'s request-thread increments. The
    historical field reads (``m.hits``) and the ``snapshot()`` schema are
    unchanged; writers go through :meth:`add`. ``lookup_latency`` is a
    bucketed histogram feeding the p50/p99 columns in BENCH_t3/BENCH_s1.
    """

    _FIELDS = {
        "requests": _names.ROUTER_REQUESTS,
        "hits": _names.ROUTER_HITS,
        "misses": _names.ROUTER_MISSES,
        "large_tier_calls": _names.ROUTER_LARGE_TIER_CALLS,
        "small_tier_calls": _names.ROUTER_SMALL_TIER_CALLS,
        "async_cachegens": _names.ROUTER_ASYNC_CACHEGENS,
        "sync_cachegen_fallbacks": _names.ROUTER_SYNC_CACHEGEN_FALLBACKS,
        "cachegen_dropped": _names.ROUTER_CACHEGEN_DROPPED,
        "lookup_s": _names.ROUTER_LOOKUP_S,
        "tokens_saved": _names.ROUTER_TOKENS_SAVED,
        "speculations": _names.ROUTER_SPECULATIONS,
        "spec_commits": _names.ROUTER_SPEC_COMMITS,
        "spec_rollbacks": _names.ROUTER_SPEC_ROLLBACKS,
        "spec_sync_verifies": _names.ROUTER_SPEC_SYNC_VERIFIES,
        "spec_dropped": _names.ROUTER_SPEC_DROPPED,
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 **labels: str):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._c = {
            field: self.registry.counter(name, **labels)
            for field, name in self._FIELDS.items()
        }
        self.lookup_latency = self.registry.histogram(
            _names.ROUTER_LOOKUP_LATENCY, **labels
        )

    requests = _metric_prop("requests")
    hits = _metric_prop("hits")
    misses = _metric_prop("misses")
    large_tier_calls = _metric_prop("large_tier_calls")
    small_tier_calls = _metric_prop("small_tier_calls")
    async_cachegens = _metric_prop("async_cachegens")
    sync_cachegen_fallbacks = _metric_prop("sync_cachegen_fallbacks")
    cachegen_dropped = _metric_prop("cachegen_dropped")
    lookup_s = _metric_prop("lookup_s")
    tokens_saved = _metric_prop("tokens_saved")
    speculations = _metric_prop("speculations")
    spec_commits = _metric_prop("spec_commits")
    spec_rollbacks = _metric_prop("spec_rollbacks")
    spec_sync_verifies = _metric_prop("spec_sync_verifies")
    spec_dropped = _metric_prop("spec_dropped")

    def add(self, field: str, n: float = 1) -> None:
        """Lock-safe increment — callable from any thread."""
        self._c[field].inc(n)

    def observe_lookup(self, dt: float) -> None:
        self._c["lookup_s"].inc(dt)
        self.lookup_latency.observe(dt)

    def reset(self) -> None:
        for c in self._c.values():
            c.reset()
        self.lookup_latency.reset()

    def snapshot(self) -> Dict[str, Any]:
        return {
            "requests": self.requests,
            "hit_rate": self.hits / max(1, self.hits + self.misses),
            "large_tier_calls": self.large_tier_calls,
            "small_tier_calls": self.small_tier_calls,
            "async_cachegens": self.async_cachegens,
            "sync_cachegen_fallbacks": self.sync_cachegen_fallbacks,
            "cachegen_dropped": self.cachegen_dropped,
            "lookup_s": round(self.lookup_s, 6),
            "tokens_saved": self.tokens_saved,
            "speculations": self.speculations,
            "spec_commits": self.spec_commits,
            "spec_rollbacks": self.spec_rollbacks,
            "spec_sync_verifies": self.spec_sync_verifies,
            "spec_dropped": self.spec_dropped,
            "lookup_latency": self.lookup_latency.snapshot(),
        }


class TwoTierRouter:
    """keyword -> cache -> tier selection."""

    def __init__(
        self,
        cache,  # PlanCache | DistributedPlanCache
        *,
        extract_keyword: Callable[[Any], str],
        plan_large: Callable[[Any], Any],
        plan_small_with_template: Callable[[Any, Any], Any],
        make_template: Callable[[Any, Any], Any],
        async_cachegen: bool = True,
        cachegen_workers: int = 2,
        cachegen_pool: Optional[Any] = None,
        cachegen_fallback: bool = True,
        clock: Optional[Callable[[], float]] = None,
        obs: Optional[MetricsRegistry] = None,
        kv_prefix: Optional[Any] = None,
        spec_verify: Optional[Callable[[Any, Optional[str]], bool]] = None,
        spec_effect: Optional[
            Callable[[Any, str], Callable[[], None]]
        ] = None,
        spec_rollback: bool = True,
        spec_verify_fallback: bool = True,
    ):
        self.cache = cache
        # the paged KV prefix pool (serving.kv_cache.KVPrefixCache): its
        # lifecycle is slaved to the plan cache — when a template is
        # evicted from the hot tier, its prefix pages are released in the
        # same breath, so the pool can never serve KV for a plan the
        # router no longer routes to. Requires a local PlanCache (the
        # distributed facade has no single eviction stream).
        self.kv_prefix = kv_prefix
        if kv_prefix is not None:
            add = getattr(cache, "add_evict_listener", None)
            if add is None:
                raise TypeError(
                    "kv_prefix requires a cache with add_evict_listener "
                    "(plan-cache eviction must free the prefix pages)"
                )
            add(kv_prefix.release)
        self.extract_keyword = extract_keyword
        self.plan_large = plan_large
        self.plan_small_with_template = plan_small_with_template
        self.make_template = make_template
        # injectable time source for latency metrics (repro.sim drives a
        # virtual clock; production uses the monotonic perf counter)
        self._clock = clock if clock is not None else time.perf_counter
        # the serving spine's registry: default to the cache's own, so one
        # snapshot covers router + store + index without extra wiring
        if obs is None:
            obs = getattr(cache, "obs", None)
        self.metrics = RouterMetrics(obs)
        # GUARD — saturated-pool fallback: when an async cachegen
        # submission is REJECTED (pool saturated / shut down), the wave is
        # generated synchronously on the request thread instead — slower,
        # never lost. False is the repro.sim ablation: the rejected wave is
        # dropped, the silent distillation-loss bug the sim's
        # ``cachegen_loss`` oracle catches.
        self.cachegen_fallback = cachegen_fallback
        # ``cachegen_pool`` is the worker-pool seam: production uses a
        # private ThreadPoolExecutor; repro.sim injects a pool whose
        # workers are scheduler-driven sim clients, so the seeded scheduler
        # owns the admission-race interleavings. An injected pool is not
        # shut down by close() — its lifecycle belongs to the injector.
        if cachegen_pool is not None:
            self._pool: Optional[Any] = cachegen_pool
            self._owns_pool = False
        else:
            self._pool = (
                cf.ThreadPoolExecutor(max_workers=cachegen_workers)
                if async_cachegen
                else None
            )
            self._owns_pool = True
        self._pending: List[cf.Future] = []
        self._sync_cachegen_errors: List[BaseException] = []
        self._lock = threading.Lock()
        # Speculative near-hit execution (batch path): with ``spec_verify``
        # installed, a fuzzy/semantic near-hit is served immediately (the
        # adapted template IS the speculative execution) while
        # ``spec_verify(request, matched_key)`` re-derives the plan in the
        # background on the cachegen pool — under repro.sim that pool is a
        # set of scheduler clients, so the seeded scheduler owns the
        # verify-vs-execute race. Agreement COMMITS the journal (deferred
        # cache promotion of the near-match under the precise keyword, with
        # the lookup-time ``unless_written_since`` token, plus the deferred
        # spec_commits bump); disagreement ROLLS BACK every journaled
        # effect.
        # GUARD — rollback: spec_rollback=False is the repro.sim ablation
        # where a disagreeing speculation commits anyway (the side-effect
        # leak the ``spec_leak`` oracle catches).
        # GUARD — verify-timeout fallback: when the pool REJECTS the verify
        # task, it runs synchronously on the request thread instead;
        # spec_verify_fallback=False is the ablation where the rejected
        # verify is dropped and the speculation never resolves (the stuck
        # journal the ``spec_liveness`` oracle catches).
        self.spec_verify = spec_verify
        self._spec_effect = spec_effect
        self.spec_rollback = spec_rollback
        self.spec_verify_fallback = spec_verify_fallback
        self.speculator: Optional[PlanSpeculator] = (
            PlanSpeculator(rollback_enabled=spec_rollback)
            if spec_verify is not None
            else None
        )

    def _read_token(self) -> Optional[float]:
        """Conditional-admission token: the store clock captured at lookup
        time, so the distilled wave inserts with ``unless_written_since``
        and can never clobber an entry written after this read (None for
        legacy stores without ``now()``)."""
        now_fn = getattr(self.cache, "now", None)
        return now_fn() if callable(now_fn) else None

    def route(self, request: Any) -> Any:
        self.metrics.add("requests")
        kw = self.extract_keyword(request)
        with trace_span(_names.SPAN_ROUTE) as sp:
            token = self._read_token()
            t0 = self._clock()
            with collect() as attrib, trace_span(_names.SPAN_ROUTER_LOOKUP, n=1):
                tpl = self.cache.lookup(kw)
            self.metrics.observe_lookup(self._clock() - t0)
            self._attribution_event(sp, 0, tpl, attrib)
            return self._dispatch(request, kw, tpl, token)

    def route_batch(self, requests: List[Any]) -> List[Any]:
        """Admit a whole batch of requests through one cache pass.

        All keywords are answered by a single ``lookup_batch`` — with a
        fuzzy cache on the ``device`` backend that is one resident-bank
        device call for the entire batch instead of one scan per request —
        then each request takes its usual hit/miss tier dispatch. The
        misses' distilled templates land back in the cache as one
        admission wave (``insert_batch``: one lock acquisition, one device
        scatter) instead of one insert per miss.
        """
        self.metrics.add("requests", len(requests))
        kws = [self.extract_keyword(r) for r in requests]
        with trace_span(_names.SPAN_ROUTE_BATCH, batch=len(requests)) as bsp:
            token = self._read_token()
            t0 = self._clock()
            # PlanStore contract: lookup_batch is the primitive — no
            # capability probing; any conformant store answers the wave in
            # one pass. The attribution collector rides the call: resolving
            # layers deposit (stage, matched_key, node, tier) per index.
            with collect() as attrib, \
                    trace_span(_names.SPAN_ROUTER_LOOKUP, n=len(kws)):
                tpls = self.cache.lookup_batch(kws)
            self.metrics.observe_lookup(self._clock() - t0)

            out: List[Any] = []
            wave: List[tuple] = []  # (request, kw, large-tier result) misses
            for i, (r, kw, tpl) in enumerate(zip(requests, kws, tpls)):
                stage = (attrib.get(i) or {}).get("stage", "exact")
                speculate = (
                    tpl is not None
                    and self.speculator is not None
                    and stage != "exact"
                )
                self._attribution_event(bsp, i, tpl, attrib,
                                        speculative=speculate)
                if tpl is not None:
                    out.append(self._serve_hit(r, tpl))
                    if speculate:
                        self._begin_speculation(
                            r, kw, tpl, token,
                            (attrib.get(i) or {}).get("matched_key"),
                        )
                else:
                    result = self._serve_miss(r)
                    out.append(result)
                    wave.append((r, kw, result))
            bsp.set(hits=len(requests) - len(wave))

            if wave:
                def gen_and_insert_wave():
                    # per-request failure isolation: one bad make_template
                    # must not discard the rest of the wave's templates (the
                    # per-request path loses only its own); the first error
                    # still surfaces through drain() after the wave lands
                    items, first_err = [], None
                    for r, kw, result in wave:
                        try:
                            template = self.make_template(r, result)
                        except Exception as e:
                            first_err = first_err or e
                            continue
                        if template is not None:
                            items.append((kw, template))
                    if items:
                        # insert-if-newer: this wave derives from the
                        # lookup above — an entry (re)written since then
                        # (client insert, another wave) must win over the
                        # possibly-slow async distillation
                        if token is not None:
                            self.cache.insert_batch(
                                items, unless_written_since=token
                            )
                        else:
                            self.cache.insert_batch(items)
                    if first_err is not None:
                        raise first_err
                    return items

                gen = self._traced_cachegen(gen_and_insert_wave, len(wave))
                if self._pool is None or not self._submit_cachegen(
                    gen, len(wave)
                ):
                    # sync mode (or the guarded saturated-pool fallback):
                    # the batch's plans are already computed and paid for —
                    # defer the wave error to drain()/close() rather than
                    # discarding every served result by raising here. Warn
                    # so a caller that never drains still sees the failure;
                    # keep the stash bounded (first error is what drain
                    # re-raises).
                    try:
                        gen()
                    except Exception as e:
                        warnings.warn(
                            f"cache generation failed for an admission wave "
                            f"(deferred to drain()): {e!r}"
                        )
                        with self._lock:
                            if len(self._sync_cachegen_errors) < 16:
                                self._sync_cachegen_errors.append(e)
            return out

    def _begin_speculation(self, request: Any, kw: str, tpl: Any,
                           token: Optional[float],
                           matched_key: Optional[str]) -> None:
        """Open a near-hit speculation and race its verification.

        The served response is already on its way (the adapted template is
        the speculative execution); what's journaled here is everything a
        wrong speculation must be able to take back: the optional eager env
        effect (``spec_effect`` applies it and returns its compensation)
        and the DEFERRED cache promotion — the near-match template admitted
        under the precise keyword with the lookup-time
        ``unless_written_since`` token, so a commit can never clobber an
        entry (re)written while the verifier was thinking. The verify task
        rides the cachegen pool so one seam owns both background races."""
        speculator = self.speculator
        assert speculator is not None

        def admit() -> None:
            if token is not None:
                self.cache.insert(kw, tpl, unless_written_since=token)
            else:
                self.cache.insert(kw, tpl)

        def bump_commit() -> None:
            self.metrics.add("spec_commits")

        effect = None
        if self._spec_effect is not None:
            spec_effect = self._spec_effect
            effect = lambda: spec_effect(request, kw)  # noqa: E731
        # begin/resolve share the router lock: PlanSpeculator is
        # single-owner, but pool workers resolve while request threads
        # begin the next speculation
        with self._lock:
            spec_id = speculator.begin(
                kw, effect=effect, on_commit=(admit, bump_commit)
            )
        self.metrics.add("speculations")
        verify = self._traced_spec_verify(request, kw, spec_id, matched_key)
        if self._pool is None:
            verify()
            return
        try:
            fut = self._pool.submit(verify)
        except Exception:
            if not self.spec_verify_fallback:
                # ABLATION (repro.sim): the rejected verify task is
                # dropped and the speculation never resolves — the stuck
                # journal the spec_liveness oracle catches
                self.metrics.add("spec_dropped")
                current_span().event(
                    _names.EVENT_SPEC_FATE, fate="dropped", kw=kw
                )
                return
            # GUARD — verify-timeout fallback: rejected submissions verify
            # synchronously on the request thread — slower, never stuck
            self.metrics.add("spec_sync_verifies")
            verify()
            return
        with self._lock:
            self._pending.append(fut)

    def _traced_spec_verify(self, request: Any, kw: str, spec_id: int,
                            matched_key: Optional[str]) -> Callable[[], str]:
        """Wrap a speculation's verification in a ``router.spec_verify``
        span (tracer/parent captured at submit time — pool workers have an
        empty span contextvar, like ``_traced_cachegen``)."""
        tracer = get_tracer()
        parent = current_span()

        def verify() -> str:
            sp = tracer.start_span(_names.SPAN_SPEC_VERIFY, parent=parent,
                                   kw=kw)
            try:
                agree = bool(self.spec_verify(request, matched_key))
                with self._lock:
                    outcome = self.speculator.resolve(spec_id, agree)
                if outcome == "rollback":
                    self.metrics.add("spec_rollbacks")
                sp.event(_names.EVENT_SPEC_FATE, fate=outcome, kw=kw)
                return outcome
            except BaseException as e:
                sp.set(error=type(e).__name__)
                raise
            finally:
                sp.end()

        return verify

    def _attribution_event(self, sp: Any, i: int, tpl: Optional[Any],
                           attrib: Any, *, speculative: bool = False) -> None:
        """One ``cache.attribution`` span event for request ``i``: which
        tier serves it, where the hit came from (stage / matched key /
        shard / replica tier, deposited by the resolving layers), and the
        §4.4 cost attribution — the large-planner output tokens the cached
        template avoids regenerating, which are also (approximately) the
        adaptation tokens the small planner must now read."""
        if tpl is None:
            sp.event(_names.EVENT_ATTRIBUTION, i=i, hit=False, tier="large")
            return
        saved = tokens_saved_estimate(tpl)
        self.metrics.add("tokens_saved", saved)
        # near-hits being raced by the verifier carry ``speculative: true``
        # until the journal commits — the event is emitted at serve time,
        # so consumers pair it with the later ``spec.fate`` event
        extra = {"speculative": True} if speculative else {}
        sp.event(
            _names.EVENT_ATTRIBUTION, i=i, hit=True, tier="small",
            tokens_saved=saved, adapt_cost_tokens=saved,
            **extra, **attrib.get(i)
        )

    def _traced_cachegen(self, gen: Callable[[], Any], n: int) -> Callable[[], Any]:
        """Wrap a cache-generation task in a ``router.cachegen`` span.

        The tracer and parent span are captured at SUBMIT time — pool
        worker threads have an empty span contextvar, so the async path
        must parent explicitly (``start_span``/``end``)."""
        tracer = get_tracer()
        parent = current_span()

        def traced() -> Any:
            sp = tracer.start_span(_names.SPAN_CACHEGEN, parent=parent, n=n)
            try:
                return gen()
            except BaseException as e:
                sp.set(error=type(e).__name__)
                raise
            finally:
                sp.end()

        return traced

    def _submit_cachegen(self, gen: Callable[[], Any], n: int) -> bool:
        """Hand one cache-generation task to the async pool.

        Returns True when the task was submitted (or, with the
        ``cachegen_fallback`` guard ablated, dropped); False when the
        caller must run it synchronously — the GUARD path for a rejected
        submission (pool saturated or shut down): slower, never lost.

        All bookkeeping goes through lock-safe registry counters: this
        method runs on request threads concurrently with other waves, and
        the historical bare ``+=`` on a shared struct lost increments.
        """
        try:
            fut = self._pool.submit(gen)
        except Exception:
            if not self.cachegen_fallback:
                # ABLATION (repro.sim): the rejected wave is silently
                # dropped — the distillation loss the cachegen_loss
                # oracle catches
                self.metrics.add("cachegen_dropped", n)
                current_span().event(
                    _names.EVENT_CACHEGEN_FATE, fate="dropped", n=n
                )
                return True
            self.metrics.add("sync_cachegen_fallbacks", n)
            current_span().event(
                _names.EVENT_CACHEGEN_FATE, fate="sync_fallback", n=n
            )
            return False
        with self._lock:
            self._pending.append(fut)
        self.metrics.add("async_cachegens", n)
        current_span().event(_names.EVENT_CACHEGEN_FATE, fate="async", n=n)
        return True

    def _serve_hit(self, request: Any, tpl: Any) -> Any:
        """Cache hit: cheap tier adapts the cached template (shared by the
        single and batched admission paths so metrics/policy can't drift).

        With ``kv_prefix`` wired, the adapter behind
        ``plan_small_with_template`` should place the SINGLE cache point
        here — after the template, before the adaptation prompt — via
        ``serving.kv_cache.plan_cache_point(...)`` and pass the resulting
        ``CachePoint`` to ``Engine.generate``: the hit then prefills only
        the adaptation suffix, with the template's KV served from the
        page pool."""
        self.metrics.add("hits")
        self.metrics.add("small_tier_calls")
        return self.plan_small_with_template(request, tpl)

    def _serve_miss(self, request: Any) -> Any:
        """Cache miss: expensive tier replans (cache distillation is the
        caller's job — per-request future or batched wave)."""
        self.metrics.add("misses")
        self.metrics.add("large_tier_calls")
        return self.plan_large(request)

    def _dispatch(self, request: Any, kw: str, tpl: Optional[Any],
                  token: Optional[float] = None) -> Any:
        if tpl is not None:
            return self._serve_hit(request, tpl)
        result = self._serve_miss(request)

        def gen_and_insert():
            template = self.make_template(request, result)
            if template is not None:
                if token is not None:
                    self.cache.insert(kw, template,
                                      unless_written_since=token)
                else:
                    self.cache.insert(kw, template)
            return template

        gen = self._traced_cachegen(gen_and_insert, 1)
        if self._pool is None or not self._submit_cachegen(gen, 1):
            gen()
        return result

    def drain(self, timeout: float = 30.0) -> None:
        """Wait for async cache generations (tests / shutdown).

        Raises the first deferred cache-generation error from either mode:
        async waves raise out of their future here; sync waves stash their
        first error at route time (the batch's responses were already
        served) and it surfaces now.
        """
        with self._lock:
            pending, self._pending = self._pending, []
            errors, self._sync_cachegen_errors = self._sync_cachegen_errors, []
        for f in pending:
            f.result(timeout=timeout)
        if errors:
            raise errors[0]

    def close(self) -> None:
        self.drain()
        if self._pool is not None and self._owns_pool:
            self._pool.shutdown(wait=True)

"""Two-tier serving router — the APC cache as a *routing policy*.

This is the system-level embodiment of the paper: a cache hit routes the
planning request to the cheap tier (small planner pool) and skips the
expensive tier entirely; a miss goes to the large planner pool, and the
completed execution is distilled into the plan cache (optionally async so
cache generation never blocks the response path — the paper lists this as
future work in §4.3; implemented here).

The router is deployment-scale aware: the plan cache is any
``repro.memory.protocol.PlanStore`` — a local PlanCache or a
DistributedPlanCache (consistent-hash sharded across serving frontends) —
consumed through the protocol's batch primitives (no ``hasattr``
capability probing), and each tier is a pool of engines with hedged
dispatch.
``route_batch`` admits a whole arrival wave through a single
``lookup_batch`` pass — with a ``device``-backend fuzzy cache that is one
resident-bank device call for the entire batch — and distills the wave's
misses back into the cache through one ``insert_batch`` (one donated
multi-slot device scatter) rather than one insert per request.

Thread-safety contract: the router itself holds ``self._lock`` only around
the ``_pending`` futures list. Cache reads/writes need no router-side lock
— PlanCache/DistributedPlanCache serialize internally (their RLock nests
the embedding bank's lock, so host arena, LSH buckets, and device arena
mutate atomically). ``route``/``route_batch`` may be called concurrently
from many request threads while async cache-generation workers insert;
``RouterMetrics`` counters are benign-racy (never consistency-critical).
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.cache import PlanCache


@dataclass
class TierPool:
    """A pool of interchangeable engine replicas for one role."""

    name: str
    replicas: List[Any] = field(default_factory=list)
    _rr: int = 0
    hedge_timeout_s: float = 30.0
    # GUARD — hedged failover: a hedged dispatch serves the first
    # SUCCESSFUL replica and only raises when every replica failed, so one
    # timed-out/crashed engine never surfaces to the request. False is the
    # repro.sim ablation: the dispatch goes to a single replica and its
    # timeout propagates (the dropped-response bug the completeness oracle
    # catches).
    hedge_failover: bool = True
    _executor: Optional[cf.ThreadPoolExecutor] = field(
        default=None, repr=False, compare=False
    )
    _executor_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def pick(self) -> Any:
        # return-then-increment so the rotation starts at replica 0 and
        # visits every replica (increment-first skipped slot 0 forever)
        eng = self.replicas[self._rr % len(self.replicas)]
        self._rr = (self._rr + 1) % len(self.replicas)
        return eng

    def dispatch(self, fn: Callable[[Any], Any], *, hedge: bool = False) -> Any:
        """Run fn(engine); optionally hedge onto a second replica.

        Hedged calls share ONE executor per pool (lazily created) instead
        of paying thread-pool construction + teardown per request. The
        winner is the first replica to SUCCEED (completion order), not an
        arbitrary member of the first-completed set — a replica that fails
        fast must not beat one that succeeds slowly."""
        if not hedge or len(self.replicas) < 2 or not self.hedge_failover:
            return fn(self.pick())
        if self._executor is None:
            # locked lazy init: concurrent first dispatches must not each
            # build an executor (the loser's threads would leak). Sized
            # above 2 because a hedge loser that is already running cannot
            # be cancelled and holds its worker until it finishes — a hard
            # cap of 2 would let one straggler serialize (or block) every
            # later hedged dispatch on this pool.
            with self._executor_lock:
                if self._executor is None:
                    self._executor = cf.ThreadPoolExecutor(
                        max_workers=max(4, 2 * len(self.replicas)),
                        thread_name_prefix=f"tier-{self.name}",
                    )
        futs = [self._executor.submit(fn, self.pick()) for _ in range(2)]
        last_err: Optional[BaseException] = None
        try:
            for f in cf.as_completed(futs, timeout=self.hedge_timeout_s):
                try:
                    result = f.result()
                except Exception as e:  # noqa: BLE001 - replica failure
                    last_err = e
                    continue
                for other in futs:
                    if other is not f:
                        other.cancel()
                return result
        except cf.TimeoutError as e:
            # reclaim what can be reclaimed: queued-but-unstarted calls are
            # cancelled so a hung replica can't brick the pool by pinning
            # every worker (a RUNNING call is uncancellable and holds its
            # worker until it returns — that is why the executor is sized
            # above 2x the hedge width)
            for f in futs:
                f.cancel()
            last_err = TimeoutError(
                f"hedged dispatch on pool {self.name!r} exceeded "
                f"{self.hedge_timeout_s}s on every replica"
            )
            last_err.__cause__ = e
        assert last_err is not None
        raise last_err

    def close(self) -> None:
        with self._executor_lock:
            if self._executor is not None:
                self._executor.shutdown(wait=True)
                self._executor = None


@dataclass
class RouterMetrics:
    requests: int = 0
    hits: int = 0
    misses: int = 0
    large_tier_calls: int = 0
    small_tier_calls: int = 0
    async_cachegens: int = 0
    sync_cachegen_fallbacks: int = 0
    cachegen_dropped: int = 0
    lookup_s: float = 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "requests": self.requests,
            "hit_rate": self.hits / max(1, self.hits + self.misses),
            "large_tier_calls": self.large_tier_calls,
            "small_tier_calls": self.small_tier_calls,
            "async_cachegens": self.async_cachegens,
            "sync_cachegen_fallbacks": self.sync_cachegen_fallbacks,
            "cachegen_dropped": self.cachegen_dropped,
            "lookup_s": round(self.lookup_s, 6),
        }


class TwoTierRouter:
    """keyword -> cache -> tier selection."""

    def __init__(
        self,
        cache,  # PlanCache | DistributedPlanCache
        *,
        extract_keyword: Callable[[Any], str],
        plan_large: Callable[[Any], Any],
        plan_small_with_template: Callable[[Any, Any], Any],
        make_template: Callable[[Any, Any], Any],
        async_cachegen: bool = True,
        cachegen_workers: int = 2,
        cachegen_pool: Optional[Any] = None,
        cachegen_fallback: bool = True,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.cache = cache
        self.extract_keyword = extract_keyword
        self.plan_large = plan_large
        self.plan_small_with_template = plan_small_with_template
        self.make_template = make_template
        # injectable time source for latency metrics (repro.sim drives a
        # virtual clock; production uses the monotonic perf counter)
        self._clock = clock if clock is not None else time.perf_counter
        self.metrics = RouterMetrics()
        # GUARD — saturated-pool fallback: when an async cachegen
        # submission is REJECTED (pool saturated / shut down), the wave is
        # generated synchronously on the request thread instead — slower,
        # never lost. False is the repro.sim ablation: the rejected wave is
        # dropped, the silent distillation-loss bug the sim's
        # ``cachegen_loss`` oracle catches.
        self.cachegen_fallback = cachegen_fallback
        # ``cachegen_pool`` is the worker-pool seam: production uses a
        # private ThreadPoolExecutor; repro.sim injects a pool whose
        # workers are scheduler-driven sim clients, so the seeded scheduler
        # owns the admission-race interleavings. An injected pool is not
        # shut down by close() — its lifecycle belongs to the injector.
        if cachegen_pool is not None:
            self._pool: Optional[Any] = cachegen_pool
            self._owns_pool = False
        else:
            self._pool = (
                cf.ThreadPoolExecutor(max_workers=cachegen_workers)
                if async_cachegen
                else None
            )
            self._owns_pool = True
        self._pending: List[cf.Future] = []
        self._sync_cachegen_errors: List[BaseException] = []
        self._lock = threading.Lock()

    def route(self, request: Any) -> Any:
        self.metrics.requests += 1
        kw = self.extract_keyword(request)
        t0 = self._clock()
        tpl = self.cache.lookup(kw)
        self.metrics.lookup_s += self._clock() - t0
        return self._dispatch(request, kw, tpl)

    def route_batch(self, requests: List[Any]) -> List[Any]:
        """Admit a whole batch of requests through one cache pass.

        All keywords are answered by a single ``lookup_batch`` — with a
        fuzzy cache on the ``device`` backend that is one resident-bank
        device call for the entire batch instead of one scan per request —
        then each request takes its usual hit/miss tier dispatch. The
        misses' distilled templates land back in the cache as one
        admission wave (``insert_batch``: one lock acquisition, one device
        scatter) instead of one insert per miss.
        """
        self.metrics.requests += len(requests)
        kws = [self.extract_keyword(r) for r in requests]
        t0 = self._clock()
        # PlanStore contract: lookup_batch is the primitive — no capability
        # probing; any conformant store answers the wave in one pass
        tpls = self.cache.lookup_batch(kws)
        self.metrics.lookup_s += self._clock() - t0

        out: List[Any] = []
        wave: List[tuple] = []  # (request, kw, large-tier result) misses
        for r, kw, tpl in zip(requests, kws, tpls):
            if tpl is not None:
                out.append(self._serve_hit(r, tpl))
            else:
                result = self._serve_miss(r)
                out.append(result)
                wave.append((r, kw, result))

        if wave:
            def gen_and_insert_wave():
                # per-request failure isolation: one bad make_template must
                # not discard the rest of the wave's templates (the
                # per-request path loses only its own); the first error
                # still surfaces through drain() after the wave lands
                items, first_err = [], None
                for r, kw, result in wave:
                    try:
                        template = self.make_template(r, result)
                    except Exception as e:
                        first_err = first_err or e
                        continue
                    if template is not None:
                        items.append((kw, template))
                if items:
                    self.cache.insert_batch(items)
                if first_err is not None:
                    raise first_err
                return items

            if self._pool is None or not self._submit_cachegen(
                gen_and_insert_wave, len(wave)
            ):
                # sync mode (or the guarded saturated-pool fallback): the
                # batch's plans are already computed and paid for — defer
                # the wave error to drain()/close() rather than discarding
                # every served result by raising here. Warn so a caller
                # that never drains still sees the failure; keep the stash
                # bounded (first error is what drain re-raises).
                try:
                    gen_and_insert_wave()
                except Exception as e:
                    warnings.warn(
                        f"cache generation failed for an admission wave "
                        f"(deferred to drain()): {e!r}"
                    )
                    with self._lock:
                        if len(self._sync_cachegen_errors) < 16:
                            self._sync_cachegen_errors.append(e)
        return out

    def _submit_cachegen(self, gen: Callable[[], Any], n: int) -> bool:
        """Hand one cache-generation task to the async pool.

        Returns True when the task was submitted (or, with the
        ``cachegen_fallback`` guard ablated, dropped); False when the
        caller must run it synchronously — the GUARD path for a rejected
        submission (pool saturated or shut down): slower, never lost.
        """
        try:
            fut = self._pool.submit(gen)
        except Exception:
            if not self.cachegen_fallback:
                # ABLATION (repro.sim): the rejected wave is silently
                # dropped — the distillation loss the cachegen_loss
                # oracle catches
                self.metrics.cachegen_dropped += n
                return True
            self.metrics.sync_cachegen_fallbacks += n
            return False
        with self._lock:
            self._pending.append(fut)
        self.metrics.async_cachegens += n
        return True

    def _serve_hit(self, request: Any, tpl: Any) -> Any:
        """Cache hit: cheap tier adapts the cached template (shared by the
        single and batched admission paths so metrics/policy can't drift)."""
        self.metrics.hits += 1
        self.metrics.small_tier_calls += 1
        return self.plan_small_with_template(request, tpl)

    def _serve_miss(self, request: Any) -> Any:
        """Cache miss: expensive tier replans (cache distillation is the
        caller's job — per-request future or batched wave)."""
        self.metrics.misses += 1
        self.metrics.large_tier_calls += 1
        return self.plan_large(request)

    def _dispatch(self, request: Any, kw: str, tpl: Optional[Any]) -> Any:
        if tpl is not None:
            return self._serve_hit(request, tpl)
        result = self._serve_miss(request)

        def gen_and_insert():
            template = self.make_template(request, result)
            if template is not None:
                self.cache.insert(kw, template)
            return template

        if self._pool is None or not self._submit_cachegen(gen_and_insert, 1):
            gen_and_insert()
        return result

    def drain(self, timeout: float = 30.0) -> None:
        """Wait for async cache generations (tests / shutdown).

        Raises the first deferred cache-generation error from either mode:
        async waves raise out of their future here; sync waves stash their
        first error at route time (the batch's responses were already
        served) and it surfaces now.
        """
        with self._lock:
            pending, self._pending = self._pending, []
            errors, self._sync_cachegen_errors = self._sync_cachegen_errors, []
        for f in pending:
            f.result(timeout=timeout)
        if errors:
            raise errors[0]

    def close(self) -> None:
        self.drain()
        if self._pool is not None and self._owns_pool:
            self._pool.shutdown(wait=True)

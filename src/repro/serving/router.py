"""Two-tier serving router — the APC cache as a *routing policy*.

This is the system-level embodiment of the paper: a cache hit routes the
planning request to the cheap tier (small planner pool) and skips the
expensive tier entirely; a miss goes to the large planner pool, and the
completed execution is distilled into the plan cache (optionally async so
cache generation never blocks the response path — the paper lists this as
future work in §4.3; implemented here).

The router is deployment-scale aware: the plan cache can be a local
PlanCache or a DistributedPlanCache (consistent-hash sharded across serving
frontends), and each tier is a pool of engines with hedged dispatch.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.cache import PlanCache


@dataclass
class TierPool:
    """A pool of interchangeable engine replicas for one role."""

    name: str
    replicas: List[Any] = field(default_factory=list)
    _rr: int = 0
    hedge_timeout_s: float = 30.0

    def pick(self) -> Any:
        self._rr = (self._rr + 1) % max(1, len(self.replicas))
        return self.replicas[self._rr]

    def dispatch(self, fn: Callable[[Any], Any], *, hedge: bool = False) -> Any:
        """Run fn(engine); optionally hedge onto a second replica."""
        if not hedge or len(self.replicas) < 2:
            return fn(self.pick())
        with cf.ThreadPoolExecutor(max_workers=2) as ex:
            futs = [ex.submit(fn, self.pick()) for _ in range(2)]
            done, not_done = cf.wait(futs, return_when=cf.FIRST_COMPLETED)
            for f in not_done:
                f.cancel()
            return next(iter(done)).result()


@dataclass
class RouterMetrics:
    requests: int = 0
    hits: int = 0
    misses: int = 0
    large_tier_calls: int = 0
    small_tier_calls: int = 0
    async_cachegens: int = 0
    lookup_s: float = 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "requests": self.requests,
            "hit_rate": self.hits / max(1, self.hits + self.misses),
            "large_tier_calls": self.large_tier_calls,
            "small_tier_calls": self.small_tier_calls,
            "async_cachegens": self.async_cachegens,
            "lookup_s": round(self.lookup_s, 6),
        }


class TwoTierRouter:
    """keyword -> cache -> tier selection."""

    def __init__(
        self,
        cache,  # PlanCache | DistributedPlanCache
        *,
        extract_keyword: Callable[[Any], str],
        plan_large: Callable[[Any], Any],
        plan_small_with_template: Callable[[Any, Any], Any],
        make_template: Callable[[Any, Any], Any],
        async_cachegen: bool = True,
        cachegen_workers: int = 2,
    ):
        self.cache = cache
        self.extract_keyword = extract_keyword
        self.plan_large = plan_large
        self.plan_small_with_template = plan_small_with_template
        self.make_template = make_template
        self.metrics = RouterMetrics()
        self._pool = (
            cf.ThreadPoolExecutor(max_workers=cachegen_workers)
            if async_cachegen
            else None
        )
        self._pending: List[cf.Future] = []
        self._lock = threading.Lock()

    def route(self, request: Any) -> Any:
        self.metrics.requests += 1
        kw = self.extract_keyword(request)
        t0 = time.perf_counter()
        tpl = self.cache.lookup(kw)
        self.metrics.lookup_s += time.perf_counter() - t0
        return self._dispatch(request, kw, tpl)

    def route_batch(self, requests: List[Any]) -> List[Any]:
        """Admit a whole batch of requests through one cache pass.

        All keywords are answered by a single ``lookup_batch`` — with a
        fuzzy cache on the ``pallas`` backend that is one ``batch_topk``
        device call for the entire batch instead of one scan per request —
        then each request takes its usual hit/miss tier dispatch.
        """
        self.metrics.requests += len(requests)
        kws = [self.extract_keyword(r) for r in requests]
        t0 = time.perf_counter()
        if hasattr(self.cache, "lookup_batch"):
            tpls = self.cache.lookup_batch(kws)
        else:
            tpls = [self.cache.lookup(kw) for kw in kws]
        self.metrics.lookup_s += time.perf_counter() - t0
        return [
            self._dispatch(r, kw, tpl) for r, kw, tpl in zip(requests, kws, tpls)
        ]

    def _dispatch(self, request: Any, kw: str, tpl: Optional[Any]) -> Any:
        if tpl is not None:
            self.metrics.hits += 1
            self.metrics.small_tier_calls += 1
            return self.plan_small_with_template(request, tpl)
        self.metrics.misses += 1
        self.metrics.large_tier_calls += 1
        result = self.plan_large(request)

        def gen_and_insert():
            template = self.make_template(request, result)
            if template is not None:
                self.cache.insert(kw, template)
            return template

        if self._pool is not None:
            with self._lock:
                self._pending.append(self._pool.submit(gen_and_insert))
            self.metrics.async_cachegens += 1
        else:
            gen_and_insert()
        return result

    def drain(self, timeout: float = 30.0) -> None:
        """Wait for async cache generations (tests / shutdown)."""
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result(timeout=timeout)

    def close(self) -> None:
        self.drain()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

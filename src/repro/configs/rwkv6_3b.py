"""rwkv6-3b ("Finch"): attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf] — 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.
Head size 64 => 40 heads. Time-mix (wkv6) + channel-mix (squared-relu) blocks
with token shift.
"""

from repro.configs.base import ModelConfig, SSMConfig, ShardingProfile

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # d_model / head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    attn_every=0,  # attention-free
    rope_type="none",
    mlp_act="squared_relu",  # rwkv channel-mix uses relu^2
    norm_type="layernorm",
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64, chunk_size=128),
    source="arXiv:2404.05892",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=("data",),
    remat="full",
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

"""nemotron-4-15b: dense GQA transformer with squared-ReLU MLP.

[arXiv:2402.16819; unverified] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU (no gate => 2 MLP matrices).
"""

from repro.configs.base import ModelConfig, ShardingProfile

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=256_000,
    mlp_act="squared_relu",
    norm_type="layernorm",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=("data",),
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

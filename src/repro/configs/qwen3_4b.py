"""qwen3-4b: dense GQA transformer with qk-norm.

[hf:Qwen/Qwen3-8B; hf] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm, GQA. head_dim=128 (Qwen3 uses 128 regardless of
d_model/num_heads).
"""

from repro.configs.base import ModelConfig, ShardingProfile

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    qk_norm=True,
    mlp_act="swiglu",
    norm_type="rmsnorm",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=("data",),
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

"""whisper-tiny: encoder-decoder audio model; conv frontend stubbed.

[arXiv:2212.04356; unverified] — 4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865, enc-dec. The conv1d/mel frontend is a STUB per the assignment:
input_specs provides precomputed frame embeddings (1500 frames x 384).
"""

from repro.configs.base import EncoderConfig, ModelConfig, ShardingProfile

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    mlp_act="gelu",
    norm_type="layernorm",
    rope_type="none",  # whisper uses sinusoidal (enc) + learned (dec) pos emb
    encoder=EncoderConfig(num_layers=4, num_frames=1500, frame_dim=384),
    frontend="audio_frames",
    source="arXiv:2212.04356",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=(),
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

"""kimi-k2-1t-a32b: trillion-parameter MoE (Kimi K2 paper-table config).

[arXiv:2501.kimi2; unverified] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8. ~1T total / ~32B active params.

Deployment notes: expert-parallel ("ep") MoE is mandatory at this scale —
GShard dense dispatch would materialize 384-way one-hot einsums. Optimizer
state is kept in bf16 and fully sharded over (pod, data, model) to fit
v5e HBM (see ShardingProfile below and EXPERIMENTS.md §Dry-run).
"""

from repro.configs.base import ModelConfig, MoEConfig, ShardingProfile

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,  # per-expert FFN width
    vocab_size=163_840,
    qk_norm=True,
    mlp_act="swiglu",
    norm_type="rmsnorm",
    rope_theta=50_000.0,
    moe=MoEConfig(
        num_experts=384,
        experts_per_token=8,
        d_ff_expert=2048,
        capacity_factor=1.25,
        mode="ep",
    ),
    source="arXiv:2501.kimi2 (paper-table)",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=("pod", "data"),
    ep_axis="model",
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
    optimizer_dtype="bfloat16",  # 1T params: fp32 m/v would not fit 512xv5e
    gradient_compression="int8_ef",
)

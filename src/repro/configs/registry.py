"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture is registered here together with its deployment
sharding profile. ``get(name)`` returns the full-size ModelConfig;
``get_smoke(name)`` returns the reduced CPU-smoke variant.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    ShardingProfile,
    reduce_for_smoke,
    supports_shape,
)

from repro.configs import (
    qwen3_4b,
    olmo_1b,
    nemotron_4_15b,
    qwen2_5_3b,
    rwkv6_3b,
    qwen2_vl_7b,
    kimi_k2_1t_a32b,
    granite_moe_1b_a400m,
    zamba2_2_7b,
    whisper_tiny,
)

_MODULES = {
    "qwen3-4b": qwen3_4b,
    "olmo-1b": olmo_1b,
    "nemotron-4-15b": nemotron_4_15b,
    "qwen2.5-3b": qwen2_5_3b,
    "rwkv6-3b": rwkv6_3b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "zamba2-2.7b": zamba2_2_7b,
    "whisper-tiny": whisper_tiny,
}

ARCH_NAMES: List[str] = list(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return _MODULES[name].CONFIG


def get_sharding(name: str, kind: str = "") -> ShardingProfile:
    """Deployment profile; per-shape-kind overrides via SHARDING_<KIND>
    module attrs (e.g. olmo's train profile drops TP, its serving profile
    keeps it — batch 32 can't shard 256 ways)."""
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = _MODULES[name]
    if kind:
        return getattr(mod, f"SHARDING_{kind.upper()}", mod.SHARDING)
    return mod.SHARDING


def get_smoke(name: str) -> ModelConfig:
    return reduce_for_smoke(get(name))


def all_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells per the assignment rules."""
    cells = []
    for arch in ARCH_NAMES:
        cfg = get(arch)
        for shape_name, shape in SHAPES.items():
            if supports_shape(cfg, shape):
                cells.append((arch, shape_name))
    return cells


def shape(name: str) -> ShapeConfig:
    return SHAPES[name]

"""olmo-1b: dense transformer with non-parametric LayerNorm.

[arXiv:2402.00838; hf] — 16L d_model=2048 16H (GQA kv=16 == MHA) d_ff=8192
vocab=50304, non-parametric LN (no scale/bias).
"""

from repro.configs.base import ModelConfig, ShardingProfile

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    mlp_act="swiglu",
    norm_type="layernorm_np",  # non-parametric: normalize only, no affine
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.00838",
)

# Serving profile: TP over model (16 heads divide 16 cleanly; inference
# batches 32/128 cannot shard 256 DP ways).
SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=(),
    remat="full",
)

# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 1):
# a 1.2B model gains nothing from TP=16 at global batch 256 — use the
# model axis as extra data parallelism + FSDP. Collective term 12.8x down,
# roofline fraction 11.9% -> 68.7%, per-device HBM 93.8G -> 4.1G.
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",  # TP disabled; model axis joins DP
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

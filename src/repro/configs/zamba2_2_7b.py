"""zamba2-2.7b: Mamba2 backbone + shared attention blocks (hybrid).

[arXiv:2411.15242; hf] — 54L d_model=2560 32H (GQA kv=32 == MHA) d_ff=10240
vocab=32000, ssm_state=64. A single shared transformer block is applied after
every 6 Mamba2 layers (9 applications over 54 layers), Zamba2-style: shared
*weights*, per-application KV cache slots.
"""

from repro.configs.base import ModelConfig, SSMConfig, ShardingProfile

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab_size=32_000,
    attn_every=6,  # shared attention after every 6 mamba2 layers
    shared_attention=True,
    mlp_act="swiglu",
    norm_type="rmsnorm",
    rope_theta=10_000.0,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2, chunk_size=128),
    source="arXiv:2411.15242",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=("data",),
    remat="full",
    shard_kv_seq=True,  # long_500k: shard the 500k KV slots by sequence
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

"""Config package: base dataclasses + per-arch configs + registry."""

"""qwen2-vl-7b: VLM backbone with M-RoPE (multimodal rotary embedding).

[arXiv:2409.12191; hf] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064. The vision frontend (dynamic-resolution patch embedding) is a
STUB per the assignment: input_specs provides precomputed patch/token
embeddings plus (temporal, height, width) position ids for M-RoPE.
"""

from repro.configs.base import ModelConfig, ShardingProfile

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    mlp_act="swiglu",
    norm_type="rmsnorm",
    rope_type="mrope",
    rope_theta=1_000_000.0,
    frontend="patch_embed",
    source="arXiv:2409.12191",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=("data",),
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

"""Configuration dataclasses for the repro framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``;
deployment-level concerns (sharding, pipeline stages, remat) live in
``ShardingProfile``; the four assigned input shapes are ``ShapeConfig``s.

Configs are frozen dataclasses so they can be hashed into jit caches and
serialized into checkpoints / experiment logs.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts layer configuration (GShard/Megablox-style)."""

    num_experts: int
    experts_per_token: int  # top-k
    d_ff_expert: int
    capacity_factor: float = 1.25
    # "dense": GShard one-hot dispatch einsums (auto-partitioned by pjit).
    # "ep": expert-parallel shard_map + all_to_all + ragged_dot grouped matmul.
    mode: str = "dense"
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # EP-path perf knobs (hillclimb; see EXPERIMENTS.md §Perf):
    a2a_dtype: str = "auto"  # auto=x dtype | bfloat16 | float8_e4m3fn
    dispatch_chunks: int = 1  # split tokens into chunks: buffers / chunks


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-attention configuration."""

    kind: str  # "rwkv6" | "mamba2"
    state_dim: int = 64  # per-head state width (d_state)
    head_dim: int = 64
    expand: int = 2  # mamba2 inner expansion (d_inner = expand * d_model)
    conv_dim: int = 4  # depthwise conv width (mamba2)
    chunk_size: int = 128  # chunked-scan block length (TPU-friendly)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (whisper)."""

    num_layers: int
    num_frames: int = 1500  # stub frontend emits this many frames
    frame_dim: int = 384


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | vlm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention features ---
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_chunk: int = 2048  # flash block size (VMEM/temp-memory knob)
    use_pallas: bool = False  # route attention through the Pallas kernels
    #   (TPU: compiled Mosaic; CPU: interpret mode — tests only)
    rope_theta: float = 10_000.0
    rope_type: str = "rope"  # rope | mrope | none
    attn_logit_softcap: float = 0.0

    # --- mlp / norm features ---
    mlp_act: str = "swiglu"  # swiglu | squared_relu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_np
    tie_embeddings: bool = False

    # --- optional subsystems ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # --- hybrid layout ---
    # attn_every = 0 -> attention-free (pure SSM).
    # attn_every = 1 -> attention in every layer (pure transformer).
    # attn_every = k>1 -> one (shared) attention block after every k SSM layers.
    attn_every: int = 1
    shared_attention: bool = False

    # --- frontend stub (modality models; see input_specs) ---
    frontend: str = "none"  # none | patch_embed | audio_frames

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- provenance ---
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.attn_every == 0

    @property
    def num_attn_layers(self) -> int:
        """How many attention applications exist (KV-cache slots)."""
        if self.attn_every == 0:
            return 0
        if self.encoder is not None:
            return self.num_layers  # decoder self-attn layers
        return self.num_layers // self.attn_every

    @property
    def supports_subquadratic_decode(self) -> bool:
        """long_500k eligibility: SSM / hybrid / linear-attention families."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (matches models.init within ties)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # output head
        per_layer = 0
        # attention block
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        # mlp block
        if self.moe is not None:
            e = self.moe
            mlp_mats = 3 if self.mlp_act == "swiglu" else 2
            mlp = e.num_experts * (mlp_mats * d * e.d_ff_expert) + d * e.num_experts
        else:
            mlp_mats = 3 if self.mlp_act == "swiglu" else 2
            mlp = mlp_mats * d * self.d_ff
        if self.ssm is not None and self.ssm.kind == "mamba2":
            # Zamba2-style: mamba2 mixer per layer, NO per-layer MLP; the MLP
            # lives inside the (shared) transformer block.
            s = self.ssm
            d_in = s.expand * d
            heads = d_in // s.head_dim
            ssm_p = (
                d * (2 * d_in + 2 * s.state_dim + heads)  # in_proj (z,x,B,C,dt)
                + s.conv_dim * (d_in + 2 * s.state_dim)  # depthwise conv
                + 3 * heads  # A_log, dt_bias, D
                + d_in  # pre-out norm
                + d_in * d  # out_proj
            )
            n += self.num_layers * ssm_p
            if self.attn_every > 0:
                n_attn = 1 if self.shared_attention else self.num_attn_layers
                n += n_attn * (attn + mlp)
        elif self.ssm is not None:  # rwkv6: time-mix + channel-mix per layer
            s = self.ssm
            heads = d // s.head_dim
            # r,k,v,g,w projections + out proj + decay lora + bonus + shift mixes
            ssm_p = 5 * d * d + d * d + 2 * heads * s.head_dim + 6 * d
            n += self.num_layers * (ssm_p + mlp)
        else:
            per_layer = attn + mlp
            n += self.num_layers * per_layer
        if self.encoder is not None:
            enc_attn = 4 * d * d
            enc_mlp = mlp_mats * d * self.d_ff
            cross = 4 * d * d
            n += self.encoder.num_layers * (enc_attn + enc_mlp)
            n += self.num_layers * cross  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        mlp_mats = 3 if self.mlp_act == "swiglu" else 2
        full_experts = e.num_experts * (mlp_mats * self.d_model * e.d_ff_expert)
        active_experts = e.experts_per_token * (mlp_mats * self.d_model * e.d_ff_expert)
        return self.param_count() - self.num_layers * (full_experts - active_experts)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def supports_shape(model: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not model.supports_subquadratic_decode:
        return False
    return True


# ---------------------------------------------------------------------------
# Sharding / deployment profile
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardingProfile:
    """How a model is laid out on the mesh.

    Axis names refer to mesh axes ("pod", "data", "model"). ``fsdp_axes``
    shards parameters + optimizer state over those axes (ZeRO-3);
    ``tp_axis`` applies Megatron-pattern tensor parallelism; MoE expert
    weights shard over ``ep_axis`` when the MoE mode is "ep".
    """

    tp_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ()  # e.g. ("data",) or ("pod", "data")
    dp_axes: Tuple[str, ...] = ("data",)  # batch axes (pod is appended when present)
    ep_axis: str = "model"
    pipeline_axis: str = ""  # "" = no pipeline parallelism
    pipeline_stages: int = 1
    remat: str = "none"  # none | full | dots | offload
    optimizer_dtype: str = "float32"  # float32 | bfloat16 (1T-scale models)
    gradient_compression: str = "none"  # none | int8_ef
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8
    # shard long KV caches over the TP axis by sequence when heads < tp size
    shard_kv_seq: bool = False
    # sequence parallelism: shard activations' seq dim over tp_axis (kills
    # within-head psums when heads % tp != 0; KV gathered once per layer)
    seq_parallel: bool = False
    # shard K/V projections over tp (disable when kv_dim/tp splits within
    # heads and causes per-block psums; replicating kv proj is cheap)
    shard_kv_proj: bool = True
    # use these mesh axes as ADDITIONAL data-parallel axes (e.g. ("model",)
    # for small models where TP is pure overhead; set tp_axis="" with it)
    extra_dp_axes: Tuple[str, ...] = ()


@dataclass(frozen=True)
class DeploymentConfig:
    """Full deployment = model + sharding + runtime knobs."""

    model: ModelConfig
    sharding: ShardingProfile = field(default_factory=ShardingProfile)
    max_decode_steps: int = 64
    microbatch: int = 0  # 0 = no gradient accumulation


# ---------------------------------------------------------------------------
# Reduced configs for smoke tests
# ---------------------------------------------------------------------------


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to CPU-smoke scale, preserving the family shape."""
    kw: Dict[str, Any] = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, experts_per_token=2, d_ff_expert=64
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=32, chunk_size=16
        )
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(
            cfg.encoder, num_layers=2, num_frames=16, frame_dim=128
        )
    if cfg.attn_every > 1:
        kw["num_layers"] = 4
        kw["attn_every"] = 2
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)

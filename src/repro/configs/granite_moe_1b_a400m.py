"""granite-moe-1b-a400m: small MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] — 24L d_model=1024 16H
(GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
"""

from repro.configs.base import ModelConfig, MoEConfig, ShardingProfile

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,  # per-expert FFN width
    vocab_size=49_155,
    mlp_act="swiglu",
    norm_type="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    moe=MoEConfig(
        num_experts=32,
        experts_per_token=8,
        d_ff_expert=512,
        capacity_factor=1.25,
        mode="dense",  # small enough for GShard dense dispatch
    ),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=(),
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

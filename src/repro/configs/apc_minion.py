"""The paper's own deployment: APC on a Minion-style Plan-Act agent.

The paper (§4.1) used GPT-4o as the large planner, LLaMa-3.1-8B as both the
small planner and the actor, and GPT-4o-mini for keyword extraction / cache
generation. In this framework the tiers are drawn from the assigned model zoo
(all open configs), preserving the size ordering:

    large planner   : nemotron-4-15b (largest dense) or kimi-k2 (MoE flagship)
    small planner   : olmo-1b
    actor           : qwen2.5-3b
    keyword/cachegen: olmo-1b (reduced)

Token prices for the $-cost model come straight from the paper's Table 8 so
benchmark dollar figures stay comparable with the paper.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass(frozen=True)
class TierPricing:
    """$ per million tokens (paper Table 8)."""

    input_per_m: float
    output_per_m: float


# Paper Table 8, verbatim.
PAPER_PRICES: Dict[str, TierPricing] = {
    "gpt-4o": TierPricing(2.50, 10.00),
    "gpt-4o-mini": TierPricing(0.15, 0.60),
    "claude-3.5-sonnet": TierPricing(3.00, 15.00),
    "llama-3.1-8b": TierPricing(0.18, 0.18),
    "llama-3.2-3b": TierPricing(0.06, 0.06),
    "qwen-2.5-7b": TierPricing(0.30, 0.30),
}


@dataclass(frozen=True)
class APCDeployment:
    """Which arch plays which APC role, and how each role is priced."""

    large_planner: str = "nemotron-4-15b"
    small_planner: str = "olmo-1b"
    actor: str = "qwen2.5-3b"
    keyword_extractor: str = "olmo-1b"
    # price table role -> Table 8 model (keeps $ comparable to the paper)
    pricing: Dict[str, str] = field(
        default_factory=lambda: {
            "large_planner": "gpt-4o",
            "small_planner": "llama-3.1-8b",
            "actor": "llama-3.1-8b",
            "keyword_extractor": "gpt-4o-mini",
            "cache_generator": "gpt-4o-mini",
        }
    )
    max_iterations: int = 10  # paper §4.1
    cache_capacity: int = 100  # paper Table 4 default
    fuzzy_matching: bool = False  # paper default: exact matching
    fuzzy_threshold: float = 0.8
    # repro.index: auto | brute | pallas | bucketed | device
    # ("device" keeps the embedding bank resident on the accelerator —
    # zero bank H2D per lookup; see docs/architecture.md)
    index_backend: str = "auto"


DEFAULT = APCDeployment()

# Flagship-scale variant: trillion-param MoE as the large planner.
FLAGSHIP = APCDeployment(large_planner="kimi-k2-1t-a32b")

"""qwen2.5-3b: dense GQA transformer with QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, GQA, QKV bias.
"""

from repro.configs.base import ModelConfig, ShardingProfile

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    qkv_bias=True,
    mlp_act="swiglu",
    norm_type="rmsnorm",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
)

SHARDING = ShardingProfile(
    tp_axis="model",
    fsdp_axes=(),
    remat="full",
    # decode KV: kv_heads < TP would split head_dim and psum scores per
    # layer; sequence-sharding the cache is 40x cheaper (§Perf iter 3)
    shard_kv_seq=True,
)


# Beyond-paper optimized TRAIN deployment (EXPERIMENTS.md §Perf iter 4):
# at seq 4k / global batch 256 on a 256-chip pod, per-layer FSDP gathers
# cost far less than Megatron activation all-reduces — every <=15B train
# cell flips to compute-bound (55-86%% of roofline).
SHARDING_TRAIN = ShardingProfile(
    tp_axis="",
    fsdp_axes=("data", "model"),
    extra_dp_axes=("model",),
    remat="full",
)

"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

Positions are explicit everywhere (no hidden state), so prefill, decode and
chunked execution all share the same code path.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple:
    """positions: (...,) int32 -> cos/sin of shape positions.shape + (head_dim/2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, half) -> broadcast over heads.

    Uses the 'split-half' convention (x = [x1, x2]) matching Llama/Qwen.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Qwen2-VL splits the half-dims into (temporal, height, width) sections.

    For hd=128 (half=64) the reference split is (16, 24, 24); we generalize
    to (half/4, 3*half/8, 3*half/8).
    """
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def mrope_angles(positions_3d: jnp.ndarray, head_dim: int, theta: float) -> Tuple:
    """positions_3d: (3, B, S) [temporal, height, width] -> (cos, sin) (B,S,half).

    Each frequency band takes its angle from the section's position stream.
    """
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # angles per stream: (3, B, S, half)
    ang = positions_3d.astype(jnp.float32)[..., None] * freqs
    t, h, w = mrope_sections(head_dim)
    sec = jnp.concatenate(
        [
            ang[0, ..., :t],
            ang[1, ..., t : t + h],
            ang[2, ..., t + h :],
        ],
        axis=-1,
    )  # (B, S, half)
    return jnp.cos(sec), jnp.sin(sec)


def positions_for_rope(cfg, positions: jnp.ndarray, head_dim: int):
    """Dispatch rope/mrope/none. positions: (B,S) int32 or (3,B,S) for mrope.

    Returns (cos, sin) or (None, None) for rope_type == 'none'.
    """
    if cfg.rope_type == "none":
        return None, None
    if cfg.rope_type == "mrope":
        if positions.ndim == 2:  # text-only: replicate across the 3 streams
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_angles(positions, head_dim, cfg.rope_theta)
    return rope_angles(positions, head_dim, cfg.rope_theta)

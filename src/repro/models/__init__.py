"""Model zoo: unified LM API over dense/moe/ssm/hybrid/vlm/audio families."""

from repro.models.lm import (  # noqa: F401
    ParallelCtx,
    abstract_cache,
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)

"""RWKV6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

TPU adaptation: GPU RWKV kernels use warp-level primitives for the wkv
recurrence; here we use the *chunked parallel form* — intra-chunk work is
dense matmuls (MXU-friendly), inter-chunk state passes through a short
``lax.scan`` — the standard TPU factorization of a linear recurrence.
kernels/rwkv6.py implements the same chunking as a Pallas kernel.

Recurrence (per head, key-dim n, value-dim m):
    y_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T
with w_t = exp(-exp(w_base + lora(x^w_t))) in (0, 1), data-dependent.

Chunked factorization (chunk c, within-chunk cumulative log-decay la):
    y_intra[i] = sum_{j<i} (r_i * exp(la_{i-1} - la_j)) . k_j  v_j
               + (sum_n r u k)_i v_i
    y_inter[i] = (r_i * exp(la_{i-1})) @ S0
    S' = diag(exp(la_C)) S0 + sum_j (k_j * exp(la_C - la_j)) v_j^T
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    dense_init,
    dtype_of,
    layer_norm,
    split_keys,
    token_shift,
)

LORA_DIM = 64


def rwkv_init(cfg, key) -> Params:
    d = cfg.d_model
    s = cfg.ssm
    heads = d // s.head_dim
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 12)
    p: Params = {
        # time-mix
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_v": jnp.full((d,), 0.5, jnp.float32),
        "mix_g": jnp.full((d,), 0.5, jnp.float32),
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": dense_init(ks[0], (d, d), dt),
        "wk": dense_init(ks[1], (d, d), dt),
        "wv": dense_init(ks[2], (d, d), dt),
        "wg": dense_init(ks[3], (d, d), dt),
        "wo": dense_init(ks[4], (d, d), dt),
        "w_base": jnp.full((d,), -4.6, jnp.float32),  # decay ~ exp(-0.01)
        "w_lora_a": dense_init(ks[5], (d, LORA_DIM), jnp.float32, scale=0.01),
        "w_lora_b": dense_init(ks[6], (LORA_DIM, d), jnp.float32, scale=0.01),
        "bonus": dense_init(ks[7], (heads, s.head_dim), jnp.float32, scale=0.1),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        # channel-mix
        "mix_ck": jnp.full((d,), 0.5, jnp.float32),
        "mix_cr": jnp.full((d,), 0.5, jnp.float32),
        "ck": dense_init(ks[8], (d, cfg.d_ff), dt),
        "cv": dense_init(ks[9], (cfg.d_ff, d), dt),
        "cr": dense_init(ks[10], (d, d), dt),
    }
    return p


# ---------------------------------------------------------------------------
# wkv6 core: chunked parallel form + recurrent step
# ---------------------------------------------------------------------------


def wkv6_chunked(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w_log: jnp.ndarray,
    u: jnp.ndarray,
    state0: jnp.ndarray,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w_log: (B,S,H,N); u: (H,N); state0: (B,H,N,N) -> (y, state)."""
    B, S, H, N = r.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        z = jnp.zeros((B, pad, H, N), r.dtype)
        r = jnp.concatenate([r, z], 1)
        k = jnp.concatenate([k, z], 1)
        v = jnp.concatenate([v, z], 1)
        w_log = jnp.concatenate([w_log, jnp.zeros((B, pad, H, N), w_log.dtype)], 1)
    Sp = S + pad
    n = Sp // C
    rc = r.reshape(B, n, C, H, N).astype(jnp.float32)
    kc = k.reshape(B, n, C, H, N).astype(jnp.float32)
    vc = v.reshape(B, n, C, H, N).astype(jnp.float32)
    wc = w_log.reshape(B, n, C, H, N).astype(jnp.float32)

    tri_excl = (jnp.arange(C)[None, :] < jnp.arange(C)[:, None]).astype(jnp.float32)

    def body(state, xs):
        rb, kb, vb, wb = xs  # (B, C, H, N)
        la = jnp.cumsum(wb, axis=1)  # inclusive cumulative log decay
        la_prev = la - wb  # A_{t-1}
        la_end = la[:, -1:]  # (B,1,H,N)
        q_t = rb * jnp.exp(la_prev)
        k_t = kb * jnp.exp(-la)
        scores = jnp.einsum("bihn,bjhn->bhij", q_t, k_t)
        scores = scores * tri_excl[None, None]
        y_intra = jnp.einsum("bhij,bjhn->bihn", scores, vb)
        diag_c = jnp.sum(rb * u[None, None] * kb, axis=-1, keepdims=True)  # (B,C,H,1)
        y_diag = diag_c * vb
        y_inter = jnp.einsum("bihn,bhnm->bihm", q_t, state)
        y = y_intra + y_diag + y_inter
        k_dec = kb * jnp.exp(la_end - la)
        state = jnp.exp(la_end[:, 0])[..., None] * state + jnp.einsum(
            "bjhn,bjhm->bhnm", k_dec, vb
        )
        return state, y

    xs = (
        rc.transpose(1, 0, 2, 3, 4),
        kc.transpose(1, 0, 2, 3, 4),
        vc.transpose(1, 0, 2, 3, 4),
        wc.transpose(1, 0, 2, 3, 4),
    )
    state, ys = jax.lax.scan(body, state0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, N)[:, :S]
    return y, state


def wkv6_step(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w_log: jnp.ndarray,
    u: jnp.ndarray,
    state: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. r,k,v,w_log: (B,H,N); state: (B,H,N,N)."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = jnp.exp(w_log.astype(jnp.float32))
    # y = r @ (S + u k v^T)
    y = jnp.einsum("bhn,bhnm->bhm", r, state)
    coef = jnp.sum(r * u[None] * k, axis=-1, keepdims=True)  # (B,H,1)
    y = y + coef * v
    state = w[..., None] * state + k[..., None] * v[..., None, :]
    return y, state


# ---------------------------------------------------------------------------
# Block-level forward
# ---------------------------------------------------------------------------


def _ddlerp(x, x_shift, mix):
    return x + (x_shift - x) * mix.astype(x.dtype)


def rwkv_time_mix(
    cfg,
    p: Params,
    x: jnp.ndarray,
    state0: jnp.ndarray,
    x_prev: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D); state0: (B,H,N,N); x_prev: (B,D) shift carry.
    Returns (y, state, new_x_prev)."""
    B, S, D = x.shape
    s = cfg.ssm
    H, N = D // s.head_dim, s.head_dim
    xs = token_shift(x, x_prev)
    xr = _ddlerp(x, xs, p["mix_r"])
    xk = _ddlerp(x, xs, p["mix_k"])
    xv = _ddlerp(x, xs, p["mix_v"])
    xg = _ddlerp(x, xs, p["mix_g"])
    xw = _ddlerp(x, xs, p["mix_w"])
    r = (xr @ p["wr"]).reshape(B, S, H, N)
    k = (xk @ p["wk"]).reshape(B, S, H, N)
    v = (xv @ p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (Finch): w = exp(-exp(base + lora))
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    w_log = -jnp.exp(p["w_base"][None, None] + lora)  # (B,S,D), negative
    w_log = jnp.clip(w_log, -8.0, -1e-5).reshape(B, S, H, N)

    y, state = wkv6_chunked(r, k, v, w_log, p["bonus"], state0, chunk=s.chunk_size)
    y = y.reshape(B, S, D)
    # per-head group norm
    yh = y.reshape(B, S, H, N)
    yh = layer_norm(yh, None, None)
    y = yh.reshape(B, S, D) * p["ln_x_scale"] + p["ln_x_bias"]
    y = (y.astype(x.dtype) * g) @ p["wo"]
    return y, state, x[:, -1]


def rwkv_time_mix_step(
    cfg, p: Params, x: jnp.ndarray, state: jnp.ndarray, x_prev: jnp.ndarray
):
    """Decode step. x: (B,D). Returns (y (B,D), state, new_x_prev)."""
    B, D = x.shape
    s = cfg.ssm
    H, N = D // s.head_dim, s.head_dim
    xr = _ddlerp(x, x_prev, p["mix_r"])
    xk = _ddlerp(x, x_prev, p["mix_k"])
    xv = _ddlerp(x, x_prev, p["mix_v"])
    xg = _ddlerp(x, x_prev, p["mix_g"])
    xw = _ddlerp(x, x_prev, p["mix_w"])
    r = (xr @ p["wr"]).reshape(B, H, N)
    k = (xk @ p["wk"]).reshape(B, H, N)
    v = (xv @ p["wv"]).reshape(B, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    w_log = -jnp.exp(p["w_base"][None] + lora)
    w_log = jnp.clip(w_log, -8.0, -1e-5).reshape(B, H, N)
    y, state = wkv6_step(r, k, v, w_log, p["bonus"], state)
    yh = layer_norm(y.reshape(B, H, N), None, None)
    y = yh.reshape(B, D) * p["ln_x_scale"] + p["ln_x_bias"]
    y = (y.astype(x.dtype) * g) @ p["wo"]
    return y, state, x


def rwkv_channel_mix(
    cfg, p: Params, x: jnp.ndarray, x_prev: Optional[jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) (or (B,D) with x_prev for decode). Returns (y, new_x_prev)."""
    if x.ndim == 2:
        xs = x_prev
        xk = _ddlerp(x, xs, p["mix_ck"])
        xr = _ddlerp(x, xs, p["mix_cr"])
        kk = jax.nn.relu(xk @ p["ck"])
        y = jax.nn.sigmoid(xr @ p["cr"]) * ((kk * kk) @ p["cv"])
        return y, x
    xs = token_shift(x, x_prev)
    xk = _ddlerp(x, xs, p["mix_ck"])
    xr = _ddlerp(x, xs, p["mix_cr"])
    kk = jax.nn.relu(xk @ p["ck"])
    y = jax.nn.sigmoid(xr @ p["cr"]) * ((kk * kk) @ p["cv"])
    return y, x[:, -1]

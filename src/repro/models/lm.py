"""Unified language model: one init/forward/prefill/decode API for every
assigned architecture family (dense, moe, ssm, hybrid, vlm, audio enc-dec).

Layer stacks run under ``lax.scan`` with stacked per-layer parameters
(leading L axis) — production pattern: O(1) HLO size in depth, FSDP
all-gathers live inside the loop body (roofline.py multiplies while-body
costs by trip count, so accounting stays exact).

Caches are explicit pytrees (see ``init_cache``), so serving code jits
``decode_step`` with donated cache buffers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import (
    Params,
    apply_norm,
    dense_init,
    dtype_of,
    mlp_forward,
    mlp_init,
    norm_param_init,
    split_keys,
)
from repro.models.rope import positions_for_rope

Batch = Dict[str, jnp.ndarray]
Cache = Dict[str, jnp.ndarray]


@dataclass(frozen=True)
class ParallelCtx:
    """Mesh context threaded through forwards (None mesh = single device)."""

    mesh: Any = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    ep_axis: str = "model"
    remat: str = "none"
    seq_parallel: bool = False

    @property
    def batch_spec(self):
        if len(self.dp_axes) == 1:
            return self.dp_axes[0]
        return tuple(self.dp_axes)

    @property
    def seq_spec(self):
        return self.tp_axis if (self.seq_parallel and self.tp_axis) else None


def _constrain(x, ctx: Optional[ParallelCtx], spec):
    if ctx is None or ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, spec)
    )


def _maybe_remat(fn, ctx: Optional[ParallelCtx]):
    mode = ctx.remat if ctx is not None else "none"
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full


# ===========================================================================
# Parameter initialization
# ===========================================================================


def _norm_params(cfg, key_prefix: str) -> Params:
    out = {}
    base = norm_param_init(cfg, cfg.d_model)
    for k, v in base.items():
        out[f"{key_prefix}_{k}"] = v
    return out


def _dense_layer_init(cfg, key) -> Params:
    ks = split_keys(key, 2)
    p: Params = {}
    p.update({f"ln1_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p.update({f"ln2_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p["attn"] = attn.attn_init(cfg, ks[0])
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(cfg, ks[1])
    else:
        p["mlp"] = mlp_init(cfg, ks[1], cfg.d_model, cfg.d_ff)
    return p


def _rwkv_layer_init(cfg, key) -> Params:
    p: Params = {}
    p.update({f"ln1_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p.update({f"ln2_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p["rwkv"] = rwkv_mod.rwkv_init(cfg, key)
    return p


def _mamba_layer_init(cfg, key) -> Params:
    p: Params = {}
    p.update({f"ln1_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p["mamba"] = mamba_init_wrap(cfg, key)
    return p


def mamba_init_wrap(cfg, key):
    return mam.mamba_init(cfg, key)


def _whisper_enc_layer_init(cfg, key) -> Params:
    ks = split_keys(key, 2)
    p: Params = {}
    p.update({f"ln1_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p.update({f"ln2_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p["attn"] = attn.attn_init(cfg, ks[0])
    p["mlp"] = mlp_init(cfg, ks[1], cfg.d_model, cfg.d_ff)
    return p


def _whisper_dec_layer_init(cfg, key) -> Params:
    ks = split_keys(key, 3)
    p: Params = {}
    for nm in ("ln1", "ln2", "ln3"):
        p.update({f"{nm}_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
    p["attn"] = attn.attn_init(cfg, ks[0])
    p["cross"] = attn.cross_attn_init(cfg, ks[1])
    p["mlp"] = mlp_init(cfg, ks[2], cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg, key) -> Params:
    """Random-init parameters; structure is family-dependent but stable."""
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 8)
    p: Params = {"embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt)}
    p.update(_norm_params(cfg, "final"))
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dt)

    if cfg.family == "audio":
        enc = cfg.encoder
        p["enc_blocks"] = _stack(cfg, _whisper_enc_layer_init, ks[2], enc.num_layers)
        p["dec_blocks"] = _stack(cfg, _whisper_dec_layer_init, ks[3], cfg.num_layers)
        p.update({f"enc_final_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
        p["dec_pos"] = dense_init(ks[4], (32_776, cfg.d_model), dt, scale=0.01)
        return p

    if cfg.family == "ssm":  # rwkv6
        p["blocks"] = _stack(cfg, _rwkv_layer_init, ks[2], cfg.num_layers)
        p.update({f"ln0_{k}": v for k, v in norm_param_init(cfg, cfg.d_model).items()})
        return p

    if cfg.family == "hybrid":  # zamba2
        groups = cfg.num_layers // cfg.attn_every
        per = cfg.attn_every

        def group_init(k):
            return _stack(cfg, _mamba_layer_init, k, per)

        p["blocks"] = _stack(cfg, lambda c, k: group_init(k), ks[2], groups)
        p["shared_attn"] = _dense_layer_init(cfg, ks[3])
        return p

    # dense / moe / vlm
    p["blocks"] = _stack(cfg, _dense_layer_init, ks[2], cfg.num_layers)
    return p


def _stack(cfg, layer_init, key, n: int) -> Params:
    keys = jax.random.split(key, n)

    def one(k):
        try:
            return layer_init(cfg, k)
        except TypeError:
            return layer_init(k)

    return jax.vmap(one)(keys)


def abstract_params(cfg) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ===========================================================================
# Cache construction
# ===========================================================================


def init_cache(cfg, batch: int, max_len: int, *, enc_len: int = 0) -> Cache:
    """Zeroed cache pytree for ``batch`` sequences of up to ``max_len``."""
    kv_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else dtype_of(cfg.dtype)
    c: Cache = {"length": jnp.zeros((), jnp.int32)}
    if cfg.family == "audio":
        L = cfg.num_layers
        c["kv_k"] = jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), kv_dt)
        c["kv_v"] = jnp.zeros_like(c["kv_k"])
        T = enc_len or cfg.encoder.num_frames
        c["cross_k"] = jnp.zeros((L, batch, T, cfg.num_kv_heads, cfg.head_dim), kv_dt)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c
    if cfg.family == "ssm":
        s = cfg.ssm
        H = cfg.d_model // s.head_dim
        L = cfg.num_layers
        c["ssm_state"] = jnp.zeros((L, batch, H, s.head_dim, s.head_dim), jnp.float32)
        c["shift_tm"] = jnp.zeros((L, batch, cfg.d_model), dtype_of(cfg.dtype))
        c["shift_cm"] = jnp.zeros_like(c["shift_tm"])
        return c
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in, heads, conv_ch = mam.mamba_dims(cfg)
        G = cfg.num_layers // cfg.attn_every
        per = cfg.attn_every
        c["ssm_state"] = jnp.zeros(
            (G, per, batch, heads, s.head_dim, s.state_dim), jnp.float32
        )
        c["conv"] = jnp.zeros(
            (G, per, batch, s.conv_dim - 1, conv_ch), dtype_of(cfg.dtype)
        )
        c["kv_k"] = jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), kv_dt)
        c["kv_v"] = jnp.zeros_like(c["kv_k"])
        return c
    L = cfg.num_layers
    c["kv_k"] = jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), kv_dt)
    c["kv_v"] = jnp.zeros_like(c["kv_k"])
    return c


def abstract_cache(cfg, batch: int, max_len: int, **kw) -> Cache:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, **kw))


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================


def _embed(cfg, params: Params, batch: Batch, ctx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x (B,S,D), positions) handling the modality stubs."""
    if "embeds" in batch:  # vlm stub frontend: precomputed patch/token embeds
        x = batch["embeds"].astype(dtype_of(cfg.dtype))
        pos = batch.get("positions")
        if pos is None:
            B, S, _ = x.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    else:
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.dtype))
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, pos


def _logits(cfg, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = apply_norm(cfg, params, "final", x)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ w


def _dense_layer_fwd(cfg, p, x, cos, sin, ctx, want_cache):
    h, kv = attn.attention_seq(cfg, p["attn"], apply_norm(cfg, p, "ln1", x), cos, sin)
    x = x + h
    if cfg.moe is not None:
        m, aux = moe_mod.moe_forward(cfg, p["moe"], apply_norm(cfg, p, "ln2", x), ctx)
    else:
        m = mlp_forward(cfg, p["mlp"], apply_norm(cfg, p, "ln2", x))
        aux = jnp.zeros((), jnp.float32)
    x = x + m
    return x, aux, kv


def forward(
    cfg,
    params: Params,
    batch: Batch,
    ctx: Optional[ParallelCtx] = None,
    *,
    want_cache: bool = False,
    cache_len: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Cache]]:
    """Full-sequence forward.

    Returns (logits (B,S,V), aux_loss, cache-or-None). When ``want_cache``,
    the cache covers ``cache_len`` positions (default S) with S filled.
    """
    if cfg.family == "audio":
        return _whisper_forward(cfg, params, batch, ctx, want_cache, cache_len)
    x, pos = _embed(cfg, params, batch, ctx)
    B, S, _ = x.shape
    bspec = None if ctx is None else P(ctx.batch_spec, ctx.seq_spec, None)
    x = _constrain(x, ctx, bspec)
    cos, sin = positions_for_rope(cfg, pos, cfg.head_dim)

    if cfg.family == "ssm":
        x = apply_norm(cfg, params, "ln0", x)
        state0 = jnp.zeros(
            (B, cfg.d_model // cfg.ssm.head_dim, cfg.ssm.head_dim, cfg.ssm.head_dim),
            jnp.float32,
        )

        def body(carry, p):
            xc = carry
            y, st, sh_tm = rwkv_mod.rwkv_time_mix(
                cfg, p["rwkv"], apply_norm(cfg, p, "ln1", xc), state0, None
            )
            xc = xc + y
            y2, sh_cm = rwkv_mod.rwkv_channel_mix(
                cfg, p["rwkv"], apply_norm(cfg, p, "ln2", xc), None
            )
            xc = xc + y2
            xc = _constrain(xc, ctx, bspec)
            out = (st, sh_tm, sh_cm) if want_cache else None
            return xc, out

        x, outs = jax.lax.scan(_maybe_remat(body, ctx), x, params["blocks"])
        logits = _logits(cfg, params, x)
        cache = None
        if want_cache:
            st, sh_tm, sh_cm = outs
            cache = {
                "length": jnp.asarray(S, jnp.int32),
                "ssm_state": st,
                "shift_tm": sh_tm,
                "shift_cm": sh_cm,
            }
        return logits, jnp.zeros((), jnp.float32), cache

    if cfg.family == "hybrid":
        return _hybrid_forward(cfg, params, x, cos, sin, ctx, want_cache, cache_len, S)

    # dense / moe / vlm
    def body(carry, p):
        xc, aux = carry
        xo, a, kv = _dense_layer_fwd(cfg, p, xc, cos, sin, ctx, want_cache)
        xo = _constrain(xo, ctx, bspec)
        return (xo, aux + a), (kv if want_cache else None)

    (x, aux), kvs = jax.lax.scan(
        _maybe_remat(body, ctx), (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    logits = _logits(cfg, params, x)
    cache = None
    if want_cache:
        k_all, v_all = kvs  # (L, B, S, Hkv, hd)
        M = cache_len or S
        kv_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else x.dtype
        if M > S:
            padk = jnp.zeros(
                (cfg.num_layers, B, M - S, cfg.num_kv_heads, cfg.head_dim), kv_dt
            )
            k_all = jnp.concatenate([k_all.astype(kv_dt), padk], axis=2)
            v_all = jnp.concatenate([v_all.astype(kv_dt), padk], axis=2)
        cache = {
            "length": jnp.asarray(S, jnp.int32),
            "kv_k": k_all.astype(kv_dt),
            "kv_v": v_all.astype(kv_dt),
        }
    return logits, aux, cache


def _hybrid_forward(cfg, params, x, cos, sin, ctx, want_cache, cache_len, S):
    B = x.shape[0]
    s = cfg.ssm
    d_in, heads, conv_ch = mam.mamba_dims(cfg)
    bspec = None if ctx is None else P(ctx.batch_spec, ctx.seq_spec, None)
    shared = params["shared_attn"]
    state0 = jnp.zeros((B, heads, s.head_dim, s.state_dim), jnp.float32)

    def body(carry, p_group):
        xc, aux = carry
        states = []
        convs = []
        for i in range(cfg.attn_every):
            p_l = jax.tree.map(lambda a: a[i], p_group)
            y, st, cv = mam.mamba_forward(
                cfg, p_l["mamba"], apply_norm(cfg, p_l, "ln1", xc), state0, None
            )
            xc = xc + y
            states.append(st)
            convs.append(cv)
        xo, a, kv = _dense_layer_fwd(cfg, shared, xc, cos, sin, ctx, True)
        xo = _constrain(xo, ctx, bspec)
        out = None
        if want_cache:
            out = (jnp.stack(states), jnp.stack(convs), kv)
        return (xo, aux + a), out

    (x, aux), outs = jax.lax.scan(
        _maybe_remat(body, ctx), (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    logits = _logits(cfg, params, x)
    cache = None
    if want_cache:
        st, cv, (k_all, v_all) = outs
        M = cache_len or S
        kv_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else x.dtype
        if M > S:
            G = cfg.num_layers // cfg.attn_every
            padk = jnp.zeros((G, B, M - S, cfg.num_kv_heads, cfg.head_dim), kv_dt)
            k_all = jnp.concatenate([k_all.astype(kv_dt), padk], axis=2)
            v_all = jnp.concatenate([v_all.astype(kv_dt), padk], axis=2)
        cache = {
            "length": jnp.asarray(S, jnp.int32),
            "ssm_state": st,
            "conv": cv[:, :, :, -(s.conv_dim - 1) :, :],
            "kv_k": k_all.astype(kv_dt),
            "kv_v": v_all.astype(kv_dt),
        }
    return logits, aux, cache


def _whisper_forward(cfg, params, batch, ctx, want_cache, cache_len):
    frames = batch["frames"].astype(dtype_of(cfg.dtype))  # (B, T, D) stub frontend
    tokens = batch["tokens"]
    B, T, _ = frames.shape
    S = tokens.shape[1]
    # sinusoidal encoder positions
    pos = jnp.arange(T)
    half = cfg.d_model // 2
    freq = jnp.exp(-jnp.arange(half) * (jnp.log(10_000.0) / (half - 1)))
    sinus = jnp.concatenate(
        [jnp.sin(pos[:, None] * freq), jnp.cos(pos[:, None] * freq)], -1
    )
    xe = frames + sinus[None].astype(frames.dtype)

    def enc_body(carry, p):
        xc = carry
        h, _ = attn.attention_seq(
            cfg, p["attn"], apply_norm(cfg, p, "ln1", xc), None, None, causal=False
        )
        xc = xc + h
        xc = xc + mlp_forward(cfg, p["mlp"], apply_norm(cfg, p, "ln2", xc))
        return xc, None

    xe, _ = jax.lax.scan(enc_body, xe, params["enc_blocks"])
    enc_out = apply_norm(cfg, params, "enc_final", xe)

    xd = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.dtype))
    xd = xd + params["dec_pos"][:S][None].astype(xd.dtype)

    def dec_body(carry, p):
        xc = carry
        h, kv = attn.attention_seq(
            cfg, p["attn"], apply_norm(cfg, p, "ln1", xc), None, None, causal=True
        )
        xc = xc + h
        ck, cv = attn.cross_attention_kv(cfg, p["cross"], enc_out)
        xc = xc + attn.cross_attention(
            cfg, p["cross"], apply_norm(cfg, p, "ln2", xc), ck, cv
        )
        xc = xc + mlp_forward(cfg, p["mlp"], apply_norm(cfg, p, "ln3", xc))
        return xc, (kv, (ck, cv)) if want_cache else None

    xd, outs = jax.lax.scan(_maybe_remat(dec_body, ctx), xd, params["dec_blocks"])
    logits = _logits(cfg, params, xd)
    cache = None
    if want_cache:
        (k_all, v_all), (ck_all, cv_all) = outs
        M = cache_len or S
        kv_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else xd.dtype
        if M > S:
            padk = jnp.zeros(
                (cfg.num_layers, B, M - S, cfg.num_kv_heads, cfg.head_dim), kv_dt
            )
            k_all = jnp.concatenate([k_all.astype(kv_dt), padk], axis=2)
            v_all = jnp.concatenate([v_all.astype(kv_dt), padk], axis=2)
        cache = {
            "length": jnp.asarray(S, jnp.int32),
            "kv_k": k_all.astype(kv_dt),
            "kv_v": v_all.astype(kv_dt),
            "cross_k": ck_all.astype(kv_dt),
            "cross_v": cv_all.astype(kv_dt),
        }
    return logits, jnp.zeros((), jnp.float32), cache


def prefill(cfg, params, batch, ctx=None, cache_len=None):
    logits, aux, cache = forward(
        cfg, params, batch, ctx, want_cache=True, cache_len=cache_len
    )
    return logits, cache


def prefill_extend(
    cfg,
    params: Params,
    batch: Batch,
    prefix_k: jnp.ndarray,
    prefix_v: jnp.ndarray,
    prefix_len: int,
    ctx: Optional[ParallelCtx] = None,
    cache_len: Optional[int] = None,
) -> Tuple[jnp.ndarray, Cache]:
    """Prefill ONLY a suffix against an already-built prefix KV.

    The suffix tokens (``batch["tokens"]``, (B, S)) are run at positions
    ``[prefix_len, prefix_len + S)`` attending over the prefix K/V plus
    themselves causally; the returned cache is the same dense pytree a
    full ``prefill`` of prefix+suffix would produce (prefix K/V copied
    into place), so ``decode_step`` continues transparently.

    prefix_k/v: (L, B, Sp, Hkv, hd) post-RoPE (Sp >= prefix_len; the
    overhang is page padding). prefix_len must be static under jit.
    KV-recurrent families keep per-token state, so a stored prefix can't
    be re-entered mid-stream — dense / moe / vlm only.
    """
    if cfg.family in ("audio", "ssm", "hybrid"):
        raise NotImplementedError(
            f"prefix extension requires a pure-KV cache; family "
            f"{cfg.family!r} carries recurrent state"
        )
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.dtype))
    pos = jnp.broadcast_to(
        prefix_len + jnp.arange(S, dtype=jnp.int32)[None], (B, S)
    )
    bspec = None if ctx is None else P(ctx.batch_spec, ctx.seq_spec, None)
    x = _constrain(x, ctx, bspec)
    cos, sin = positions_for_rope(cfg, pos, cfg.head_dim)

    def body(carry, inp):
        xc, aux = carry
        p, pk, pv = inp
        h, kv = attn.attention_extend(
            cfg, p["attn"], apply_norm(cfg, p, "ln1", xc), cos, sin,
            pk, pv, prefix_len,
        )
        xc = xc + h
        if cfg.moe is not None:
            m, a = moe_mod.moe_forward(cfg, p["moe"], apply_norm(cfg, p, "ln2", xc), ctx)
        else:
            m = mlp_forward(cfg, p["mlp"], apply_norm(cfg, p, "ln2", xc))
            a = jnp.zeros((), jnp.float32)
        xc = xc + m
        xc = _constrain(xc, ctx, bspec)
        return (xc, aux + a), kv

    (x, _), kvs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], prefix_k, prefix_v),
    )
    logits = _logits(cfg, params, x)
    k_suf, v_suf = kvs  # (L, B, S, Hkv, hd)
    total = prefix_len + S
    M = cache_len or total
    assert M >= total, (M, total)
    kv_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else x.dtype

    def assemble(pre, suf):
        parts = [pre[:, :, :prefix_len].astype(kv_dt), suf.astype(kv_dt)]
        if M > total:
            parts.append(jnp.zeros(
                (cfg.num_layers, B, M - total, cfg.num_kv_heads, cfg.head_dim),
                kv_dt,
            ))
        return jnp.concatenate(parts, axis=2)

    cache = {
        "length": jnp.asarray(total, jnp.int32),
        "kv_k": assemble(prefix_k, k_suf),
        "kv_v": assemble(prefix_v, v_suf),
    }
    return logits, cache


# ===========================================================================
# Decode step
# ===========================================================================


def decode_step(
    cfg,
    params: Params,
    cache: Cache,
    tokens: jnp.ndarray,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jnp.ndarray, Cache]:
    """One decode step. tokens: (B, 1) int32 (or embeds for vlm handled
    upstream). Returns (logits (B, 1, V), new cache)."""
    length = cache["length"]
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype_of(cfg.dtype))
    pos = jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32)
    cos, sin = positions_for_rope(cfg, pos, cfg.head_dim)

    if cfg.family == "ssm":
        x2 = apply_norm(cfg, params, "ln0", x)[:, 0]  # (B, D)

        def body(carry, inp):
            xc = carry
            p, st, sh_tm, sh_cm = inp
            xn = apply_norm(cfg, p, "ln1", xc)
            y, st, sh_tm = rwkv_mod.rwkv_time_mix_step(cfg, p["rwkv"], xn, st, sh_tm)
            xc = xc + y
            xn = apply_norm(cfg, p, "ln2", xc)
            y2, sh_cm = rwkv_mod.rwkv_channel_mix(cfg, p["rwkv"], xn, sh_cm)
            xc = xc + y2
            return xc, (st, sh_tm, sh_cm)

        x2, (st, sh_tm, sh_cm) = jax.lax.scan(
            body, x2, (params["blocks"], cache["ssm_state"], cache["shift_tm"], cache["shift_cm"])
        )
        logits = _logits(cfg, params, x2[:, None])
        new_cache = {
            "length": length + 1,
            "ssm_state": st,
            "shift_tm": sh_tm,
            "shift_cm": sh_cm,
        }
        return logits, new_cache

    if cfg.family == "hybrid":
        return _hybrid_decode(cfg, params, cache, x, cos, sin, ctx)

    if cfg.family == "audio":
        return _whisper_decode(cfg, params, cache, x, ctx)

    def body(carry, inp):
        xc = carry
        p, ck, cv = inp
        h, ck, cv = attn.attention_decode(
            cfg, p["attn"], apply_norm(cfg, p, "ln1", xc), cos, sin, ck, cv, length
        )
        xc = xc + h
        if cfg.moe is not None:
            m, _ = moe_mod.moe_forward(cfg, p["moe"], apply_norm(cfg, p, "ln2", xc), ctx)
        else:
            m = mlp_forward(cfg, p["mlp"], apply_norm(cfg, p, "ln2", xc))
        xc = xc + m
        return xc, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["kv_k"], cache["kv_v"]))
    logits = _logits(cfg, params, x)
    return logits, {"length": length + 1, "kv_k": ck, "kv_v": cv}


def _hybrid_decode(cfg, params, cache, x, cos, sin, ctx):
    length = cache["length"]
    shared = params["shared_attn"]
    x2 = x[:, 0]

    def body(carry, inp):
        xc = carry
        p_group, st_g, cv_g, ck, cvv = inp
        sts = []
        cvs = []
        for i in range(cfg.attn_every):
            p_l = jax.tree.map(lambda a: a[i], p_group)
            xn = apply_norm(cfg, p_l, "ln1", xc)
            y, st, cvx = mam.mamba_step(cfg, p_l["mamba"], xn, st_g[i], cv_g[i])
            xc = xc + y
            sts.append(st)
            cvs.append(cvx)
        # shared attention block (on (B,1,D))
        x3 = xc[:, None]
        h, ck, cvv = attn.attention_decode(
            cfg, shared["attn"], apply_norm(cfg, shared, "ln1", x3), cos, sin, ck, cvv, length
        )
        x3 = x3 + h
        x3 = x3 + mlp_forward(cfg, shared["mlp"], apply_norm(cfg, shared, "ln2", x3))
        return x3[:, 0], (jnp.stack(sts), jnp.stack(cvs), ck, cvv)

    x2, (st, cv, ck, cvv) = jax.lax.scan(
        body,
        x2,
        (params["blocks"], cache["ssm_state"], cache["conv"], cache["kv_k"], cache["kv_v"]),
    )
    logits = _logits(cfg, params, x2[:, None])
    return logits, {
        "length": length + 1,
        "ssm_state": st,
        "conv": cv,
        "kv_k": ck,
        "kv_v": cvv,
    }


def _whisper_decode(cfg, params, cache, x, ctx):
    length = cache["length"]
    pos_emb = jax.lax.dynamic_index_in_dim(params["dec_pos"], length, keepdims=True)
    x = x + pos_emb[None].astype(x.dtype)

    def body(carry, inp):
        xc = carry
        p, ck, cv, crk, crv = inp
        h, ck, cv = attn.attention_decode(
            cfg, p["attn"], apply_norm(cfg, p, "ln1", xc), None, None, ck, cv, length
        )
        xc = xc + h
        xc = xc + attn.cross_attention(
            cfg, p["cross"], apply_norm(cfg, p, "ln2", xc), crk, crv
        )
        xc = xc + mlp_forward(cfg, p["mlp"], apply_norm(cfg, p, "ln3", xc))
        return xc, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body,
        x,
        (
            params["dec_blocks"],
            cache["kv_k"],
            cache["kv_v"],
            cache["cross_k"],
            cache["cross_v"],
        ),
    )
    logits = _logits(cfg, params, x)
    return logits, {
        "length": length + 1,
        "kv_k": ck,
        "kv_v": cv,
        "cross_k": cache["cross_k"],
        "cross_v": cache["cross_v"],
    }

"""Mixture-of-Experts layers.

Three execution paths, one parameterization:

  * ``moe_forward_grouped`` — exact dropless top-k MoE: sort tokens by expert
    and run grouped matmuls via ``jax.lax.ragged_dot``. Single-device
    semantics; serves as the numerical oracle for the other two paths.
  * ``moe_forward_dense`` — GShard-style capacity-factor dispatch with
    one-hot einsums. Fully auto-partitioned by pjit (no shard_map); robust
    baseline, but dispatch FLOPs scale with group_size * E * capacity (this
    is the classic GShard overhead — measured in the roofline table, and the
    motivation for the EP path).
  * ``moe_forward_ep`` — expert parallelism: experts sharded over the
    ``ep_axis`` mesh axis; tokens routed to their expert's shard with
    ``all_to_all`` inside ``shard_map``; local grouped matmul via ragged_dot
    (TPU Megablox analogue). Capacity-based (static shapes, TPU-friendly).

All paths return ``(y, aux_loss)`` where aux_loss is the standard
load-balancing loss E * sum_e(f_e * p_e).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import mesh_compat
from repro.models.common import Params, dense_init, dtype_of, split_keys


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def moe_init(cfg, key) -> Params:
    e = cfg.moe
    d = cfg.d_model
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 4)
    p: Params = {
        "router": dense_init(ks[0], (d, e.num_experts), jnp.float32, scale=0.02),
        "w_up": dense_init(ks[2], (e.num_experts, d, e.d_ff_expert), dt),
        "w_down": dense_init(ks[3], (e.num_experts, e.d_ff_expert, d), dt),
    }
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = dense_init(ks[1], (e.num_experts, d, e.d_ff_expert), dt)
    return p


def _routing(cfg, p: Params, x2d: jnp.ndarray):
    """x2d: (T, D) -> (probs (T,E) f32, topk_w (T,K), topk_idx (T,K), aux)."""
    e = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, e.experts_per_token)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss
    T = x2d.shape[0]
    onehot = jax.nn.one_hot(topk_idx, e.num_experts, dtype=jnp.float32)  # (T,K,E)
    f = jnp.sum(onehot, axis=(0, 1)) / (T * e.experts_per_token)  # fraction routed
    pbar = jnp.mean(probs, axis=0)
    aux = e.num_experts * jnp.sum(f * pbar) * e.aux_loss_weight
    return probs, topk_w, topk_idx, aux


def _expert_ffn(cfg, p: Params, h: jnp.ndarray, group_sizes: jnp.ndarray):
    """Grouped FFN via ragged_dot. h: (M, D) sorted by expert; returns (M, D)."""
    if cfg.mlp_act == "swiglu":
        g = jax.lax.ragged_dot(h, p["w_gate"], group_sizes)
        u = jax.lax.ragged_dot(h, p["w_up"], group_sizes)
        a = jax.nn.silu(g) * u
    else:
        u = jax.lax.ragged_dot(h, p["w_up"], group_sizes)
        a = jax.nn.relu(u) ** 2 if cfg.mlp_act == "squared_relu" else jax.nn.gelu(u)
    return jax.lax.ragged_dot(a, p["w_down"], group_sizes)


# ---------------------------------------------------------------------------
# Exact grouped path (oracle)
# ---------------------------------------------------------------------------


def moe_forward_grouped(cfg, p: Params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless: every routed (token, expert) pair is computed."""
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    K = e.experts_per_token
    x2d = x.reshape(T, D)
    _, topk_w, topk_idx, aux = _routing(cfg, p, x2d)

    flat_expert = topk_idx.reshape(-1)  # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_expert)  # stable
    sorted_tokens = flat_token[order]
    h = x2d[sorted_tokens]  # (T*K, D)
    group_sizes = jnp.bincount(flat_expert, length=e.num_experts).astype(jnp.int32)
    out_sorted = _expert_ffn(cfg, p, h, group_sizes)  # (T*K, D)
    w_sorted = topk_w.reshape(-1)[order]
    contrib = out_sorted * w_sorted[:, None].astype(out_sorted.dtype)
    y2d = jnp.zeros((T, D), contrib.dtype).at[sorted_tokens].add(contrib)
    return y2d.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# GShard dense dispatch (capacity-based, pjit-auto-partitioned)
# ---------------------------------------------------------------------------


def moe_forward_dense(
    cfg, p: Params, x: jnp.ndarray, *, capacity: Optional[int] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    e = cfg.moe
    B, S, D = x.shape
    K = e.experts_per_token
    G = B  # one dispatch group per batch row (keeps dispatch local under DP)
    Tg = S
    x3d = x.reshape(G, Tg, D)
    x2d = x.reshape(G * Tg, D)
    _, topk_w, topk_idx, aux = _routing(cfg, p, x2d)
    topk_w = topk_w.reshape(G, Tg, K)
    topk_idx = topk_idx.reshape(G, Tg, K)

    if capacity is None:
        capacity = int(Tg * K / e.num_experts * e.capacity_factor) + 1
    C = capacity

    # position of each (token, k) within its expert queue, per group
    onehot = jax.nn.one_hot(topk_idx, e.num_experts, dtype=jnp.int32)  # (G,Tg,K,E)
    flat = onehot.reshape(G, Tg * K, e.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, Tg*K, E) position in queue
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, Tg, K)  # (G,Tg,K)
    keep = pos < C

    # dispatch/combine tensors: (G, Tg, E, C)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=jnp.float32)[..., :C]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(jnp.float32), pos_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(jnp.float32), pos_oh, topk_w)

    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), x3d)  # (G,E,C,D)
    if cfg.mlp_act == "swiglu":
        gg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
        uu = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        a = jax.nn.silu(gg) * uu
    else:
        uu = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        a = jax.nn.relu(uu) ** 2 if cfg.mlp_act == "squared_relu" else jax.nn.gelu(uu)
    ye = jnp.einsum("gecf,efd->gecd", a, p["w_down"])  # (G,E,C,D)
    y = jnp.einsum("gtec,gecd->gtd", comb.astype(ye.dtype), ye)
    return y.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map + all_to_all + ragged_dot)
# ---------------------------------------------------------------------------


def moe_forward_ep(
    cfg,
    p: Params,
    x: jnp.ndarray,
    *,
    mesh,
    ep_axis: str = "model",
    dp_axes: Tuple[str, ...] = ("data",),
    capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Experts sharded over ``ep_axis``; tokens sharded over ``dp_axes``.

    Per-device: route local tokens, bucket them by destination expert-shard
    (capacity-limited), all_to_all across ep_axis, run local experts via
    ragged_dot, all_to_all back, combine.
    """
    e = cfg.moe
    B, S, D = x.shape
    n_ep = 1
    for ax, sz in zip(mesh.axis_names, mesh.devices.shape):
        if ax == ep_axis:
            n_ep = sz
    assert e.num_experts % n_ep == 0, (e.num_experts, n_ep)
    e_loc = e.num_experts // n_ep

    K = e.experts_per_token

    E = e.num_experts
    a2a_dt = {"auto": None, "bfloat16": jnp.bfloat16,
              "float8_e4m3fn": jnp.float8_e4m3fn,
              "float32": jnp.float32}[e.a2a_dtype]

    def local_fn(p_loc, x_loc):
        """x_loc: (B_loc, S, D); expert weights p_loc sharded: (e_loc, D, F).

        Per-EXPERT capacity buckets (not per-shard): the expert compute is a
        batched matmul einsum('ecd,edf->ecf') — static shapes, MXU-friendly
        (Megablox-equivalent), and FLOP-exact in the HLO (the CPU lowering of
        ragged_dot dense-expands over experts, inflating accounting 24x).

        Perf knobs: dispatch payloads cross the ICI in ``a2a_dtype`` (fp8
        halves bytes, DeepSeek-V3-style); ``dispatch_chunks`` splits the
        token stream to bound the transient buffer footprint.
        """
        Bl, Sl, Dl = x_loc.shape
        T_all = Bl * Sl
        x2d_all = x_loc.reshape(T_all, D)
        n_chunks = max(1, e.dispatch_chunks)
        assert T_all % n_chunks == 0, (T_all, n_chunks)
        ys = []
        aux_out = None
        for ci in range(n_chunks):
            y, aux = _dispatch_block(
                p_loc, x2d_all[ci * (T_all // n_chunks):(ci + 1) * (T_all // n_chunks)]
            )
            ys.append(y)
            aux_out = aux
        y2d = jnp.concatenate(ys, axis=0) if n_chunks > 1 else ys[0]
        return y2d.reshape(Bl, Sl, D).astype(x_loc.dtype), aux_out

    def _dispatch_block(p_loc, x2d):
        T = x2d.shape[0]
        _, topk_w, topk_idx, aux = _routing(cfg, {**p_loc, "router": p_loc["router"]}, x2d)
        aux = jax.lax.pmean(aux, ep_axis)
        for ax in dp_axes:
            aux = jax.lax.pmean(aux, ax)

        if capacity is None:
            cap = int(T * K / E * e.capacity_factor) + 1
        else:
            cap = capacity
        flat_e = topk_idx.reshape(-1)  # (T*K,) global expert ids
        flat_t = jnp.repeat(jnp.arange(T), K)
        flat_w = topk_w.reshape(-1)
        # position within expert via sort-rank (O(M log M), no M*E one-hot)
        M0 = T * K
        order = jnp.argsort(flat_e)  # stable
        sorted_e = flat_e[order]
        idx = jnp.arange(M0)
        first_of_group = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_sorted = idx - first_of_group
        pos = jnp.zeros((M0,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
        keep = pos < cap
        slot = jnp.where(keep, flat_e * cap + pos, E * cap)  # overflow -> dropped

        # send buffer: one bucket per (global expert, capacity slot); payload
        # crosses the ICI in a2a_dtype. fp8 uses per-token scales
        # (DeepSeek-V3-style quantized dispatch: +4 bytes/row of scale vs
        # 2x fewer payload bytes).
        dt_wire = a2a_dt if a2a_dt is not None else x2d.dtype
        fp8 = dt_wire == jnp.float8_e4m3fn

        def quant(rows):
            if not fp8:
                return rows.astype(dt_wire), None
            scale = jnp.max(jnp.abs(rows.astype(jnp.float32)), -1, keepdims=True)
            scale = jnp.maximum(scale, 1e-6) / 240.0
            return (rows / scale).astype(a2a_dt), scale[:, 0]

        def dequant(rows, scale, dt):
            if not fp8:
                return rows.astype(dt)
            return (rows.astype(jnp.float32) * scale[:, None]).astype(dt)

        payload, pscale = quant(x2d[flat_t])
        send = jnp.zeros((E * cap + 1, D), dt_wire).at[slot].set(payload)
        send = send[: E * cap].reshape(n_ep, e_loc * cap, D)
        if fp8:
            sscale = jnp.zeros((E * cap + 1,), jnp.float32).at[slot].set(pscale)
            sscale = sscale[: E * cap].reshape(n_ep, e_loc * cap)

        # all_to_all over the EP axis: device p receives every shard's buckets
        # for ITS experts: (n_ep src, e_loc*cap, D)
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0, tiled=True)
        if fp8:
            rscale = jax.lax.all_to_all(sscale, ep_axis, split_axis=0,
                                        concat_axis=0, tiled=True)
            recv = dequant(recv.reshape(-1, D), rscale.reshape(-1), x2d.dtype)
            recv = recv.reshape(n_ep, e_loc * cap, D)
        xe = recv.astype(x2d.dtype).reshape(n_ep, e_loc, cap, D).transpose(1, 0, 2, 3)
        xe = xe.reshape(e_loc, n_ep * cap, D)  # (E_loc, C', D)

        # batched expert FFN on the MXU
        if cfg.mlp_act == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", xe, p_loc["w_gate"])
            uu = jnp.einsum("ecd,edf->ecf", xe, p_loc["w_up"])
            a = jax.nn.silu(g) * uu
        else:
            uu = jnp.einsum("ecd,edf->ecf", xe, p_loc["w_up"])
            a = jax.nn.relu(uu) ** 2 if cfg.mlp_act == "squared_relu" else jax.nn.gelu(uu)
        ye = jnp.einsum("ecf,efd->ecd", a, p_loc["w_down"])  # (E_loc, C', D)

        # route back to the source shards (same quantized payload scheme)
        yq, yscale = quant(ye.reshape(-1, D))
        back = yq.reshape(e_loc, n_ep, cap, D).transpose(1, 0, 2, 3)
        back = back.reshape(n_ep, e_loc * cap, D)
        back = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0, tiled=True)
        if fp8:
            bscale = yscale.reshape(e_loc, n_ep, cap).transpose(1, 0, 2)
            bscale = jax.lax.all_to_all(bscale.reshape(n_ep, e_loc * cap), ep_axis,
                                        split_axis=0, concat_axis=0, tiled=True)
            back_rows = dequant(back.reshape(E * cap, D), bscale.reshape(-1),
                                x2d.dtype)
        else:
            back_rows = back.reshape(E * cap, D).astype(x2d.dtype)
        back2d = jnp.concatenate(
            [back_rows, jnp.zeros((1, D), x2d.dtype)], axis=0
        )
        gathered = back2d[slot]  # (T*K, D); dropped slots read the zero row
        contrib = gathered * flat_w[:, None].astype(x2d.dtype)
        y2d = jnp.zeros((T, D), contrib.dtype).at[flat_t].add(contrib)
        return y2d, aux

    # replicate router over ep; shard experts over ep
    pspec_params = {
        "router": P(),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }
    if "w_gate" in p:
        pspec_params["w_gate"] = P(ep_axis, None, None)
    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None)

    fn = mesh_compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(pspec_params, batch_spec),
        out_specs=(batch_spec, P()),
        check_vma=False,
    )
    return fn({k: v for k, v in p.items()}, x)


def moe_forward(cfg, p: Params, x: jnp.ndarray, parallel_ctx=None):
    """Dispatch on cfg.moe.mode (+ availability of a mesh)."""
    mode = cfg.moe.mode
    if mode == "ep" and parallel_ctx is not None and parallel_ctx.mesh is not None:
        return moe_forward_ep(
            cfg,
            p,
            x,
            mesh=parallel_ctx.mesh,
            ep_axis=parallel_ctx.ep_axis,
            dp_axes=parallel_ctx.dp_axes,
        )
    if mode == "ep":
        # no mesh (smoke tests): exact grouped path, same math minus collectives
        return moe_forward_grouped(cfg, p, x)
    return moe_forward_dense(cfg, p, x)

"""Attention: GQA projections (+qk-norm, +bias), flash-pattern causal
attention for train/prefill, and single-token decode attention.

Design notes (TPU adaptation):
  * The train/prefill path is a *chunked online-softmax* ("flash") attention
    written with a ``lax.scan`` over the lower-triangular block list, so the
    (S, S) score matrix is never materialized and — because every scanned
    block does identical work — the HLO while-loop trip count exactly equals
    the number of causal blocks (roofline.py multiplies body FLOPs by trip
    count, so causal FLOP accounting is exact: nq*(nq+1)/2 blocks).
  * The Pallas kernel (kernels/flash_attention.py) implements the same tiling
    for real TPUs; this jnp version is the XLA path used by the dry-run and
    as the numerical oracle.
  * Decode is a plain einsum over the KV cache (memory-bound; no benefit from
    chunking at batch sizes of interest) — kernels/decode_attention.py is the
    TPU kernel analogue.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, dtype_of, rms_norm, split_keys
from repro.models.rope import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def attn_init(cfg, key, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, cfg.q_dim), dt),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dt),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dt),
        "wo": dense_init(ks[3], (cfg.q_dim, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm_scale"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def _project_qkv(cfg, p: Params, x: jnp.ndarray):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm_scale"])
        k = rms_norm(k, p["k_norm_scale"])
    return q, k, v


# ---------------------------------------------------------------------------
# Chunked causal flash attention (jnp / XLA path)
# ---------------------------------------------------------------------------


def flash_attention_jnp(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    chunk: int = 2048,
    causal: bool = True,
    logit_softcap: float = 0.0,
) -> jnp.ndarray:
    """Online-softmax attention over (chunk x chunk) blocks.

    q: (B, S, Hq, hd); k, v: (B, S, Hkv, hd); Hq % Hkv == 0 (GQA).
    Returns (B, S, Hq, hd). fp32 accumulators throughout.
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        zq = jnp.zeros((B, pad, Hq, hd), q.dtype)
        zk = jnp.zeros((B, pad, Hkv, hd), k.dtype)
        q = jnp.concatenate([q, zq], axis=1)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    Sp = S + pad
    n = Sp // C
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qc = q.reshape(B, n, C, Hkv, G, hd)
    kc = k.reshape(B, n, C, Hkv, hd)
    vc = v.reshape(B, n, C, Hkv, hd)

    if causal:
        pairs = [(i, j) for i in range(n) for j in range(i + 1)]
    else:
        pairs = [(i, j) for i in range(n) for j in range(n)]
    qi = jnp.array([p[0] for p in pairs], jnp.int32)
    kj = jnp.array([p[1] for p in pairs], jnp.int32)

    # block-local masks
    row = jnp.arange(C)[:, None]
    col = jnp.arange(C)[None, :]
    tri = (col > row).astype(jnp.float32) * NEG_INF  # (C, C) intra-block causal

    m0 = jnp.full((n, B, Hkv, G, C), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n, B, Hkv, G, C), jnp.float32)
    a0 = jnp.zeros((n, B, Hkv, G, C, hd), jnp.float32)

    def body(carry, idx):
        m, l, acc = carry
        i, j = idx
        qb = jax.lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        # scores: (B, Hkv, G, Cq, Ck)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qb.astype(jnp.float32), kb.astype(jnp.float32)
        )
        s = s * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        if causal:
            s = s + jnp.where(i == j, tri, 0.0)
        # mask padded keys (global col index >= S)
        if pad:
            gcol = j * C + jnp.arange(C)
            s = s + jnp.where(gcol >= S, NEG_INF, 0.0)[None, None, None, None, :]
        m_old = jax.lax.dynamic_index_in_dim(m, i, axis=0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, i, axis=0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, i, axis=0, keepdims=False)
        m_blk = jnp.max(s, axis=-1)  # (B, Hkv, G, Cq)
        m_new = jnp.maximum(m_old, m_blk)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_old * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        a_new = a_old * alpha[..., None] + pv
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, axis=0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (qi, kj))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (n, B, Hkv, G, C, hd) -> (B, S, Hq, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, Hq, hd)
    return out[:, :S].astype(q.dtype)


def naive_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    logit_softcap: float = 0.0,
    kv_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Reference softmax attention (materializes scores). Small shapes only."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    M = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if causal:
        mask = jnp.arange(M)[None, :] > jnp.arange(S)[:, None]
        s = s + mask * NEG_INF
    if kv_mask is not None:  # (B, M) valid-key mask
        s = s + jnp.where(kv_mask, 0.0, NEG_INF)[:, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Layer-level entry points
# ---------------------------------------------------------------------------


def attention_seq(
    cfg,
    p: Params,
    x: jnp.ndarray,
    cos,
    sin,
    *,
    causal: bool = True,
    use_flash: bool = True,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if getattr(cfg, "use_pallas", False) and S % 128 == 0 and causal:
        from repro.kernels import ops as kops

        o = kops.flash_attention_op(q, k, v, causal=True)
    elif use_flash and S > 512:
        o = flash_attention_jnp(
            q, k, v, causal=causal, logit_softcap=cfg.attn_logit_softcap,
            chunk=getattr(cfg, "attn_chunk", 2048),
        )
    else:
        o = naive_attention(q, k, v, causal=causal, logit_softcap=cfg.attn_logit_softcap)
    out = o.reshape(B, S, cfg.q_dim) @ p["wo"]
    return out, (k, v)


def attention_decode(
    cfg,
    p: Params,
    x: jnp.ndarray,
    cos,
    sin,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    length: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token decode. x: (B, 1, D); cache: (B, M, Hkv, hd); length: ()
    number of valid cached positions. Writes the new token's K/V at ``length``
    and attends over positions [0, length].
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    M = cache_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x)  # (B, 1, H*, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    pos = jnp.minimum(length, M - 1)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    kv_mask = jnp.arange(M)[None, :] <= pos  # (1, M) -> broadcast over batch
    kv_mask = jnp.broadcast_to(kv_mask, (B, M))
    o = naive_attention(
        q,
        ck.astype(q.dtype),
        cv.astype(q.dtype),
        causal=False,
        logit_softcap=cfg.attn_logit_softcap,
        kv_mask=kv_mask,
    )
    out = o.reshape(B, 1, cfg.q_dim) @ p["wo"]
    return out, ck, cv


def attention_extend(
    cfg,
    p: Params,
    x: jnp.ndarray,
    cos,
    sin,
    prefix_k: jnp.ndarray,
    prefix_v: jnp.ndarray,
    prefix_len: int,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Chunked-prefill continuation: the suffix attends over a prefilled
    prefix plus itself causally.

    x: (B, S, D) suffix activations; prefix_k/v: (B, Sp, Hkv, hd) cached
    post-RoPE prefix K/V (Sp >= prefix_len; positions past ``prefix_len``
    are page padding and are masked out); prefix_len: static int. The
    caller supplies cos/sin at positions offset by ``prefix_len`` — the
    suffix's RoPE phases continue where the prefix stopped.
    Returns (out (B, S, D), (k_suf, v_suf)).
    """
    B, S, _ = x.shape
    Sp = prefix_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    keys = jnp.concatenate([prefix_k.astype(q.dtype), k], axis=1)
    vals = jnp.concatenate([prefix_v.astype(q.dtype), v], axis=1)
    M = Sp + S
    Hkv = keys.shape[2]
    hd = cfg.head_dim
    G = cfg.num_heads // Hkv
    # per-query mask: prefix keys below prefix_len are always visible;
    # suffix keys are causal relative to the suffix row
    row = jnp.arange(S)[:, None]
    col = jnp.arange(M)[None, :]
    visible = jnp.where(col < Sp, col < prefix_len, (col - Sp) <= row)
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, keys.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    if cfg.attn_logit_softcap > 0.0:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    s = jnp.where(visible[None, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, vals.astype(jnp.float32))
    out = o.reshape(B, S, cfg.q_dim).astype(q.dtype) @ p["wo"]
    return out, (k, v)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_init(cfg, key) -> Params:
    d = cfg.d_model
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.q_dim), dt),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dt),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dt),
        "wo": dense_init(ks[3], (cfg.q_dim, d), dt),
    }


def cross_attention_kv(cfg, p: Params, enc_out: jnp.ndarray):
    """Precompute cross K/V from encoder output (done once per request)."""
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def cross_attention(cfg, p: Params, x: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    o = naive_attention(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=False)
    return o.reshape(B, S, cfg.q_dim) @ p["wo"]

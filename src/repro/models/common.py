"""Shared layers: norms, activations, initializers, dtype helpers."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# Norms. All norms compute in fp32 and cast back (TPU numerics convention).
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: Optional[jnp.ndarray], eps: float = 1e-6):
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    return x.astype(orig)


def layer_norm(
    x: jnp.ndarray,
    scale: Optional[jnp.ndarray],
    bias: Optional[jnp.ndarray],
    eps: float = 1e-5,
):
    orig = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(orig)


def apply_norm(cfg, p: Params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    """Dispatch on cfg.norm_type; ``p`` holds <name>_scale/<name>_bias if any."""
    if cfg.norm_type == "rmsnorm":
        return rms_norm(x, p[f"{name}_scale"])
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p[f"{name}_scale"], p[f"{name}_bias"])
    if cfg.norm_type == "layernorm_np":  # OLMo: non-parametric
        return layer_norm(x, None, None)
    raise ValueError(cfg.norm_type)


def norm_param_init(cfg, d: int) -> Params:
    """Norm params for one norm site (possibly empty for layernorm_np)."""
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {}


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


def mlp_act_fn(name: str):
    return {
        "swiglu": None,  # handled structurally (gate * up)
        "squared_relu": squared_relu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def stacked_init(init_fn, key, n: int):
    """vmap a per-layer initializer over n layer keys -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# MLP block (dense; MoE lives in moe.py)
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, d_model: int, d_ff: int) -> Params:
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 3)
    p: Params = {}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dt)
        p["w_up"] = dense_init(ks[1], (d_model, d_ff), dt)
    else:
        p["w_up"] = dense_init(ks[1], (d_model, d_ff), dt)
    p["w_down"] = dense_init(ks[2], (d_ff, d_model), dt)
    return p


def mlp_forward(cfg, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = mlp_act_fn(cfg.mlp_act)(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Token shift (RWKV)
# ---------------------------------------------------------------------------


def token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Shift sequence right by one; position 0 receives ``prev`` (or zeros).

    x: (B, S, D). prev: (B, D) carried state for chunked/recurrent execution.
    """
    shifted = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, 0]) if prev is None else prev
    return shifted.at[:, 0].set(first)

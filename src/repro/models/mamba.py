"""Mamba2 (SSD — state space duality) mixer, Zamba2-style.

Recurrence (per head h, head_dim p, state s; B/C shared across heads,
n_groups=1):
    a_t = exp(-dt_t * exp(A_log_h))              scalar decay per head
    H_t = a_t H_{t-1} + (dt_t x_t) B_t^T         H: (P, S_state)
    y_t = H_t C_t + D_h x_t

TPU adaptation: chunked SSD — intra-chunk term is a (C x C) masked matmul
per head (MXU), inter-chunk state passes through lax.scan. GPU versions use
warp shuffles / shared-memory scans; the chunk factorization is the
TPU-idiomatic equivalent. kernels/ssd.py is the Pallas analogue.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, dtype_of, rms_norm, split_keys


def mamba_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim  # conv over (x, B, C)
    return d_in, heads, conv_ch


def mamba_init(cfg, key) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in, heads, conv_ch = mamba_dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = split_keys(key, 4)
    # in_proj -> [z (d_in), x (d_in), B (state), C (state), dt (heads)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * s.state_dim + heads), dt),
        "conv_w": dense_init(ks[1], (s.conv_dim, conv_ch), jnp.float32, scale=0.1),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((heads,), jnp.float32),  # A = -exp(A_log) = -1
        "dt_bias": jnp.full((heads,), -2.0, jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d), dt),
    }


def _causal_conv(xc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, prev=None):
    """Depthwise causal conv. xc: (B,S,CH); w: (K,CH); prev: (B,K-1,CH) carry.
    Returns (y (B,S,CH), new_prev (B,K-1,CH))."""
    B, S, CH = xc.shape
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((B, K - 1, CH), xc.dtype)
    full = jnp.concatenate([prev, xc], axis=1)  # (B, S+K-1, CH)
    y = jnp.zeros((B, S, CH), jnp.float32)
    for i in range(K):
        y = y + full[:, i : i + S].astype(jnp.float32) * w[i]
    y = y + b
    return jax.nn.silu(y).astype(xc.dtype), full[:, -(K - 1) :]


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A_log: jnp.ndarray,
    B_: jnp.ndarray,
    C_: jnp.ndarray,
    D: jnp.ndarray,
    state0: jnp.ndarray,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,H,P); dt: (B,S,H); B_/C_: (B,S,Ns); D,A_log: (H,);
    state0: (B,H,P,Ns). Returns (y (B,S,H,P), state)."""
    Bb, S, H, P = x.shape
    Ns = B_.shape[-1]
    Cs = min(chunk, S)
    pad = (-S) % Cs
    if pad:
        x = jnp.concatenate([x, jnp.zeros((Bb, pad, H, P), x.dtype)], 1)
        dt = jnp.concatenate([dt, jnp.zeros((Bb, pad, H), dt.dtype)], 1)
        B_ = jnp.concatenate([B_, jnp.zeros((Bb, pad, Ns), B_.dtype)], 1)
        C_ = jnp.concatenate([C_, jnp.zeros((Bb, pad, Ns), C_.dtype)], 1)
    Sp = S + pad
    n = Sp // Cs
    xc = x.reshape(Bb, n, Cs, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bb, n, Cs, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, n, Cs, Ns).astype(jnp.float32)
    Cc = C_.reshape(Bb, n, Cs, Ns).astype(jnp.float32)
    neg_A = -jnp.exp(A_log.astype(jnp.float32))  # (H,)

    tri_incl = (jnp.arange(Cs)[None, :] <= jnp.arange(Cs)[:, None]).astype(jnp.float32)

    def body(state, xs):
        xb, dtb, Bb_, Cb = xs  # (B,C,H,P), (B,C,H), (B,C,Ns), (B,C,Ns)
        dlog = dtb * neg_A[None, None]  # log decay per step, (B,C,H)
        la = jnp.cumsum(dlog, axis=1)  # inclusive
        la_end = la[:, -1:]  # (B,1,H)
        # intra-chunk: scores[b,h,i,j] = exp(la_i - la_j) * (C_i . B_j), j <= i
        dec = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # (B,Ci,Cj,H)
        cb = jnp.einsum("bis,bjs->bij", Cb, Bb_)  # (B,Ci,Cj)
        scores = cb[..., None] * dec * tri_incl[None, :, :, None]
        dtx = xb * dtb[..., None]  # (B,C,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, dtx)
        # inter-chunk from incoming state
        y_inter = jnp.exp(la)[..., None] * jnp.einsum("bhps,bis->bihp", state, Cb)
        y = y_intra + y_inter + D[None, None, :, None] * xb
        # state update
        k_dec = dtx * jnp.exp(la_end - la)[..., None]  # (B,C,H,P)
        state = jnp.exp(la_end[:, 0])[..., None, None] * state + jnp.einsum(
            "bjhp,bjs->bhps", k_dec, Bb_
        )
        return state, y

    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
    )
    state, ys = jax.lax.scan(body, state0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, Sp, H, P)[:, :S]
    return y, state


def ssd_step(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A_log: jnp.ndarray,
    B_: jnp.ndarray,
    C_: jnp.ndarray,
    D: jnp.ndarray,
    state: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One step. x: (B,H,P); dt: (B,H); B_/C_: (B,Ns); state: (B,H,P,Ns)."""
    x = x.astype(jnp.float32)
    a = jnp.exp(-dt.astype(jnp.float32) * jnp.exp(A_log.astype(jnp.float32)))  # (B,H)
    dtx = x * dt[..., None]
    state = a[..., None, None] * state + dtx[..., None] * B_[:, None, None, :]
    y = jnp.einsum("bhps,bs->bhp", state, C_) + D[None, :, None] * x
    return y, state


# ---------------------------------------------------------------------------
# Block-level forward
# ---------------------------------------------------------------------------


def mamba_forward(
    cfg,
    p: Params,
    x: jnp.ndarray,
    state0: jnp.ndarray,
    conv_prev: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D); state0: (B,H,P,Ns). Returns (y, state, conv_carry)."""
    B, S, D = x.shape
    s = cfg.ssm
    d_in, heads, conv_ch = mamba_dims(cfg)
    proj = x @ p["in_proj"]  # (B,S,2*d_in+2*Ns+H)
    z, xin, Bc, Cc, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.state_dim, 2 * d_in + 2 * s.state_dim], -1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_carry = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_prev)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + s.state_dim], -1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = xin.reshape(B, S, heads, s.head_dim)
    y, state = ssd_chunked(
        xh, dt, p["A_log"], Bc, Cc, p["D"], state0, chunk=s.chunk_size
    )
    y = y.reshape(B, S, d_in)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], state, conv_carry


def mamba_step(
    cfg, p: Params, x: jnp.ndarray, state: jnp.ndarray, conv_prev: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Decode step. x: (B,D); conv_prev: (B,K-1,CH)."""
    B, D = x.shape
    s = cfg.ssm
    d_in, heads, conv_ch = mamba_dims(cfg)
    proj = x @ p["in_proj"]
    z, xin, Bc, Cc, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.state_dim, 2 * d_in + 2 * s.state_dim], -1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)  # (B, CH)
    K = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_prev, conv_in[:, None]], axis=1)  # (B,K,CH)
    conv_out = jnp.sum(window.astype(jnp.float32) * p["conv_w"][None], axis=1)
    conv_out = jax.nn.silu(conv_out + p["conv_b"]).astype(x.dtype)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + s.state_dim], -1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    xh = xin.reshape(B, heads, s.head_dim)
    y, state = ssd_step(xh, dt, p["A_log"], Bc, Cc, p["D"], state)
    y = y.reshape(B, d_in)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], state, window[:, 1:]

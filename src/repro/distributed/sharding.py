"""Parameter/activation sharding rules.

``param_pspecs(cfg, profile)`` walks the parameter pytree (by path) and emits
a ``PartitionSpec`` per leaf:

  * Megatron TP over ``profile.tp_axis``: column-shard up-projections
    (wq/wk/wv/w_gate/w_up), row-shard down-projections (wo/w_down).
  * FSDP (ZeRO-3) over ``profile.fsdp_axes``: shard the *other* matrix dim.
  * EP over ``profile.ep_axis`` for MoE expert stacks.
  * Vocab sharding for embed/head.

Every axis assignment is divisibility-guarded: if a dim doesn't divide by the
mesh extent it falls back to replication on that dim (e.g. GQA kv-heads <
TP size).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingProfile


def _axes_size(mesh_shape: Dict[str, int], axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_shape.get(axes, 1)
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return n


def _guard(spec_entry, dim: int, mesh_shape: Dict[str, int]):
    """Drop a sharding assignment whose extent doesn't divide the dim."""
    if spec_entry is None:
        return None
    if dim % _axes_size(mesh_shape, spec_entry) == 0:
        return spec_entry
    return None


def _present_axes(axes: Tuple[str, ...], mesh_shape: Dict[str, int]):
    out = tuple(a for a in axes if a in mesh_shape)
    if not out:
        return None
    return out if len(out) > 1 else out[0]


# trailing-dims role table; leading dims (layer stacks) padded with None.
# roles: 'fsdp' | 'tp' | 'ep' | 'vocab' | None
_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": ("vocab", "fsdp"),
    "head": ("fsdp", "vocab"),
    "dec_pos": (None, "fsdp"),
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",),
    "bk": ("tp",),
    "bv": ("tp",),
    # dense mlp
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # moe (rank includes expert dim) — see override below
    "router": ("fsdp", None),
    # rwkv
    "wr": ("fsdp", "tp"),
    "wg": ("fsdp", "tp"),
    "ck": ("fsdp", "tp"),
    "cv": ("tp", "fsdp"),
    "cr": ("fsdp", "tp"),
    "w_lora_a": (None, None),
    "w_lora_b": (None, None),
    # mamba
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "norm_scale": ("tp",),
}

_MOE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("ep", "fsdp", None),
    "w_up": ("ep", "fsdp", None),
    "w_down": ("ep", None, "fsdp"),
    "router": ("fsdp", None),
}


def _role_to_axes(role: Optional[str], profile: ShardingProfile, mesh_shape):
    if role is None:
        return None
    if role == "fsdp":
        return _present_axes(profile.fsdp_axes, mesh_shape)
    if role == "tp" or role == "vocab":
        if not profile.tp_axis:  # TP disabled (model axis used as DP)
            return None
        return profile.tp_axis if profile.tp_axis in mesh_shape else None
    if role == "ep":
        return profile.ep_axis if profile.ep_axis in mesh_shape else None
    raise ValueError(role)


def spec_for_param(
    path: Tuple[str, ...],
    shape: Tuple[int, ...],
    profile: ShardingProfile,
    mesh_shape: Dict[str, int],
) -> P:
    leaf = path[-1]
    in_moe = "moe" in path
    rules = _MOE_RULES if in_moe and leaf in _MOE_RULES else _RULES
    roles = rules.get(leaf)
    if not profile.shard_kv_proj and leaf in ("wk", "wv", "bk", "bv") and not in_moe:
        roles = tuple("fsdp" if r == "fsdp" else None for r in (roles or ()))
    if roles is None:
        return P()  # replicate (norm scales, mixes, biases of recurrences...)
    ndim = len(shape)
    lead = ndim - len(roles)
    if lead < 0:  # scalar-ish param with a rule (shouldn't happen)
        return P()
    entries = [None] * lead
    for i, role in enumerate(roles):
        ax = _role_to_axes(role, profile, mesh_shape)
        entries.append(_guard(ax, shape[lead + i], mesh_shape))
    # avoid reusing a mesh axis twice in one spec (illegal)
    seen = set()
    clean = []
    for e in entries:
        names = (e,) if isinstance(e, str) else (e or ())
        if any(n in seen for n in names):
            clean.append(None)
            continue
        seen.update(names)
        clean.append(e)
    return P(*clean)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def param_pspecs(params: Any, profile: ShardingProfile, mesh) -> Any:
    """PartitionSpec pytree matching ``params`` (arrays or SDS leaves)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        return spec_for_param(_path_names(path), tuple(leaf.shape), profile, mesh_shape)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, profile: ShardingProfile, mesh) -> Any:
    specs = param_pspecs(params, profile, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def dp_axes_for_mesh(mesh, profile: Optional[ShardingProfile] = None) -> Tuple[str, ...]:
    """Batch axes: ('pod', 'data') when pod exists (+ profile extras)."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    if profile is not None:
        dp += tuple(a for a in profile.extra_dp_axes if a in names and a not in dp)
    return dp


def batch_entry(mesh, profile: Optional[ShardingProfile] = None):
    dp = dp_axes_for_mesh(mesh, profile)
    return dp if len(dp) > 1 else dp[0]


def batch_pspecs(batch: Any, mesh, profile: Optional[ShardingProfile] = None) -> Any:
    """Shard leading (batch) dim of every input over the DP axes; with
    ``profile.seq_parallel``, also shard the sequence dim over tp_axis.

    VLM positions have shape (3, B, S) — batch is dim 1 there; detected by
    rank-3 int arrays whose first dim == 3 under key 'positions'.
    """
    be = batch_entry(mesh, profile)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    sp = None
    if profile is not None and profile.seq_parallel and profile.tp_axis:
        sp = profile.tp_axis if profile.tp_axis in mesh_shape else None

    def one(path, leaf):
        names = _path_names(path)
        if names and names[-1] == "positions" and leaf.ndim == 3:
            return P(
                None,
                _guard(be, leaf.shape[1], mesh_shape),
                _guard(sp, leaf.shape[2], mesh_shape),
            )
        if leaf.ndim == 0:
            return P()
        entries = [_guard(be, leaf.shape[0], mesh_shape)]
        if leaf.ndim >= 2 and sp:
            entries.append(_guard(sp, leaf.shape[1], mesh_shape))
        entries += [None] * (leaf.ndim - len(entries))
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_pspecs(cache: Any, cfg: ModelConfig, profile: ShardingProfile, mesh) -> Any:
    """KV caches: batch over DP; head-or-headdim over TP (divisibility-
    guarded); SSM states: batch over DP, head dim over TP."""
    be = batch_entry(mesh, profile)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = profile.tp_axis if (profile.tp_axis and profile.tp_axis in mesh_shape) else None

    def one(path, leaf):
        names = _path_names(path)
        nm = names[-1]
        if nm == "length":
            return P()
        if nm in ("kv_k", "kv_v", "cross_k", "cross_v"):
            # (L, B, M, H, hd) — prefer head sharding, else shard head_dim,
            # optionally shard sequence (shard_kv_seq) instead.
            L, B, M, H, hd = leaf.shape
            b = _guard(be, B, mesh_shape)
            if profile.shard_kv_seq and tp and M % mesh_shape[tp] == 0:
                return P(None, b, tp, None, None)
            if tp and H % mesh_shape[tp] == 0:
                return P(None, b, None, tp, None)
            if tp and hd % mesh_shape[tp] == 0:
                return P(None, b, None, None, tp)
            return P(None, b, None, None, None)
        if nm == "ssm_state":
            # (..., B, H, P, N) with leading layer dims
            lead = leaf.ndim - 4
            B, H, Pd, N = leaf.shape[lead:]
            b = _guard(be, B, mesh_shape)
            h = _guard(tp, H, mesh_shape)
            return P(*([None] * lead), b, h, None, None)
        if nm in ("shift_tm", "shift_cm"):
            L, B, D = leaf.shape
            return P(None, _guard(be, B, mesh_shape), _guard(tp, D, mesh_shape))
        if nm == "conv":
            lead = leaf.ndim - 3
            B, K, CH = leaf.shape[lead:]
            return P(*([None] * lead), _guard(be, B, mesh_shape), None, _guard(tp, CH, mesh_shape))
        return P()

    return jax.tree_util.tree_map_with_path(one, cache)


def to_shardings(specs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, specs,
        is_leaf=lambda s: isinstance(s, P),
    )

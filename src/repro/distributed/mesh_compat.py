"""Mesh-API version shims: the jax>=0.6 surface on jax 0.4.37.

The distributed/training code targets the modern mesh API —
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.shard_map(..., check_vma=...)`` — which this
container's jax 0.4.37 lacks. Import the surface from HERE instead of
``jax`` and both versions work (pattern: ``kernels/_compat.py``):

====================  ==========================================  =============================
modern name           jax>=0.6                                    jax 0.4.37 mapping
====================  ==========================================  =============================
``make_mesh``         ``jax.make_mesh(axis_types=...)``           ``jax.make_mesh`` (axis types
                                                                  dropped: 0.4 meshes are Auto)
``AxisType``          ``jax.sharding.AxisType``                   enum-like placeholder
``set_mesh``          ``jax.set_mesh`` context manager            ``Mesh.__enter__`` resource
                                                                  env (ambient mesh)
``shard_map``         ``jax.shard_map(check_vma=...)``            ``jax.experimental.shard_map
                                                                  .shard_map(check_rep=...)``
====================  ==========================================  =============================

``check_vma`` (0.6 name for varying-manual-axes checking) maps onto
``check_rep`` (its 0.4 name) — same meaning, renamed upstream.
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import jax

HAS_NEW_MESH_API = hasattr(jax.sharding, "AxisType")

if HAS_NEW_MESH_API:
    AxisType = jax.sharding.AxisType

    def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
                  axis_types: Optional[Sequence[Any]] = None):
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axes)
        return jax.make_mesh(tuple(shape), tuple(axes), axis_types=tuple(axis_types))

    def set_mesh(mesh):
        return jax.set_mesh(mesh)

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:
    class AxisType:  # minimal stand-in: 0.4 meshes are implicitly Auto
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
                  axis_types: Optional[Sequence[Any]] = None):
        del axis_types  # 0.4 meshes carry no axis types (all Auto)
        return jax.make_mesh(tuple(shape), tuple(axes))

    @contextlib.contextmanager
    def set_mesh(mesh):
        # 0.4 equivalent of the ambient mesh: the Mesh resource-env
        # context manager (explicit in_shardings/NamedShardings don't
        # strictly need it, but code written against jax.set_mesh expects
        # the mesh to be ambient inside the block)
        with mesh:
            yield mesh

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        from jax.experimental.shard_map import shard_map as _shard_map

        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


__all__ = ["AxisType", "HAS_NEW_MESH_API", "make_mesh", "set_mesh", "shard_map"]

"""Fault-tolerance manager: checkpoint/auto-resume training supervision.

At 1000+ nodes, mean-time-between-failures is minutes; the training loop
must (1) checkpoint asynchronously on a cadence, (2) detect failures —
NaN/infs (data or hardware), stalled steps (stragglers/deadlock), worker
loss — and (3) restart from the last committed step, optionally on a
*smaller* elastic mesh.

The manager wraps any step function; failures are injected in tests via
``inject``. Per-step wall-time watermarks implement straggler detection
(p99-based deadline like the serving hedger). Step timing reads an
injectable ``clock`` (default: the monotonic perf counter), so straggler
tests drive a virtual clock instead of sleeping.

:class:`FaultSchedule` is the shared inject path: both this runner and the
``repro.sim`` deterministic-simulation harness schedule faults through it
(``inject(step, kind, **details)`` / ``pop(step)``), so a fault plan
written for the simulator reads identically to one written for training
supervision.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


@dataclass
class FaultSpec:
    """One scheduled fault: what happens and (optionally) to whom."""

    kind: str  # "nan" | "stall" | "worker_lost" | sim kinds ("crash", ...)
    details: Dict[str, Any] = field(default_factory=dict)


class FaultSchedule:
    """Step-indexed fault injection shared by the training runner and the
    ``repro.sim`` harness. Multiple faults may land on one step; ``pop``
    returns them in injection order and removes them (a fault fires once)."""

    def __init__(self) -> None:
        self._by_step: Dict[int, List[FaultSpec]] = {}

    def inject(self, step: int, kind: str, **details: Any) -> None:
        self._by_step.setdefault(step, []).append(FaultSpec(kind, details))

    def pop(self, step: int) -> List[FaultSpec]:
        return self._by_step.pop(step, [])

    def pending(self) -> int:
        return sum(len(v) for v in self._by_step.values())

    def __bool__(self) -> bool:
        return bool(self._by_step)


@dataclass
class FaultPolicy:
    checkpoint_every: int = 50
    max_restarts: int = 5
    nan_tolerance: int = 0  # consecutive NaN steps tolerated before rollback
    step_deadline_factor: float = 5.0  # x median step time = straggler/stall
    min_steps_for_deadline: int = 10
    min_deadline_s: float = 0.5  # absolute floor (µs-scale jitter is not a stall)


@dataclass
class FaultEvent:
    step: int
    kind: str  # "nan" | "stall" | "worker_lost" | "injected"
    action: str  # "rollback" | "skip" | "abort"


class FaultTolerantRunner:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], Tuple[Any, Dict[str, float]]],
        store: CheckpointStore,
        policy: FaultPolicy = FaultPolicy(),
        *,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.step_fn = step_fn
        self.store = store
        self.policy = policy
        # injectable time source: straggler/stall detection compares THESE
        # readings, so tests (and repro.sim) drive a virtual clock instead
        # of depending on wall-clock sleeps
        self.clock = clock if clock is not None else time.perf_counter
        self.events: List[FaultEvent] = []
        self._step_times: List[float] = []
        self.schedule = FaultSchedule()

    def inject(self, step: int, kind: str, **details: Any) -> None:
        """Test hook: fail at a given step ('nan' | 'worker_lost' | 'stall')."""
        self.schedule.inject(step, kind, **details)

    # ------------------------------------------------------------------

    def _is_bad(self, metrics: Dict[str, Any]) -> bool:
        for v in metrics.values():
            try:
                x = float(np.asarray(v))
            except Exception:
                continue
            if math.isnan(x) or math.isinf(x):
                return True
        return False

    def run(
        self,
        state: Any,
        batches: Callable[[int], Any],
        n_steps: int,
        *,
        start_step: int = 0,
    ) -> Tuple[Any, int, List[FaultEvent]]:
        """Runs with checkpoint/rollback; returns (state, completed, events)."""
        step = start_step
        restarts = 0
        last_ckpt = start_step
        # resume from store if anything is committed
        committed = self.store.committed_steps()
        if committed and committed[-1] > step:
            state, extra = self.store.restore(state)
            step = extra.get("step", committed[-1])
            last_ckpt = step
        while step < n_steps:
            # every fault scheduled for this step fires (pop is fire-once,
            # so dropping any spec here would silently lose an injection)
            injected = {spec.kind for spec in self.schedule.pop(step)}
            t0 = self.clock()
            try:
                if "worker_lost" in injected:
                    raise RuntimeError("injected worker loss")
                new_state, metrics = self.step_fn(state, batches(step))
                if "nan" in injected:
                    metrics = dict(metrics, loss=float("nan"))
                dt = self.clock() - t0
                if self._stalled(dt) or "stall" in injected:
                    raise TimeoutError(f"step {step} exceeded deadline ({dt:.2f}s)")
                if self._is_bad(metrics):
                    self.events.append(FaultEvent(step, "nan", "rollback"))
                    state, step, restarts = self._rollback(state, restarts)
                    continue
                self._step_times.append(dt)
                state = new_state
                step += 1
                if step % self.policy.checkpoint_every == 0:
                    self.store.save(step, state, extra={"step": step})
                    last_ckpt = step
            except (RuntimeError, TimeoutError) as e:
                kind = "stall" if isinstance(e, TimeoutError) else "worker_lost"
                self.events.append(FaultEvent(step, kind, "rollback"))
                state, step, restarts = self._rollback(state, restarts)
        # final checkpoint
        if step != last_ckpt:
            self.store.save(step, state, extra={"step": step})
        return state, step, self.events

    def _stalled(self, dt: float) -> bool:
        if len(self._step_times) < self.policy.min_steps_for_deadline:
            return False
        med = sorted(self._step_times)[len(self._step_times) // 2]
        deadline = max(med * self.policy.step_deadline_factor,
                       self.policy.min_deadline_s)
        return dt > deadline

    def _rollback(self, state: Any, restarts: int) -> Tuple[Any, int, int]:
        restarts += 1
        if restarts > self.policy.max_restarts:
            raise RuntimeError("exceeded max_restarts; aborting run")
        committed = self.store.committed_steps()
        if not committed:
            return state, 0, restarts  # restart from scratch
        state, extra = self.store.restore(state)
        return state, extra.get("step", committed[-1]), restarts

"""Fault-tolerance manager: checkpoint/auto-resume training supervision.

At 1000+ nodes, mean-time-between-failures is minutes; the training loop
must (1) checkpoint asynchronously on a cadence, (2) detect failures —
NaN/infs (data or hardware), stalled steps (stragglers/deadlock), worker
loss — and (3) restart from the last committed step, optionally on a
*smaller* elastic mesh.

The manager wraps any step function; failures are injected in tests via
``inject``. Per-step wall-time watermarks implement straggler detection
(p99-based deadline like the serving hedger).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


@dataclass
class FaultPolicy:
    checkpoint_every: int = 50
    max_restarts: int = 5
    nan_tolerance: int = 0  # consecutive NaN steps tolerated before rollback
    step_deadline_factor: float = 5.0  # x median step time = straggler/stall
    min_steps_for_deadline: int = 10
    min_deadline_s: float = 0.5  # absolute floor (µs-scale jitter is not a stall)


@dataclass
class FaultEvent:
    step: int
    kind: str  # "nan" | "stall" | "worker_lost" | "injected"
    action: str  # "rollback" | "skip" | "abort"


class FaultTolerantRunner:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], Tuple[Any, Dict[str, float]]],
        store: CheckpointStore,
        policy: FaultPolicy = FaultPolicy(),
    ):
        self.step_fn = step_fn
        self.store = store
        self.policy = policy
        self.events: List[FaultEvent] = []
        self._step_times: List[float] = []
        self._inject: Dict[int, str] = {}

    def inject(self, step: int, kind: str) -> None:
        """Test hook: fail at a given step ('nan' | 'worker_lost' | 'stall')."""
        self._inject[step] = kind

    # ------------------------------------------------------------------

    def _is_bad(self, metrics: Dict[str, Any]) -> bool:
        for v in metrics.values():
            try:
                x = float(np.asarray(v))
            except Exception:
                continue
            if math.isnan(x) or math.isinf(x):
                return True
        return False

    def run(
        self,
        state: Any,
        batches: Callable[[int], Any],
        n_steps: int,
        *,
        start_step: int = 0,
    ) -> Tuple[Any, int, List[FaultEvent]]:
        """Runs with checkpoint/rollback; returns (state, completed, events)."""
        step = start_step
        restarts = 0
        last_ckpt = start_step
        # resume from store if anything is committed
        committed = self.store.committed_steps()
        if committed and committed[-1] > step:
            state, extra = self.store.restore(state)
            step = extra.get("step", committed[-1])
            last_ckpt = step
        while step < n_steps:
            injected = self._inject.pop(step, None)
            t0 = time.perf_counter()
            try:
                if injected == "worker_lost":
                    raise RuntimeError("injected worker loss")
                new_state, metrics = self.step_fn(state, batches(step))
                if injected == "nan":
                    metrics = dict(metrics, loss=float("nan"))
                dt = time.perf_counter() - t0
                if self._stalled(dt) or injected == "stall":
                    raise TimeoutError(f"step {step} exceeded deadline ({dt:.2f}s)")
                if self._is_bad(metrics):
                    self.events.append(FaultEvent(step, "nan", "rollback"))
                    state, step, restarts = self._rollback(state, restarts)
                    continue
                self._step_times.append(dt)
                state = new_state
                step += 1
                if step % self.policy.checkpoint_every == 0:
                    self.store.save(step, state, extra={"step": step})
                    last_ckpt = step
            except (RuntimeError, TimeoutError) as e:
                kind = "stall" if isinstance(e, TimeoutError) else "worker_lost"
                self.events.append(FaultEvent(step, kind, "rollback"))
                state, step, restarts = self._rollback(state, restarts)
        # final checkpoint
        if step != last_ckpt:
            self.store.save(step, state, extra={"step": step})
        return state, step, self.events

    def _stalled(self, dt: float) -> bool:
        if len(self._step_times) < self.policy.min_steps_for_deadline:
            return False
        med = sorted(self._step_times)[len(self._step_times) // 2]
        deadline = max(med * self.policy.step_deadline_factor,
                       self.policy.min_deadline_s)
        return dt > deadline

    def _rollback(self, state: Any, restarts: int) -> Tuple[Any, int, int]:
        restarts += 1
        if restarts > self.policy.max_restarts:
            raise RuntimeError("exceeded max_restarts; aborting run")
        committed = self.store.committed_steps()
        if not committed:
            return state, 0, restarts  # restart from scratch
        state, extra = self.store.restore(state)
        return state, extra.get("step", committed[-1]), restarts

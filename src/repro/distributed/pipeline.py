"""Pipeline parallelism: GPipe-style microbatched stage execution via
shard_map + collective_permute.

The layer stack (L, ...) is split into ``n_stages`` contiguous stages along
a mesh axis; microbatches stream through: at global step t, stage s runs
microbatch t-s (bubble = n_stages-1 idle slots at each end — the standard
GPipe trade-off; 1F1B would halve activation memory but complicates the
schedule; noted as future work in DESIGN.md).

Backward comes for free through autodiff: the transpose of ppermute is the
reverse ppermute, so jax.grad of ``pipeline_apply`` yields the GPipe
backward schedule automatically.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import mesh_compat


def pipeline_apply(
    layer_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stacked_params: Any,
    x: jnp.ndarray,
    *,
    mesh,
    axis: str,
    n_microbatches: int,
):
    """Run x through L stacked layers, pipelined over mesh axis ``axis``.

    layer_fn(params_one_layer, h) -> h. stacked_params leaves have leading L
    divisible by the axis size. x: (B, ...) with B divisible by
    n_microbatches. Returns f(x) identical (up to dtype rounding) to the
    sequential loop.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    B = x.shape[0]
    assert B % n_microbatches == 0, (B, n_microbatches)
    mb = B // n_microbatches

    # stage-local params: (n_stages, L/n_stages, ...) sharded over axis
    def restack(p):
        return p.reshape((n_stages, L // n_stages) + p.shape[1:])

    sp = jax.tree.map(restack, stacked_params)
    mbs = x.reshape((n_microbatches, mb) + x.shape[1:])

    p_spec = jax.tree.map(lambda _: P(axis), sp)
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def stage_body(params_local, mbs_local):
        """Inside shard_map: params_local (1, L/n, ...), mbs replicated."""
        params_local = jax.tree.map(lambda p: p[0], params_local)
        sid = jax.lax.axis_index(axis)
        T = n_microbatches + n_stages - 1
        state = jnp.zeros((mb,) + mbs_local.shape[2:], mbs_local.dtype)
        outs = jnp.zeros_like(mbs_local)

        def apply_stage(h):
            for i in range(L // n_stages):
                p_i = jax.tree.map(lambda p: p[i], params_local)
                h = layer_fn(p_i, h)
            return h

        def step(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (clamped; masked out later)
            ingest = jax.lax.dynamic_index_in_dim(
                mbs_local, jnp.minimum(t, n_microbatches - 1), 0, keepdims=False
            )
            h = jnp.where(sid == 0, ingest, state)
            h = apply_stage(h)
            # collect on the last stage when a real microbatch exits
            out_idx = t - (n_stages - 1)
            valid = (sid == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h.astype(o.dtype), jnp.maximum(out_idx, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # shift activations to the next stage
            state = jax.lax.ppermute(h, axis, fwd)
            return state, outs

        state, outs = jax.lax.fori_loop(0, T, step, (state, outs))
        # broadcast from the last stage (all other stages hold zeros)
        outs = jax.lax.psum(outs, axis)
        return outs

    fn = mesh_compat.shard_map(
        stage_body,
        mesh=mesh,
        in_specs=(p_spec, P()),
        out_specs=P(),
        check_vma=False,
    )
    out = fn(sp, mbs)
    return out.reshape((B,) + x.shape[1:])


def sequential_reference(layer_fn, stacked_params, x):
    """Oracle: plain scan over the layer stack."""
    def body(h, p):
        return layer_fn(p, h), None

    h, _ = jax.lax.scan(body, x, stacked_params)
    return h

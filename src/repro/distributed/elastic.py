"""Elastic scaling: re-shard live state onto a different mesh.

When nodes join/leave, training resumes on a new mesh: parameters and
optimizer state are re-laid-out with ``reshard_tree`` (device_put with the
new NamedShardings — XLA moves only the bytes that change owners), the data
pipeline re-partitions by the new DP size, and the APC plan cache
re-partitions via consistent hashing (core/distributed_cache.py — only
~K/N keys move).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax

from repro.configs.base import ShardingProfile
from repro.distributed import sharding as shd


def reshard_tree(tree: Any, mesh, profile: ShardingProfile) -> Any:
    """Re-layout a param/opt pytree for ``mesh`` (the elastic-rescale core)."""
    shardings = shd.to_shardings(shd.param_pspecs(tree, profile, mesh), mesh)
    return jax.device_put(tree, shardings)


def rescale_training_state(
    params: Any, opt_state: Any, new_mesh, profile: ShardingProfile
) -> Tuple[Any, Any]:
    params = reshard_tree(params, new_mesh, profile)
    new_m = reshard_tree(opt_state["m"], new_mesh, profile)
    new_v = reshard_tree(opt_state["v"], new_mesh, profile)
    return params, {"m": new_m, "v": new_v, "step": opt_state["step"]}


def rebatch_for_mesh(global_batch: int, mesh) -> int:
    """Largest per-step batch divisible by the new DP extent."""
    dp = 1
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    for ax in ("pod", "data"):
        dp *= shape.get(ax, 1)
    return (global_batch // dp) * dp

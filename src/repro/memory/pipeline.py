"""MatchPipeline: the exact -> fuzzy -> semantic lookup cascade as data.

Every cache-consuming surface used to hand-roll its own matching: PlanCache
interleaved an exact dict probe with a FuzzyMatcher fallback, the semantic
baseline kept a private ``SimilarityIndex`` over query embeddings, and the
distributed cache re-implemented tiered probing. A :class:`MatchPipeline`
makes the cascade explicit — an ordered list of stages, each of which tries
to RESOLVE a query string to a stored key; the store then serves the
resolved key through its one exact/TTL/eviction-accounting path.

Stages are incremental: the store notifies them on insert/remove/clear so
their indexes never rebuild on the lookup path (the ``repro.index``
contract). Batch notifications map to batched index ingestion — one
embedding batch per admission wave and, on the ``device`` backend, one
donated multi-slot device scatter.

Built-in stages:

* :class:`ExactStage`    — dict membership, O(1), always first in practice;
* :class:`FuzzyStage`    — keyword-embedding similarity over the stored
  keys (the paper's fuzzy matching, Tables 5-6), any ``repro.index``
  backend;
* :class:`SemanticStage` — GPTCache-style similarity over each entry's
  *insertion context* (e.g. the raw task query), matched against the
  lookup context. This is the semantic baseline's matcher, now reusable:
  the ``cascade`` method composes it BEHIND exact+fuzzy so plan templates
  can be reused across paraphrased queries whose keywords don't match.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple, Union


class MatchStage:
    """One resolution stage. Subclasses override ``resolve`` plus whichever
    maintenance hooks their index needs (defaults are no-ops)."""

    name = "stage"

    def on_insert(
        self,
        key: str,
        value: Any,
        context: Optional[str] = None,
        vector: Optional[Any] = None,
    ) -> None:
        pass

    def on_insert_batch(
        self,
        items: Sequence[Tuple[str, Any]],
        contexts: Sequence[Optional[str]],
        vectors: Optional[Any] = None,
    ) -> None:
        for j, (key, value) in enumerate(items):
            self.on_insert(
                key,
                value,
                contexts[j],
                None if vectors is None else vectors[j],
            )

    def on_remove(self, key: str) -> None:
        pass

    def clear(self) -> None:
        pass

    def resolve(
        self,
        queries: Sequence[str],
        contexts: Sequence[Optional[str]],
        contains: Callable[[str], bool],
    ) -> List[Optional[str]]:
        """Per query: the stored key this stage resolves it to, else None.
        ``contains`` is exact membership in the owning store."""
        raise NotImplementedError


class ExactStage(MatchStage):
    """Exact dict membership — the paper's O(1) default (§3.2)."""

    name = "exact"

    def resolve(self, queries, contexts, contains):
        return [q if contains(q) else None for q in queries]


class FuzzyStage(MatchStage):
    """Keyword-embedding similarity over stored keys (``repro.index``)."""

    name = "fuzzy"

    def __init__(self, threshold: float = 0.8, backend: str = "auto", **index_kw):
        from repro.core.fuzzy import FuzzyMatcher

        self.threshold = threshold
        self.matcher = FuzzyMatcher(backend=backend, **index_kw)

    def on_insert(self, key, value, context=None, vector=None):
        self.matcher.add(key, vector)

    def on_insert_batch(self, items, contexts, vectors=None):
        self.matcher.add_batch([k for k, _ in items], vectors)

    def on_remove(self, key):
        self.matcher.remove(key)

    def clear(self):
        self.matcher.clear()

    def resolve(self, queries, contexts, contains):
        return self.matcher.best_match_batch(list(queries), self.threshold)

    def autotune(self, **thresholds) -> Optional[str]:
        return self.matcher.index.autotune(**thresholds)


class SemanticStage(MatchStage):
    """Similarity over each entry's insertion *context* text.

    At insert the stage embeds ``context`` (falling back to the key — which
    makes a query-keyed store like the semantic baseline work unchanged);
    at lookup it embeds the lookup context (falling back to the query) and
    returns the stored key whose context is most similar above
    ``threshold``. Lookup vectors are embedded once per batch.
    """

    name = "semantic"

    def __init__(self, threshold: float = 0.85, backend: str = "auto", **index_kw):
        from repro.index import SimilarityIndex

        self.threshold = threshold
        self.index = SimilarityIndex(backend=backend, **index_kw)

    def on_insert(self, key, value, context=None, vector=None):
        # `vector` is the KEY-embedding channel (consumed by key-matching
        # stages like fuzzy); this stage matches on context text, so it
        # always embeds the context itself — indexing a caller's keyword
        # vector here would silently break paraphrase matching
        from repro.index import embed

        self.index.add(
            key,
            None if context is None or context == key else embed(context),
        )

    def on_insert_batch(self, items, contexts, vectors=None):
        from repro.index import embed_batch

        keys = [k for k, _ in items]
        texts = [c if c is not None else k for k, c in zip(keys, contexts)]
        self.index.add_batch(keys, embed_batch(texts))

    def on_remove(self, key):
        self.index.remove(key)

    def clear(self):
        self.index.clear()

    def resolve(self, queries, contexts, contains):
        from repro.index import embed_batch

        texts = [c if c is not None else q for q, c in zip(queries, contexts)]
        return self.index.best_match_batch(embed_batch(texts), self.threshold)

    def autotune(self, **thresholds) -> Optional[str]:
        return self.index.autotune(**thresholds)


class MatchPipeline:
    """Ordered stages; the store broadcasts maintenance to all of them and
    walks them in order at lookup, narrowing to still-unresolved queries."""

    def __init__(self, stages: Sequence[MatchStage]):
        self.stages: List[MatchStage] = list(stages)
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names in pipeline: {names}")

    def stage(self, name: str) -> Optional[MatchStage]:
        for s in self.stages:
            if s.name == name:
                return s
        return None

    def on_insert_batch(self, items, contexts, vectors=None) -> None:
        for s in self.stages:
            s.on_insert_batch(items, contexts, vectors)

    def on_remove(self, key: str) -> None:
        for s in self.stages:
            s.on_remove(key)

    def clear(self) -> None:
        for s in self.stages:
            s.clear()


def build_pipeline(
    spec: Sequence[Union[str, MatchStage]],
    *,
    fuzzy_threshold: float = 0.8,
    semantic_threshold: float = 0.85,
    index_backend: str = "auto",
    obs: Optional[Any] = None,
    obs_labels: Optional[dict] = None,
) -> MatchPipeline:
    """Build a pipeline from stage names (``exact`` | ``fuzzy`` |
    ``semantic``) and/or pre-built stage instances, in cascade order.

    ``obs`` (a :class:`repro.obs.MetricsRegistry`) and ``obs_labels`` ride
    down into each stage's similarity index, which registers its LSH /
    device-bank telemetry there with an added ``stage=<name>`` label — so
    a fuzzy and a semantic index in one pipeline stay distinct series."""
    base = dict(obs_labels or {})

    def stage_kw(name: str) -> dict:
        if obs is None and not base:
            return {}
        return {"obs": obs, "obs_labels": dict(base, stage=name)}

    stages: List[MatchStage] = []
    for item in spec:
        if isinstance(item, MatchStage):
            stages.append(item)
        elif item == "exact":
            stages.append(ExactStage())
        elif item == "fuzzy":
            stages.append(
                FuzzyStage(fuzzy_threshold, index_backend, **stage_kw("fuzzy"))
            )
        elif item == "semantic":
            stages.append(
                SemanticStage(
                    semantic_threshold, index_backend, **stage_kw("semantic")
                )
            )
        else:
            raise ValueError(
                f"unknown pipeline stage {item!r} "
                "(expected 'exact' | 'fuzzy' | 'semantic' | MatchStage)"
            )
    return MatchPipeline(stages)


__all__ = [
    "ExactStage",
    "FuzzyStage",
    "MatchPipeline",
    "MatchStage",
    "SemanticStage",
    "build_pipeline",
]

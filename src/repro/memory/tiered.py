"""Tiered plan memory: the cold persistent tier under the hot stores.

The paper's test-time memory lives in host RAM (``PlanCache``) with hot
vectors in the DeviceBank; eviction is a hard delete, so cache capacity is
bounded by one process's RAM. This module adds the third tier: when the
eviction policy picks a victim, the victim's template (plus its insertion
context and key embedding) *spills* to a :class:`~repro.checkpoint.store.
CheckpointStore`-backed on-disk segment instead of vanishing, and a miss
in the hot tier consults a compact in-RAM **manifest** (key -> segment id,
``size_tokens``, reuse score) to *promote* the entry back through the
store's normal ``insert_batch`` path.

Two invariants make the tier safe:

* **two-phase spill** — the segment is written (atomically: the
  CheckpointStore's ``COMMITTED`` marker) BEFORE the manifest references
  it. A crash between the two phases loses the spilled entries (they were
  already evicted from the hot tier) but can never leave the manifest
  pointing at a segment that does not exist — the manifest is the source
  of truth for what the cold tier holds.
* **refcounted segment gc** — segments are garbage-collected by live
  reference count (entries still in the manifest pin their segment), NOT
  by ``keep_last`` age rotation: an old segment whose entries were never
  promoted must survive arbitrarily many newer spill waves. Only
  fully-unreferenced segments rotate. ``refcount_gc=False`` is the
  ``repro.sim`` ablation (``cold_gc_refcount``): age rotation deletes
  live segments and the sim's durability oracle catches the lost
  templates.

**Template compaction** bounds the bytes a cold entry costs: past a token
budget, step bodies are truncated and non-skeleton output steps collapse
into one summary step (the compacting-session-manager pattern — keep the
slotted skeleton, summarize the bulk). Compaction is idempotent and never
grows ``size_tokens``; non-template values pass through untouched.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.template import PlanStep, PlanTemplate

# -- template compaction ----------------------------------------------------

# per-step body cap (chars) applied by the truncation pass
_STEP_CHAR_CAP = 160
_SUMMARY_PREFIX = "[compacted:"


def _truncate_steps(tpl: PlanTemplate) -> PlanTemplate:
    """Pass 1: cap each step body at ``_STEP_CHAR_CAP`` chars (ops are the
    slotted skeleton and are kept verbatim)."""
    steps = [
        PlanStep(s.kind, s.content[:_STEP_CHAR_CAP], s.op) for s in tpl.steps
    ]
    return PlanTemplate(tpl.keyword, steps, tpl.source_task[:_STEP_CHAR_CAP],
                        tpl.uses)


def _elide_outputs(tpl: PlanTemplate) -> PlanTemplate:
    """Pass 2: collapse the non-skeleton ``output`` steps into ONE summary
    step. Message steps (the slotted plan skeleton) and the answer step
    are kept; an existing summary step is not re-summarized (idempotence)."""
    kept: List[PlanStep] = []
    elided = 0
    summary_at: Optional[int] = None
    for s in tpl.steps:
        if s.kind == "output" and not s.content.startswith(_SUMMARY_PREFIX):
            elided += 1
            if summary_at is None:
                summary_at = len(kept)
                kept.append(None)  # placeholder, patched below
            continue
        kept.append(s)
    if elided == 0:
        return tpl
    kept[summary_at] = PlanStep(
        "output", f"{_SUMMARY_PREFIX} {elided} output step(s) elided]", None
    )
    return PlanTemplate(tpl.keyword, kept, tpl.source_task, tpl.uses)


def compact_template(value: Any, *, budget_tokens: int = 160) -> Tuple[Any, int]:
    """Compact ``value`` toward ``budget_tokens``; returns ``(value',
    saved_tokens)``.

    Only :class:`~repro.core.template.PlanTemplate` values compact —
    anything else (sim payload dicts, benchmark stand-ins) passes through
    with 0 saved. Guarantees: idempotent (compacting a compacted template
    is the identity) and monotone (``size_tokens`` never grows — a pass
    whose result is not strictly smaller is discarded).
    """
    if not isinstance(value, PlanTemplate):
        return value, 0
    before = value.size_tokens()
    out = value
    for compact_pass in (_truncate_steps, _elide_outputs):
        if out.size_tokens() <= budget_tokens:
            break
        candidate = compact_pass(out)
        if candidate.size_tokens() < out.size_tokens():
            out = candidate
    return out, before - out.size_tokens()


# -- cold-entry serialization ------------------------------------------------
#
# Segments carry JSON (as a uint8 array leaf through the CheckpointStore's
# crc-verified shard files): templates round-trip through a tagged encoding,
# plain JSON values pass through, embedding vectors travel as float lists.


def _encode_value(v: Any) -> Any:
    if isinstance(v, PlanTemplate):
        return {
            "__plan_template__": {
                "keyword": v.keyword,
                "steps": [s.to_json() for s in v.steps],
                "source_task": v.source_task,
                "uses": v.uses,
            }
        }
    return v


def _decode_value(v: Any) -> Any:
    if isinstance(v, dict) and "__plan_template__" in v:
        d = v["__plan_template__"]
        return PlanTemplate(
            keyword=d["keyword"],
            steps=[PlanStep(s["kind"], s["content"], s["op"])
                   for s in d["steps"]],
            source_task=d["source_task"],
            uses=d["uses"],
        )
    return v


class ColdEntry:
    """One promoted cold-tier record (value + its insertion side-channel)."""

    __slots__ = ("value", "context", "vector")

    def __init__(self, value: Any, context: Optional[str], vector: Optional[Any]):
        self.value = value
        self.context = context
        self.vector = vector


class ColdTier:
    """Manifest + CheckpointStore-backed segments for evicted templates.

    Thread-safety: all public methods take the tier's own lock; the owning
    ``PlanCache`` additionally serializes spill/promote under its store
    lock, so the lock here only protects direct ColdTier users (tests,
    benchmarks).
    """

    def __init__(
        self,
        directory: str,
        *,
        budget_tokens: int = 160,
        keep_last: int = 8,
        refcount_gc: bool = True,
    ):
        # local import: checkpoint pulls in jax; memory.policies must stay
        # importable without it
        from repro.checkpoint.store import CheckpointStore

        self.budget_tokens = budget_tokens
        # ABLATION SEAM (repro.sim only): refcount_gc=False drops the
        # pin_check, so keep_last age rotation deletes segments that still
        # have live manifest entries — the lost-template regression the
        # sim's cold_tier durability oracle catches.
        self.refcount_gc = refcount_gc
        self.store = CheckpointStore(
            directory,
            keep_last=keep_last,
            pin_check=(self._segment_live if refcount_gc else None),
        )
        # the compact in-RAM manifest: key -> {segment, size_tokens, score}
        self.manifest: Dict[str, Dict[str, Any]] = {}
        self._seg_refs: Dict[int, int] = {}  # segment id -> live entries
        self._next_segment = 0
        self._crash_after_segment = 0  # sim fault arming (count-based)
        self._lock = threading.RLock()
        # resume: adopt committed segments left by a previous process so a
        # fresh manifest never collides with their ids (their entries are
        # unreachable without the in-RAM manifest and gc will reclaim them)
        steps = self.store.committed_steps()
        if steps:
            self._next_segment = steps[-1] + 1

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self.manifest)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self.manifest

    def keys(self) -> List[str]:
        with self._lock:
            return sorted(self.manifest)

    def _segment_live(self, segment: int) -> bool:
        return self._seg_refs.get(segment, 0) > 0

    def live_segments(self) -> List[int]:
        with self._lock:
            return sorted(s for s, n in self._seg_refs.items() if n > 0)

    # -- sim fault seam ------------------------------------------------------

    def arm_crash_after_segment(self, waves: int) -> None:
        """Sim fault: the next ``waves`` spill waves crash between the
        segment write and the manifest commit — the segment lands on disk
        but the manifest never references it (entries lost; gc reclaims
        the orphan). Deterministic, count-based, mirrored by the sim's
        ModelStore."""
        with self._lock:
            self._crash_after_segment = waves

    # -- spill / fetch / take ------------------------------------------------

    def spill(
        self,
        entries: Sequence[Tuple[str, Any, Optional[str], Optional[Any], float]],
    ) -> int:
        """Write one spill wave ``(key, value, context, vector, score)`` as
        ONE segment, then commit the manifest. Returns the compaction
        tokens saved across the wave.

        Phase order is load-bearing: segment first (atomic via the
        CheckpointStore COMMITTED marker), manifest second — a crash
        between the phases loses the wave (already evicted from hot) but
        never yields a manifest entry without a segment behind it.
        """
        if not entries:
            return 0
        with self._lock:
            records = []
            saved_total = 0
            for key, value, context, vector, score in entries:
                value, saved = compact_template(
                    value, budget_tokens=self.budget_tokens
                )
                saved_total += saved
                size_fn = getattr(value, "size_tokens", None)
                records.append({
                    "key": key,
                    "value": _encode_value(value),
                    "context": context,
                    "vector": (None if vector is None
                               else np.asarray(vector, dtype=np.float32).tolist()),
                    "size_tokens": int(size_fn()) if callable(size_fn) else 1,
                    "score": float(score),
                })
            segment = self._next_segment
            self._next_segment += 1
            payload = np.frombuffer(
                json.dumps(records, sort_keys=True).encode(), dtype=np.uint8
            )
            # phase 1: the segment (atomic; also runs gc over unpinned ones)
            self.store.save(segment, {"payload": payload})
            if self._crash_after_segment > 0:
                # injected crash between segment write and manifest commit:
                # the wave is lost (the orphan segment has no references and
                # will be reclaimed by gc)
                self._crash_after_segment -= 1
                return saved_total
            # phase 2: the manifest commit makes the wave visible
            for rec in records:
                self._drop_ref(rec["key"])  # overwrite: release the old segment
                self.manifest[rec["key"]] = {
                    "segment": segment,
                    "size_tokens": rec["size_tokens"],
                    "score": rec["score"],
                }
                self._seg_refs[segment] = self._seg_refs.get(segment, 0) + 1
            return saved_total

    def _drop_ref(self, key: str) -> None:
        meta = self.manifest.pop(key, None)
        if meta is not None:
            seg = meta["segment"]
            self._seg_refs[seg] = self._seg_refs.get(seg, 1) - 1
            if self._seg_refs[seg] <= 0:
                del self._seg_refs[seg]

    def _read_segment(self, segment: int) -> Dict[str, Dict[str, Any]]:
        template = {"payload": np.zeros(0, dtype=np.uint8)}
        try:
            tree, _ = self.store.restore(template, step=segment)
        except (FileNotFoundError, KeyError, IOError):
            return {}
        records = json.loads(bytes(np.asarray(tree["payload"])).decode())
        return {r["key"]: r for r in records}

    def fetch(self, keys: Sequence[str]) -> List[Optional[ColdEntry]]:
        """Resolve ``keys`` against the manifest and load the referenced
        segments (one read per distinct segment). Entries stay in the cold
        tier — use :meth:`take` for promotion."""
        with self._lock:
            out: List[Optional[ColdEntry]] = [None] * len(keys)
            by_segment: Dict[int, List[int]] = {}
            for i, k in enumerate(keys):
                meta = self.manifest.get(k)
                if meta is not None:
                    by_segment.setdefault(meta["segment"], []).append(i)
            for segment, idxs in by_segment.items():
                records = self._read_segment(segment)
                for i in idxs:
                    rec = records.get(keys[i])
                    if rec is None:
                        # the segment is gone or torn (e.g. age-rotated by
                        # the gc ablation): the manifest entry is stale —
                        # drop it so the miss is accounted once
                        self._drop_ref(keys[i])
                        continue
                    vec = rec["vector"]
                    out[i] = ColdEntry(
                        _decode_value(rec["value"]),
                        rec["context"],
                        None if vec is None else np.asarray(vec, dtype=np.float32),
                    )
            return out

    def take(self, keys: Sequence[str]) -> List[Optional[ColdEntry]]:
        """Fetch + remove: the promotion primitive (an entry lives in
        exactly one tier, so promoting moves it out of the manifest and
        unpins its segment)."""
        with self._lock:
            got = self.fetch(keys)
            for k, e in zip(keys, got):
                if e is not None:
                    self._drop_ref(k)
            return got

    # -- maintenance ---------------------------------------------------------

    def purge(self, key: str) -> bool:
        """Drop one cold entry (store ``remove`` reaches the cold tier too —
        a removed key must not resurrect on a later miss)."""
        with self._lock:
            present = key in self.manifest
            self._drop_ref(key)
            return present

    def clear(self) -> None:
        with self._lock:
            self.manifest.clear()
            self._seg_refs.clear()
            self.store.gc()  # nothing is pinned now; reclaim segments

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "entries": len(self.manifest),
                "segments": len(self._seg_refs),
                "size_tokens": sum(
                    m["size_tokens"] for m in self.manifest.values()
                ),
            }


__all__ = ["ColdEntry", "ColdTier", "compact_template"]

"""The ``PlanStore`` protocol: the batch-native contract every plan-cache
implementation satisfies.

The paper's test-time memory (arXiv 2506.14852 §3) is consumed by several
surfaces — the agent loop, the two-tier serving router, distributed shards,
benchmarks — and each used to duck-type its way around the differences
(``hasattr(cache, "lookup_batch")`` probes, per-method ``if`` ladders).
This module pins the contract down:

* ``lookup_batch`` / ``insert_batch`` are the PRIMITIVE operations. Every
  implementation answers a whole wave in one pass (one lock acquisition,
  one batched fuzzy/semantic resolution, one device scatter on the
  ``device`` index backend).
* ``lookup`` / ``insert`` are thin wrappers over the batch primitives,
  provided once by :class:`PlanStoreBase` — single-request callers get the
  exact same semantics as the batched path because they ARE the batched
  path with a batch of one.
* ``contexts`` carry optional side-channel text per keyword (e.g. the raw
  task query) for pipeline stages that match on something other than the
  key — see :class:`repro.memory.pipeline.SemanticStage`.
* ``vectors`` let a caller that already embedded the KEYS (a replicating
  distributed cache, a benchmark with a prebuilt bank) ship those key
  embeddings instead of having every shard re-embed them. They feed the
  key-matching stages only — a context-matching stage (semantic) always
  embeds its context text itself.
* ``unless_written_since`` is CONDITIONAL ADMISSION (insert-if-newer): a
  writer that derived its wave from a cache read at time *t* (async cache
  generation) passes ``unless_written_since=store.now()`` captured at that
  read, and any key whose live entry was (re)written at or after *t* is
  skipped — a slow background distillation can never clobber a newer
  client insert with a stale template. ``now()`` reads the store's
  injectable clock so tokens and entry timestamps share one time source.

``CacheStats`` lives here too (re-exported from ``repro.core.cache`` for
backward compatibility) so implementations share one accounting shape. It
is a *view* over a :class:`repro.obs.MetricsRegistry` — stores that share
a registry (distributed shards, a traced serving path) contribute to one
label-keyed series set, while a bare ``CacheStats()`` gets a private
registry and behaves exactly like the historical dataclass.
"""

from __future__ import annotations

from typing import (
    Any,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    TypeVar,
    runtime_checkable,
)

from repro.obs import MetricsRegistry
from repro.obs import names as _names

V = TypeVar("V")


def _stat_prop(field: str):
    def get(self):
        v = self._counters[field].value
        return v if field == "lookup_time_s" else int(v)

    def set_(self, v):
        # deprecated ``stats.hits += 1`` shim: get-then-set, safe only
        # under the owning store's lock (where all historical writers
        # live); lock-free callers use ``add()``
        self._counters[field].set(v)

    return property(get, set_)


class CacheStats:
    """Hit/miss/insert/evict accounting for one plan store.

    Registry-backed view: the historical dataclass fields are properties
    over lock-safe :class:`repro.obs.Counter` instances, so the old
    ``stats.hits`` reads and ``snapshot()`` schema are unchanged while
    shared-registry deployments get per-shard labeled series for free.
    """

    _FIELDS = {
        "hits": _names.CACHE_HITS,
        "misses": _names.CACHE_MISSES,
        "inserts": _names.CACHE_INSERTS,
        "evictions": _names.CACHE_EVICTIONS,
        "lookup_time_s": _names.CACHE_LOOKUP_TIME_S,
        # cold-tier + conditional-admission accounting (repro.memory.tiered);
        # stay 0 for two-tier stores, and stay OUT of snapshot() so the
        # historical snapshot schema is unchanged — read cold_snapshot()
        "cold_hits": _names.CACHE_COLD_HITS,
        "spills": _names.CACHE_SPILLS,
        "promotes": _names.CACHE_PROMOTES,
        "compaction_saved_tokens": _names.CACHE_COMPACTION_SAVED_TOKENS,
        "stale_insert_skips": _names.CACHE_STALE_INSERT_SKIPS,
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 **labels: str):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.labels = labels
        self._counters = {
            field: self.registry.counter(name, **labels)
            for field, name in self._FIELDS.items()
        }

    hits = _stat_prop("hits")
    misses = _stat_prop("misses")
    inserts = _stat_prop("inserts")
    evictions = _stat_prop("evictions")
    lookup_time_s = _stat_prop("lookup_time_s")
    cold_hits = _stat_prop("cold_hits")
    spills = _stat_prop("spills")
    promotes = _stat_prop("promotes")
    compaction_saved_tokens = _stat_prop("compaction_saved_tokens")
    stale_insert_skips = _stat_prop("stale_insert_skips")

    def add(self, field: str, n: float = 1) -> None:
        """Lock-safe increment (the contract for unlocked callers)."""
        self._counters[field].inc(n)

    def reset(self) -> None:
        """Zero this view's own series (NOT the whole registry) — what
        ``clear()`` calls now that stats objects are shared handles."""
        for c in self._counters.values():
            c.reset()

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "inserts": self.inserts,
            "evictions": self.evictions,
            "lookup_time_s": round(self.lookup_time_s, 6),
        }

    def cold_snapshot(self) -> Dict[str, int]:
        """The tiered-memory counters (all 0 unless a cold tier is wired)."""
        return {
            "cold_hits": self.cold_hits,
            "spills": self.spills,
            "promotes": self.promotes,
            "compaction_saved_tokens": self.compaction_saved_tokens,
            "stale_insert_skips": self.stale_insert_skips,
        }


@runtime_checkable
class PlanStore(Protocol):
    """Batch-native keyword -> plan store.

    Implementations: :class:`repro.core.cache.PlanCache` and
    :class:`repro.core.distributed_cache.DistributedPlanCache`. Consumers
    (router, agent methods, harness) program against this protocol and
    never probe for optional capabilities.
    """

    stats: CacheStats

    def lookup_batch(
        self,
        keywords: Sequence[str],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
    ) -> List[Optional[Any]]: ...

    def insert_batch(
        self,
        items: Sequence[Tuple[str, Any]],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
        vectors: Optional[Any] = None,
        unless_written_since: Optional[float] = None,
    ) -> None: ...

    def now(self) -> float: ...

    def lookup(
        self, keyword: str, *, context: Optional[str] = None
    ) -> Optional[Any]: ...

    def insert(
        self,
        keyword: str,
        value: Any,
        *,
        context: Optional[str] = None,
        vector: Optional[Any] = None,
    ) -> None: ...

    def remove(self, keyword: str) -> bool: ...

    def keys(self) -> List[str]: ...

    def clear(self) -> None: ...

    def __contains__(self, keyword: str) -> bool: ...

    def __len__(self) -> int: ...


class PlanStoreBase:
    """Singular ``lookup``/``insert`` as thin wrappers over the batch
    primitives — inherit this and implement only ``lookup_batch`` /
    ``insert_batch``."""

    def lookup(
        self, keyword: str, *, context: Optional[str] = None
    ) -> Optional[Any]:
        return self.lookup_batch([keyword], contexts=[context])[0]

    def insert(
        self,
        keyword: str,
        value: Any,
        *,
        context: Optional[str] = None,
        vector: Optional[Any] = None,
        unless_written_since: Optional[float] = None,
    ) -> None:
        self.insert_batch(
            [(keyword, value)],
            contexts=[context],
            vectors=None if vector is None else [vector],
            unless_written_since=unless_written_since,
        )


__all__ = ["CacheStats", "PlanStore", "PlanStoreBase", "V"]

"""The ``PlanStore`` protocol: the batch-native contract every plan-cache
implementation satisfies.

The paper's test-time memory (arXiv 2506.14852 §3) is consumed by several
surfaces — the agent loop, the two-tier serving router, distributed shards,
benchmarks — and each used to duck-type its way around the differences
(``hasattr(cache, "lookup_batch")`` probes, per-method ``if`` ladders).
This module pins the contract down:

* ``lookup_batch`` / ``insert_batch`` are the PRIMITIVE operations. Every
  implementation answers a whole wave in one pass (one lock acquisition,
  one batched fuzzy/semantic resolution, one device scatter on the
  ``device`` index backend).
* ``lookup`` / ``insert`` are thin wrappers over the batch primitives,
  provided once by :class:`PlanStoreBase` — single-request callers get the
  exact same semantics as the batched path because they ARE the batched
  path with a batch of one.
* ``contexts`` carry optional side-channel text per keyword (e.g. the raw
  task query) for pipeline stages that match on something other than the
  key — see :class:`repro.memory.pipeline.SemanticStage`.
* ``vectors`` let a caller that already embedded the KEYS (a replicating
  distributed cache, a benchmark with a prebuilt bank) ship those key
  embeddings instead of having every shard re-embed them. They feed the
  key-matching stages only — a context-matching stage (semantic) always
  embeds its context text itself.

``CacheStats`` lives here too (re-exported from ``repro.core.cache`` for
backward compatibility) so implementations share one accounting shape.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import (
    Any,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    TypeVar,
    runtime_checkable,
)

V = TypeVar("V")


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    lookup_time_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "inserts": self.inserts,
            "evictions": self.evictions,
            "lookup_time_s": round(self.lookup_time_s, 6),
        }


@runtime_checkable
class PlanStore(Protocol):
    """Batch-native keyword -> plan store.

    Implementations: :class:`repro.core.cache.PlanCache` and
    :class:`repro.core.distributed_cache.DistributedPlanCache`. Consumers
    (router, agent methods, harness) program against this protocol and
    never probe for optional capabilities.
    """

    stats: CacheStats

    def lookup_batch(
        self,
        keywords: Sequence[str],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
    ) -> List[Optional[Any]]: ...

    def insert_batch(
        self,
        items: Sequence[Tuple[str, Any]],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
        vectors: Optional[Any] = None,
    ) -> None: ...

    def lookup(
        self, keyword: str, *, context: Optional[str] = None
    ) -> Optional[Any]: ...

    def insert(
        self,
        keyword: str,
        value: Any,
        *,
        context: Optional[str] = None,
        vector: Optional[Any] = None,
    ) -> None: ...

    def remove(self, keyword: str) -> bool: ...

    def keys(self) -> List[str]: ...

    def clear(self) -> None: ...

    def __contains__(self, keyword: str) -> bool: ...

    def __len__(self) -> int: ...


class PlanStoreBase:
    """Singular ``lookup``/``insert`` as thin wrappers over the batch
    primitives — inherit this and implement only ``lookup_batch`` /
    ``insert_batch``."""

    def lookup(
        self, keyword: str, *, context: Optional[str] = None
    ) -> Optional[Any]:
        return self.lookup_batch([keyword], contexts=[context])[0]

    def insert(
        self,
        keyword: str,
        value: Any,
        *,
        context: Optional[str] = None,
        vector: Optional[Any] = None,
    ) -> None:
        self.insert_batch(
            [(keyword, value)],
            contexts=[context],
            vectors=None if vector is None else [vector],
        )


__all__ = ["CacheStats", "PlanStore", "PlanStoreBase", "V"]

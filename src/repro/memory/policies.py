"""Composable eviction policies for plan stores (paper §4.4).

The seed ``PlanCache`` hardcoded LRU (an ``OrderedDict``) with a TTL
special case threaded through the lookup path. Here eviction is a policy
OBJECT the store composes:

* ``lru``  — least-recently-used, O(1) victim selection (the paper default);
* ``lfu``  — least-frequently-used on the store's live hit counters;
* ``ttl``  — entries expire ``ttl_s`` after insert; wraps an inner policy
  that picks capacity victims (``PlanCache(ttl_s=...)`` builds this wrap
  automatically, so the historical kwarg keeps working);
* ``cost`` — cost-aware (paper §4.4): score each entry by the tokens a
  reuse saves times how often it is actually reused —
  ``(1 + reuses) * tokens_saved`` where ``reuses`` counts live store hits
  plus the template's own ``uses`` counter and ``tokens_saved`` is
  ``value.size_tokens()`` when the value is a
  :class:`~repro.core.template.PlanTemplate` (1 otherwise). The entry with
  the LEAST expected savings is evicted, so a hot, large template survives
  a flood of one-shot keywords that would churn it out of plain LRU.

The store drives the policy through five hooks (``on_insert`` /
``on_access`` / ``on_remove`` / ``expired`` / ``victim``); policies keep
only derived bookkeeping and the store's entry dict stays the single
source of truth.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Optional, Union


@dataclass
class CacheEntry:
    """One live store entry plus the accounting eviction policies read."""

    value: Any
    inserted_at: float
    hits: int = 0  # lookups served by this entry since (re)insert
    # insertion side-channel kept so a cold-tier spill can round-trip the
    # entry (repro.memory.tiered): the semantic-stage context string and
    # the key's embedding vector (None when the store has no fuzzy tier)
    context: Optional[str] = None
    vector: Any = None


class EvictionPolicy:
    """Base policy: no expiry, no victim preference (subclasses decide)."""

    name = "none"

    def reset(self) -> None:
        pass

    def on_insert(self, key: str, entry: CacheEntry) -> None:
        pass

    def on_access(self, key: str, entry: CacheEntry) -> None:
        pass

    def on_remove(self, key: str) -> None:
        pass

    def expired(self, key: str, entry: CacheEntry, now: float) -> bool:
        return False

    def victim(self, entries: Dict[str, CacheEntry]) -> str:
        """Key to evict when the store is over capacity. ``entries`` is the
        store's live dict (insertion-ordered); must not mutate it."""
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    """Least-recently-used; O(1) victim via a private recency list."""

    name = "lru"

    def __init__(self) -> None:
        self._order: "OrderedDict[str, None]" = OrderedDict()

    def reset(self) -> None:
        self._order.clear()

    def on_insert(self, key: str, entry: CacheEntry) -> None:
        self._order[key] = None
        self._order.move_to_end(key)

    def on_access(self, key: str, entry: CacheEntry) -> None:
        if key in self._order:
            self._order.move_to_end(key)

    def on_remove(self, key: str) -> None:
        self._order.pop(key, None)

    def victim(self, entries: Dict[str, CacheEntry]) -> str:
        return next(iter(self._order))


class LFUPolicy(EvictionPolicy):
    """Least-frequently-used on live hit counts; oldest breaks ties.

    Victim selection scans the entry dict (O(N)) — plan caches hold
    hundreds-to-thousands of templates and evict rarely, so a scan beats
    maintaining a frequency heap under the store lock.
    """

    name = "lfu"

    def victim(self, entries: Dict[str, CacheEntry]) -> str:
        return min(entries, key=lambda k: (entries[k].hits, entries[k].inserted_at))


class CostAwarePolicy(EvictionPolicy):
    """Evict the entry with the least expected tokens-saved (paper §4.4)."""

    name = "cost"

    @staticmethod
    def score(entry: CacheEntry) -> float:
        reuses = entry.hits + getattr(entry.value, "uses", 0)
        tokens_saved = 1
        size_fn = getattr(entry.value, "size_tokens", None)
        if callable(size_fn):
            tokens_saved = max(1, int(size_fn()))
        return float((1 + reuses) * tokens_saved)

    def victim(self, entries: Dict[str, CacheEntry]) -> str:
        return min(
            entries,
            key=lambda k: (self.score(entries[k]), entries[k].inserted_at),
        )


class TTLPolicy(EvictionPolicy):
    """Expire entries ``ttl_s`` after insert; delegate capacity pressure to
    an inner policy (LRU unless composed otherwise)."""

    name = "ttl"

    def __init__(self, ttl_s: float, inner: Optional[EvictionPolicy] = None):
        self.ttl_s = float(ttl_s)
        self.inner = inner if inner is not None else LRUPolicy()

    def reset(self) -> None:
        self.inner.reset()

    def on_insert(self, key: str, entry: CacheEntry) -> None:
        self.inner.on_insert(key, entry)

    def on_access(self, key: str, entry: CacheEntry) -> None:
        self.inner.on_access(key, entry)

    def on_remove(self, key: str) -> None:
        self.inner.on_remove(key)

    def expired(self, key: str, entry: CacheEntry, now: float) -> bool:
        return now - entry.inserted_at > self.ttl_s

    def victim(self, entries: Dict[str, CacheEntry]) -> str:
        return self.inner.victim(entries)


EVICTION_POLICIES = {
    "lru": LRUPolicy,
    "lfu": LFUPolicy,
    "cost": CostAwarePolicy,
}


def make_policy(
    spec: Union[str, EvictionPolicy] = "lru",
    *,
    ttl_s: Optional[float] = None,
) -> EvictionPolicy:
    """Resolve a policy spec; a ``ttl_s`` wraps the result in TTL expiry.

    ``spec`` is a registered name (``lru`` | ``lfu`` | ``cost`` | ``ttl``)
    or an already-built :class:`EvictionPolicy` instance (never share one
    instance between stores — its bookkeeping is per-store).
    """
    if isinstance(spec, EvictionPolicy):
        policy = spec
    elif spec == "ttl":
        if ttl_s is None:
            raise ValueError("eviction='ttl' requires ttl_s")
        return TTLPolicy(ttl_s)
    elif spec in EVICTION_POLICIES:
        policy = EVICTION_POLICIES[spec]()
    else:
        raise ValueError(
            f"unknown eviction policy {spec!r}; registered: "
            f"{sorted(EVICTION_POLICIES) + ['ttl']}"
        )
    if ttl_s is not None and not isinstance(policy, TTLPolicy):
        policy = TTLPolicy(ttl_s, policy)
    return policy


__all__ = [
    "CacheEntry",
    "CostAwarePolicy",
    "EVICTION_POLICIES",
    "EvictionPolicy",
    "LFUPolicy",
    "LRUPolicy",
    "TTLPolicy",
    "make_policy",
]

"""repro.memory — the plan-store API: protocol, policies, pipeline,
registry.

This package is the contract layer between the APC test-time memory and
everything that consumes it:

* :mod:`repro.memory.protocol`  — :class:`PlanStore`, the batch-native
  store protocol (``lookup_batch``/``insert_batch`` primitive, singular
  ops are :class:`PlanStoreBase` wrappers), plus :class:`CacheStats`;
* :mod:`repro.memory.policies`  — composable :class:`EvictionPolicy`
  objects (``lru`` | ``lfu`` | ``ttl`` | ``cost``), paper §4.4;
* :mod:`repro.memory.pipeline`  — :class:`MatchPipeline` of
  exact -> fuzzy -> semantic :class:`MatchStage` resolution stages;
* :mod:`repro.memory.registry`  — the ``@register_method`` agent-strategy
  registry the harness and benchmarks enumerate.

Implementations live in ``repro.core`` (:class:`~repro.core.cache.PlanCache`,
:class:`~repro.core.distributed_cache.DistributedPlanCache`, the method
strategies in :mod:`repro.core.methods`); see docs/architecture.md for the
composition guide and migration notes from the pre-protocol constructor
kwargs.
"""

from repro.memory.pipeline import (
    ExactStage,
    FuzzyStage,
    MatchPipeline,
    MatchStage,
    SemanticStage,
    build_pipeline,
)
from repro.memory.policies import (
    EVICTION_POLICIES,
    CacheEntry,
    CostAwarePolicy,
    EvictionPolicy,
    LFUPolicy,
    LRUPolicy,
    TTLPolicy,
    make_policy,
)
from repro.memory.protocol import CacheStats, PlanStore, PlanStoreBase
from repro.memory.tiered import ColdEntry, ColdTier, compact_template
from repro.memory.registry import (
    METHOD_REGISTRY,
    AgentMethod,
    get_method_class,
    make_method,
    method_names,
    register_method,
)

__all__ = [
    "AgentMethod",
    "CacheEntry",
    "CacheStats",
    "ColdEntry",
    "ColdTier",
    "CostAwarePolicy",
    "EVICTION_POLICIES",
    "EvictionPolicy",
    "ExactStage",
    "FuzzyStage",
    "LFUPolicy",
    "LRUPolicy",
    "MatchPipeline",
    "MatchStage",
    "METHOD_REGISTRY",
    "PlanStore",
    "PlanStoreBase",
    "SemanticStage",
    "TTLPolicy",
    "build_pipeline",
    "compact_template",
    "get_method_class",
    "make_method",
    "make_policy",
    "method_names",
    "register_method",
]

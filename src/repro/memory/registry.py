"""Method registry: agent run-strategies as registered, self-contained
classes.

``PlanActAgent.run_task`` used to be an ``if method == ...`` ladder over
five private ``_run_*`` functions, so adding a baseline (an
AgenticCache-style async planner, a Cortex-style semantic tier, the
``cascade`` hybrid) meant editing the agent's core loop. Now a method is a
class decorated with :func:`register_method`; the agent resolves it by
name, benchmarks and the harness enumerate :func:`method_names` instead of
keeping a parallel hand-written list, and every strategy funnels its result
through the same :class:`~repro.core.agent_loop.RunRecord` accounting
helper (``repro.core.methods.record``).

The registry itself is agent-agnostic — it stores classes keyed by name.
The concrete strategies live in ``repro.core.methods`` (importing that
module populates the registry).
"""

from __future__ import annotations

from typing import Any, Dict, List, Type


class AgentMethod:
    """One run strategy bound to one agent deployment.

    Subclass, decorate with ``@register_method(name)``, implement
    ``run(task) -> RunRecord``. ``setup()`` runs once at agent construction
    for per-deployment state (e.g. the semantic baseline's query store).
    """

    name = ""  # set by register_method

    def __init__(self, agent: Any):
        self.agent = agent
        self.setup()

    def setup(self) -> None:
        pass

    def run(self, task: Any):
        raise NotImplementedError


METHOD_REGISTRY: Dict[str, Type[AgentMethod]] = {}


def register_method(name: str):
    """Class decorator: register an :class:`AgentMethod` under ``name``."""

    def deco(cls: Type[AgentMethod]) -> Type[AgentMethod]:
        if not (isinstance(cls, type) and issubclass(cls, AgentMethod)):
            raise TypeError(f"{cls!r} is not an AgentMethod subclass")
        cls.name = name
        METHOD_REGISTRY[name] = cls
        return cls

    return deco


def get_method_class(name: str) -> Type[AgentMethod]:
    try:
        return METHOD_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; registered: {sorted(METHOD_REGISTRY)}"
        ) from None


def make_method(name: str, agent: Any) -> AgentMethod:
    return get_method_class(name)(agent)


def method_names() -> List[str]:
    """Registered method names, in registration order."""
    return list(METHOD_REGISTRY)


__all__ = [
    "METHOD_REGISTRY",
    "AgentMethod",
    "get_method_class",
    "make_method",
    "method_names",
    "register_method",
]

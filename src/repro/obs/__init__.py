"""repro.obs — the unified tracing + metrics spine for the serving path.

Two halves, one package:

* :mod:`repro.obs.registry` — label-keyed counters/gauges/histograms with
  p50/p90/p99, lock-safe, snapshot-to-dict. The four historical telemetry
  islands (``RouterMetrics``, ``memory.CacheStats``, ``index.
  LSHTelemetry``, ``DeviceBank`` H2D counters) are views over one
  :class:`MetricsRegistry`.
* :mod:`repro.obs.spans` — structured spans (``trace_span`` context
  manager + explicit ``start_span``/``end`` for async paths) threading
  router → distributed lookup → match-pipeline stage → index backend,
  with per-request cache-attribution events
  (:mod:`repro.obs.attribution`). Exporters
  (:mod:`repro.obs.exporters`): canonical JSONL and Chrome-trace format.

The clock is injectable end to end: under ``repro.sim`` spans bind to the
``VirtualClock`` and the exported span stream is byte-deterministic per
seed. ``python -m repro.obs`` runs a traced quickstart of the full
serving path; ``tools/check_trace.py`` validates its artifacts.
"""

from repro.obs.attribution import (
    AttributionCollector,
    collect,
    deposit,
    tokens_saved_estimate,
)
from repro.obs.exporters import (
    InMemoryExporter,
    JsonlExporter,
    chrome_trace,
    write_chrome_trace,
)
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    latency_buckets,
    pow2_buckets,
)
from repro.obs.spans import (
    NOOP_SPAN,
    NOOP_TRACER,
    NoopTracer,
    Span,
    Tracer,
    current_span,
    get_tracer,
    set_tracer,
    trace_span,
    use_tracer,
)

__all__ = [
    "AttributionCollector",
    "Counter",
    "Gauge",
    "Histogram",
    "InMemoryExporter",
    "JsonlExporter",
    "MetricsRegistry",
    "NOOP_SPAN",
    "NOOP_TRACER",
    "NoopTracer",
    "Span",
    "Tracer",
    "chrome_trace",
    "collect",
    "current_span",
    "deposit",
    "get_tracer",
    "latency_buckets",
    "pow2_buckets",
    "set_tracer",
    "tokens_saved_estimate",
    "trace_span",
    "use_tracer",
    "write_chrome_trace",
]

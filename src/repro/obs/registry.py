"""The metrics registry: counters, gauges, and bucketed histograms.

One :class:`MetricsRegistry` is the accounting spine for a serving path:
the router, the plan store(s), the match-pipeline stages, and the index
backends all register their counters here instead of keeping private
telemetry structs. The four historical islands — ``RouterMetrics``,
``memory.CacheStats``, ``index.LSHTelemetry``, ``DeviceBank``'s H2D
counters — are now *views* over this registry (their ``snapshot()``
schemas are unchanged), so one ``registry.snapshot()`` answers "where did
this request's tokens go" across every layer.

Design rules:

* **Label-keyed.** A metric instance is ``(name, labels)``; the same name
  with different labels (``shard="cache-0"`` vs ``shard="cache-1"``) is a
  distinct series. ``registry.counter(name, **labels)`` returns the ONE
  instance for that series — callers cache the handle and pay a plain
  lock-protected add per increment, no dict lookup on the hot path.
* **Lock-safe.** Every mutation takes the metric's own lock. This is what
  fixes the historical ``RouterMetrics`` race: async cache-generation
  workers increment from pool threads while ``route_batch`` mutates the
  same struct from request threads. ``Counter.inc`` is the contract for
  unlocked callers; the ``+=``-style property shims on the view classes
  are only safe under the owning store's lock (where all of them live).
* **Deterministic snapshots.** ``snapshot()`` sorts names and label sets,
  so serializing it with ``sort_keys=True`` is byte-stable — snapshots can
  join the sim's determinism contract.
* **Catalogued names.** Canonical metric names live in
  :mod:`repro.obs.names`; ``tools/check_docs.py`` fails CI when a
  catalogued name is missing from the docs, and ``tests/test_obs.py``
  fails when instrumentation registers a name outside the catalog.

Histogram percentiles are computed from bucket counts by linear
interpolation inside the winning bucket (the Prometheus rule), clamped to
the observed min/max so a single-bucket histogram still reports sane
values. ``tests/test_obs.py`` checks the math against ``np.percentile``
to within one bucket width.
"""

from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class Counter:
    """Monotonic (by convention) float/int accumulator. ``inc`` is
    lock-safe; ``set`` exists for the deprecated ``+=`` property shims,
    which are only safe under the owning store's lock."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value: float = 0

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def reset(self) -> None:
        self.set(0)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(Counter):
    """A value that goes up and down (arena capacity, pool depth)."""

    __slots__ = ()

    def dec(self, n: float = 1) -> None:
        self.inc(-n)


def latency_buckets(lo: float = 1e-6, hi: float = 120.0) -> Tuple[float, ...]:
    """Geometric (x2) bucket bounds for second-scale latencies."""
    out: List[float] = []
    b = lo
    while b < hi:
        out.append(b)
        b *= 2.0
    out.append(hi)
    return tuple(out)


def pow2_buckets(n: int = 32) -> Tuple[float, ...]:
    """Bounds 1, 2, 4, ... — bucket i counts values in [2^(i-1), 2^i)."""
    return tuple(float(1 << i) for i in range(n))


DEFAULT_LATENCY_BUCKETS = latency_buckets()


class Histogram:
    """Bucketed histogram with p50/p90/p99 by in-bucket interpolation.

    ``bounds`` are ascending upper bounds; an implicit +inf bucket catches
    overflow. Also tracks count/sum/min/max so means and tails survive the
    bucketing.
    """

    __slots__ = ("name", "labels", "bounds", "_lock", "_counts",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None,
                 labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        bs = tuple(bounds) if bounds is not None else DEFAULT_LATENCY_BUCKETS
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram bounds must be ascending: {bs}")
        self.bounds = bs
        self._lock = threading.Lock()
        self._counts = [0] * (len(bs) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)  # v <= bounds[i]
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._count = 0
            self._sum = 0.0
            self._min = float("inf")
            self._max = float("-inf")

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]. None when empty. Linear interpolation inside the
        winning bucket, clamped to observed min/max."""
        with self._lock:
            if self._count == 0:
                return None
            target = (q / 100.0) * self._count
            cum = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                lo = self.bounds[i - 1] if i > 0 else self._min
                hi = self.bounds[i] if i < len(self.bounds) else self._max
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if cum + c >= target:
                    frac = (target - cum) / c
                    return lo + (hi - lo) * min(1.0, max(0.0, frac))
                cum += c
            return self._max

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            count, total = self._count, self._sum
            counts = list(self._counts)
            mn = self._min if count else None
            mx = self._max if count else None
        out: Dict[str, Any] = {
            "count": count,
            "sum": round(total, 9),
            "min": mn,
            "max": mx,
            "mean": round(total / count, 9) if count else None,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }
        out["buckets"] = {
            (f"le_{self.bounds[i]:g}" if i < len(self.bounds) else "le_inf"): c
            for i, c in enumerate(counts) if c
        }
        return out


class MetricsRegistry:
    """Name+labels -> metric instance; one per serving spine.

    A metric name has ONE kind (counter | gauge | histogram) — asking for
    the same name with a different kind is a programming error and raises.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[Tuple[str, LabelKey], Any] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, Any],
             factory) -> Any:
        key = (name, _label_key(labels))
        with self._lock:
            known = self._kinds.get(name)
            if known is not None and known != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {known}, "
                    f"requested as {kind}"
                )
            inst = self._metrics.get(key)
            if inst is None:
                inst = factory(name, key[1])
                self._metrics[key] = inst
                self._kinds[name] = kind
            return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, bounds: Optional[Sequence[float]] = None,
                  **labels: Any) -> Histogram:
        return self._get(
            "histogram", name, labels,
            lambda n, lk: Histogram(n, bounds, lk),
        )

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._kinds)

    def reset(self) -> None:
        with self._lock:
            for m in self._metrics.values():
                m.reset()

    def snapshot(self) -> Dict[str, Any]:
        """{name: {label_str: value | histogram dict}}, fully sorted —
        ``json.dumps(snapshot(), sort_keys=True)`` is byte-stable."""
        with self._lock:
            items = sorted(self._metrics.items())
        out: Dict[str, Any] = {}
        for (name, lk), m in items:
            series = out.setdefault(name, {})
            val = m.snapshot() if isinstance(m, Histogram) else m.value
            series[_label_str(lk)] = val
        return out


__all__ = [
    "Counter",
    "DEFAULT_LATENCY_BUCKETS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "latency_buckets",
    "pow2_buckets",
]

"""Traced quickstart: drive the full serving path with tracing + metrics on.

    python -m repro.obs --out-dir trace-out [--requests 24] [--nodes 2]

Builds a fuzzy :class:`DistributedPlanCache` behind a
:class:`TwoTierRouter`, routes a few admission waves (repeats + paraphrases
so exact and fuzzy hits both occur), and writes:

* ``trace.jsonl``        — one canonical JSON span per line
* ``trace_chrome.json``  — Chrome trace-event timeline (chrome://tracing,
  https://ui.perfetto.dev)
* ``metrics.json``       — the full registry snapshot

``tools/check_trace.py`` validates these artifacts; the smoke workflow
runs both and uploads the trace as a build artifact.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

from repro.core.distributed_cache import DistributedPlanCache
from repro.obs import (
    InMemoryExporter,
    JsonlExporter,
    MetricsRegistry,
    Tracer,
    use_tracer,
    write_chrome_trace,
)
from repro.serving.router import TwoTierRouter


def _requests(n: int) -> List[dict]:
    """A workload with guaranteed repeats and near-duplicates: round r of
    the same keyword set re-arrives with light paraphrasing."""
    base = [
        "book flight to tokyo",
        "summarize quarterly report",
        "plan team offsite",
        "debug pallas kernel",
        "write launch email",
        "review pull request",
    ]
    out = []
    for i in range(n):
        kw = base[i % len(base)]
        if (i // len(base)) % 2 == 1:
            kw = kw + " please"  # paraphrase: lands on the fuzzy stage
        out.append({"query": kw})
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="trace-out")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--batch", type=int, default=6)
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    registry = MetricsRegistry()
    mem = InMemoryExporter()
    jsonl_path = os.path.join(args.out_dir, "trace.jsonl")
    tracer = Tracer(exporters=[mem, JsonlExporter(jsonl_path)])

    cache = DistributedPlanCache(
        n_nodes=args.nodes, fuzzy=True, capacity_per_node=64, obs=registry
    )
    router = TwoTierRouter(
        cache,
        extract_keyword=lambda r: r["query"],
        plan_large=lambda r: {"plan": f"fresh plan for {r['query']}"},
        plan_small_with_template=lambda r, t: {"plan": "adapted", "from": t},
        make_template=lambda r, res: res["plan"],
        async_cachegen=True,
    )

    reqs = _requests(args.requests)
    with use_tracer(tracer):
        for i in range(0, len(reqs), args.batch):
            router.route_batch(reqs[i : i + args.batch])
        router.drain()
    router.close()
    tracer.close()

    chrome_path = os.path.join(args.out_dir, "trace_chrome.json")
    write_chrome_trace(chrome_path, mem.spans)
    metrics_path = os.path.join(args.out_dir, "metrics.json")
    with open(metrics_path, "w") as f:
        json.dump(registry.snapshot(), f, sort_keys=True, indent=1)
        f.write("\n")

    m = router.metrics.snapshot()
    print(f"routed {m['requests']} requests  "
          f"hit_rate={m['hit_rate']:.2f}  tokens_saved={m['tokens_saved']}")
    print(f"spans: {tracer.n_spans}  digest={mem.digest()}")
    for p in (jsonl_path, chrome_path, metrics_path):
        print(f"wrote {p}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

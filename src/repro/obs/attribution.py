"""Per-request cache attribution: WHERE did a hit come from, WHAT did it
save.

The router sees only ``lookup_batch -> Optional[template]``; the layers
underneath know the interesting part — which pipeline stage resolved the
query (exact | fuzzy | semantic), which shard and replica tier answered,
what key it matched. This module carries that detail back up WITHOUT
widening the ``PlanStore`` protocol: the router opens a context-local
:class:`AttributionCollector` around its lookup, every resolving layer
calls :func:`deposit` (a no-op when no collector is open), and facade
layers re-map indices as the batch narrows:

* ``PlanCache.lookup_batch`` deposits ``stage`` + ``matched_key`` at its
  local batch index;
* ``DistributedPlanCache.lookup_batch`` opens a nested collector around
  each per-shard call, then re-deposits at the facade's indices with
  ``node`` and ``tier`` added (contextvars nest, so the inner collector
  shadows the outer one for exactly the duration of the shard call);
* the router joins the collected detail with the §4.4 cost model
  (:func:`tokens_saved_estimate`) and emits one ``cache.attribution``
  span event per request.

Deposits are thread-local by construction (a collector is visible only to
the call stack that opened it), so concurrent ``route_batch`` waves never
see each other's attributions.
"""

from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from typing import Any, Dict, Optional

_collector: ContextVar[Optional["AttributionCollector"]] = ContextVar(
    "repro_obs_attribution", default=None
)


class AttributionCollector:
    """index -> merged attribution dict for one lookup batch."""

    __slots__ = ("info",)

    def __init__(self):
        self.info: Dict[int, Dict[str, Any]] = {}

    def deposit(self, i: int, **fields: Any) -> None:
        self.info.setdefault(i, {}).update(fields)

    def get(self, i: int) -> Dict[str, Any]:
        return self.info.get(i, {})

    def items(self):
        return self.info.items()


@contextmanager
def collect():
    """Open a collector for the enclosed lookup; nested opens shadow."""
    c = AttributionCollector()
    token = _collector.set(c)
    try:
        yield c
    finally:
        _collector.reset(token)


def deposit(i: int, **fields: Any) -> None:
    """Attach attribution fields to batch index ``i`` of the innermost
    open collector; silently a no-op when none is open (un-traced paths
    pay one contextvar read)."""
    c = _collector.get()
    if c is not None:
        c.deposit(i, **fields)


def tokens_saved_estimate(template: Any) -> int:
    """§4.4 cost-model attribution for one hit: the large-planner output
    tokens a cached template avoids regenerating. Templates that expose
    ``size_tokens()`` (:class:`repro.core.template.PlanTemplate`) answer
    exactly; anything else is estimated from its serialized length (the
    chars/4 heuristic the cost model uses everywhere)."""
    size = getattr(template, "size_tokens", None)
    if callable(size):
        try:
            return int(size())
        except Exception:
            pass
    return max(1, len(str(template)) // 4)


__all__ = [
    "AttributionCollector",
    "collect",
    "deposit",
    "tokens_saved_estimate",
]

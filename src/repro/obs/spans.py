"""Structured spans: the tracing half of ``repro.obs``.

A :class:`Span` is one timed operation with attributes and point-in-time
events; spans nest via a context-local "current span", so instrumented
library code — router, distributed cache, match pipeline, index backends —
composes into one tree per request without threading a span handle through
every call signature:

    with trace_span("router.route_batch", batch=len(reqs)) as sp:
        ...                       # children attach to sp automatically
        sp.event("cache.attribution", i=0, hit=True, tokens_saved=412)

Two APIs, one span type:

* ``trace_span(name, **attrs)`` — context manager; sets/restores the
  current span (contextvar), so synchronous nesting is automatic.
* ``tracer.start_span(name, parent=..., **attrs)`` + ``span.end()`` — the
  explicit API for async paths (the router's cache-generation workers run
  on pool threads where the contextvar is empty; they capture the parent
  span at submit time and finish the span whenever the work lands).

Determinism contract: span ids are SEQUENTIAL per tracer (allocated under
a lock), never random, and timestamps come from the tracer's injectable
``clock``. Under ``repro.sim`` the tracer binds to the
:class:`~repro.sim.clock.VirtualClock`, so the exported span stream is a
pure function of ``(seed, config)`` — byte-identical across runs — and
joins the sim's trace-hash determinism contract.

When no tracer is installed, ``trace_span`` hands back a shared no-op
span: no allocation, no clock read, no lock — instrumentation left in hot
paths costs one truthiness check.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Any, Callable, Dict, List, Optional


class Span:
    """One timed operation. Created by a :class:`Tracer`; ended exactly
    once (idempotent ``end``)."""

    __slots__ = ("name", "span_id", "parent_id", "start", "end_time",
                 "attrs", "events", "_tracer", "_token")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: Optional[int], start: float,
                 attrs: Dict[str, Any]):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.start = start
        self.end_time: Optional[float] = None
        self.attrs = attrs
        self.events: List[Dict[str, Any]] = []
        self._tracer = tracer
        self._token = None

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs: Any) -> None:
        """A point-in-time event on this span (e.g. one request's
        cache-attribution record)."""
        self.events.append(
            {"name": name, "t": self._tracer.clock(), "attrs": attrs}
        )

    def end(self) -> None:
        if self.end_time is None:
            self.end_time = self._tracer.clock()
            self._tracer._finish(self)

    # -- context-manager protocol (sets/restores the current span) --------

    def __enter__(self) -> "Span":
        self._token = _current_span.set(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._token is not None:
            _current_span.reset(self._token)
            self._token = None
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "start": self.start,
            "end": self.end_time,
            "attrs": self.attrs,
            "events": self.events,
        }


class _NoopSpan:
    """Shared do-nothing span: the cost of disabled tracing."""

    __slots__ = ()
    name = ""
    span_id = -1
    parent_id = None
    attrs: Dict[str, Any] = {}
    events: List[Dict[str, Any]] = []

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self

    def event(self, name: str, **attrs: Any) -> None:
        pass

    def end(self) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NOOP_SPAN = _NoopSpan()

# context-local current span: per-thread, per-context; pool threads start
# empty (async paths pass parents explicitly via start_span)
_current_span: ContextVar[Optional[Span]] = ContextVar(
    "repro_obs_current_span", default=None
)


class Tracer:
    """Span factory + exporter fan-out with an injectable clock.

    ``clock`` is any ``() -> float``; production uses the monotonic perf
    counter, ``repro.sim`` passes its :class:`VirtualClock` so span
    streams are deterministic per seed.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 exporters: Optional[List[Any]] = None):
        self.clock = clock if clock is not None else time.perf_counter
        self.exporters: List[Any] = list(exporters or [])
        self._lock = threading.Lock()
        self._next_id = 1
        self.n_spans = 0

    def _alloc_id(self) -> int:
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            return sid

    def span(self, name: str, **attrs: Any) -> Span:
        """A span parented on the context-local current span. Use as a
        context manager (``with tracer.span(...)``)."""
        parent = _current_span.get()
        return Span(self, name, self._alloc_id(),
                    None if parent is None else parent.span_id,
                    self.clock(), attrs)

    def start_span(self, name: str, *, parent: Optional[Span] = None,
                   **attrs: Any) -> Span:
        """Explicit-parent span for async paths; caller must ``end()`` it
        (it does NOT install itself as the current span)."""
        pid = None
        if parent is not None and not isinstance(parent, _NoopSpan):
            pid = parent.span_id
        return Span(self, name, self._alloc_id(), pid, self.clock(), attrs)

    def _finish(self, span: Span) -> None:
        with self._lock:
            self.n_spans += 1
            for e in self.exporters:
                e.export(span)

    def close(self) -> None:
        for e in self.exporters:
            close = getattr(e, "close", None)
            if close is not None:
                close()


class NoopTracer:
    """Installed by default: every span is the shared no-op span."""

    clock = staticmethod(time.perf_counter)
    n_spans = 0

    def span(self, name: str, **attrs: Any) -> _NoopSpan:
        return NOOP_SPAN

    def start_span(self, name: str, *, parent: Optional[Any] = None,
                   **attrs: Any) -> _NoopSpan:
        return NOOP_SPAN

    def close(self) -> None:
        pass


NOOP_TRACER = NoopTracer()

# process-global active tracer. A module global (not a contextvar) on
# purpose: worker threads spawned by the router/tier pools must see the
# tracer installed by the main thread. Installation is scoped via
# use_tracer(); concurrent *different* tracers in one process are not a
# supported configuration (tests serialize through use_tracer).
_active: Any = NOOP_TRACER
_active_lock = threading.Lock()


def get_tracer() -> Any:
    return _active


def set_tracer(tracer: Optional[Any]) -> Any:
    """Install (or, with None, uninstall) the process-global tracer;
    returns the previous one."""
    global _active
    with _active_lock:
        prev = _active
        _active = tracer if tracer is not None else NOOP_TRACER
        return prev


@contextmanager
def use_tracer(tracer: Any):
    """Scoped install: ``with use_tracer(Tracer(...)) as tr: ...``"""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


def trace_span(name: str, **attrs: Any):
    """The instrumentation entry point: a context-managed span on the
    active tracer (no-op when tracing is disabled)."""
    return _active.span(name, **attrs)


def current_span() -> Any:
    """The context-local current span (NOOP_SPAN when none) — use it to
    attach events from instrumented library code."""
    sp = _current_span.get()
    return NOOP_SPAN if sp is None else sp


__all__ = [
    "NOOP_SPAN",
    "NOOP_TRACER",
    "NoopTracer",
    "Span",
    "Tracer",
    "current_span",
    "get_tracer",
    "set_tracer",
    "trace_span",
    "use_tracer",
]

"""The canonical catalog of metric names, span kinds, and event kinds.

Instrumented code references these constants instead of writing string
literals, and two gates keep the catalog honest:

* ``tools/check_docs.py`` reads the literals below via the AST (no import
  needed) and fails CI when any catalogued name is missing from the docs
  corpus — adding a metric or span kind without documenting it is a build
  failure;
* ``tests/test_obs.py`` runs a traced serving path and fails when the
  registry or tracer saw a name OUTSIDE this catalog — so the catalog
  can't silently under-report the instrumented surface either.

The tuples below must stay pure literals (the docs gate parses, it does
not import).
"""

from __future__ import annotations

# -- metrics (see docs/observability.md for semantics & units) -------------

ROUTER_REQUESTS = "router.requests"
ROUTER_HITS = "router.hits"
ROUTER_MISSES = "router.misses"
ROUTER_LARGE_TIER_CALLS = "router.large_tier_calls"
ROUTER_SMALL_TIER_CALLS = "router.small_tier_calls"
ROUTER_ASYNC_CACHEGENS = "router.async_cachegens"
ROUTER_SYNC_CACHEGEN_FALLBACKS = "router.sync_cachegen_fallbacks"
ROUTER_CACHEGEN_DROPPED = "router.cachegen_dropped"
ROUTER_LOOKUP_S = "router.lookup_s"
ROUTER_LOOKUP_LATENCY = "router.lookup_latency_s"
ROUTER_TOKENS_SAVED = "router.tokens_saved"
ROUTER_SPECULATIONS = "router.speculations"
ROUTER_SPEC_COMMITS = "router.spec_commits"
ROUTER_SPEC_ROLLBACKS = "router.spec_rollbacks"
ROUTER_SPEC_SYNC_VERIFIES = "router.spec_sync_verifies"
ROUTER_SPEC_DROPPED = "router.spec_dropped"

CACHE_HITS = "cache.hits"
CACHE_MISSES = "cache.misses"
CACHE_INSERTS = "cache.inserts"
CACHE_EVICTIONS = "cache.evictions"
CACHE_LOOKUP_TIME_S = "cache.lookup_time_s"
CACHE_COLD_HITS = "cache.cold_hits"
CACHE_SPILLS = "cache.spills"
CACHE_PROMOTES = "cache.promotes"
CACHE_COMPACTION_SAVED_TOKENS = "cache.compaction_saved_tokens"
CACHE_STALE_INSERT_SKIPS = "cache.stale_insert_skips"

LSH_QUERIES = "index.lsh.queries"
LSH_PROBED_QUERIES = "index.lsh.probed_queries"
LSH_BRUTE_FALLBACK_QUERIES = "index.lsh.brute_fallback_queries"
LSH_CANDIDATES_TOTAL = "index.lsh.candidates_total"
LSH_EMPTY_CANDIDATE_QUERIES = "index.lsh.empty_candidate_queries"
LSH_CANDIDATES = "index.lsh.candidates"
LSH_RECALL_CHECKS = "index.lsh.recall_checks"
LSH_RECALL_AGREEMENTS = "index.lsh.recall_agreements"

KV_PAGES_HIT = "kv.pages_hit"
KV_PAGES_BUILT = "kv.pages_built"
KV_TOKENS_PREFETCHED = "kv.tokens_prefetched"
KV_PREFIX_EVICTIONS = "kv.prefix_evictions"

DEVICE_CAPACITY = "index.device.capacity"
DEVICE_H2D_BYTES = "index.device.h2d_bytes_total"
DEVICE_ROW_UPDATES = "index.device.row_updates"
DEVICE_BATCHED_UPDATES = "index.device.batched_updates"
DEVICE_CLEARS = "index.device.clears"
DEVICE_GROWS = "index.device.grows"

METRIC_NAMES = (
    "router.requests",
    "router.hits",
    "router.misses",
    "router.large_tier_calls",
    "router.small_tier_calls",
    "router.async_cachegens",
    "router.sync_cachegen_fallbacks",
    "router.cachegen_dropped",
    "router.lookup_s",
    "router.lookup_latency_s",
    "router.tokens_saved",
    "router.speculations",
    "router.spec_commits",
    "router.spec_rollbacks",
    "router.spec_sync_verifies",
    "router.spec_dropped",
    "cache.hits",
    "cache.misses",
    "cache.inserts",
    "cache.evictions",
    "cache.lookup_time_s",
    "cache.cold_hits",
    "cache.spills",
    "cache.promotes",
    "cache.compaction_saved_tokens",
    "cache.stale_insert_skips",
    "kv.pages_hit",
    "kv.pages_built",
    "kv.tokens_prefetched",
    "kv.prefix_evictions",
    "index.lsh.queries",
    "index.lsh.probed_queries",
    "index.lsh.brute_fallback_queries",
    "index.lsh.candidates_total",
    "index.lsh.empty_candidate_queries",
    "index.lsh.candidates",
    "index.lsh.recall_checks",
    "index.lsh.recall_agreements",
    "index.device.capacity",
    "index.device.h2d_bytes_total",
    "index.device.row_updates",
    "index.device.batched_updates",
    "index.device.clears",
    "index.device.grows",
)

# -- span kinds ------------------------------------------------------------

SPAN_ROUTE = "router.route"
SPAN_ROUTE_BATCH = "router.route_batch"
SPAN_ROUTER_LOOKUP = "router.lookup"
SPAN_CACHEGEN = "router.cachegen"
SPAN_SPEC_VERIFY = "router.spec_verify"
SPAN_DCACHE_LOOKUP = "dcache.lookup_batch"
SPAN_DCACHE_INSERT = "dcache.insert_batch"
SPAN_DCACHE_TIER = "dcache.tier"
SPAN_SHARD_CALL = "dcache.shard_call"
SPAN_CACHE_LOOKUP = "cache.lookup_batch"
SPAN_CACHE_INSERT = "cache.insert_batch"
SPAN_CACHE_SPILL = "cache.spill"
SPAN_CACHE_PROMOTE = "cache.promote"
SPAN_MATCH_STAGE = "match.stage"
SPAN_INDEX_TOPK = "index.topk"
SPAN_ENGINE_GENERATE = "engine.generate"

SPAN_NAMES = (
    "router.route",
    "router.route_batch",
    "router.lookup",
    "router.cachegen",
    "router.spec_verify",
    "dcache.lookup_batch",
    "dcache.insert_batch",
    "dcache.tier",
    "dcache.shard_call",
    "cache.lookup_batch",
    "cache.insert_batch",
    "cache.spill",
    "cache.promote",
    "match.stage",
    "index.topk",
    "engine.generate",
)

# -- span event kinds ------------------------------------------------------

EVENT_ATTRIBUTION = "cache.attribution"
EVENT_CACHEGEN_FATE = "cachegen.fate"
EVENT_SPEC_FATE = "spec.fate"

EVENT_NAMES = (
    "cache.attribution",
    "cachegen.fate",
    "spec.fate",
)

__all__ = [n for n in dir() if n.isupper()]

"""Span exporters: canonical JSONL and Chrome-trace-format timelines.

Spans are exported as they END (children before parents). Two sinks:

* :class:`JsonlExporter` / :class:`InMemoryExporter` — one canonical JSON
  line per span (``sort_keys``, fixed separators), so two deterministic
  runs produce byte-identical files; ``InMemoryExporter.digest()`` is the
  blake2b of that byte stream, the value the sim's determinism check
  compares across reruns.
* :func:`write_chrome_trace` — the Chrome trace-event format
  (``chrome://tracing`` or https://ui.perfetto.dev load it directly):
  complete ``"X"`` events with microsecond ts/dur, span attributes under
  ``args``, and span events as instant ``"i"`` markers.
"""

from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, IO, List, Optional, Union

from repro.obs.spans import Span


def span_line(span_dict: Dict[str, Any]) -> str:
    """Canonical one-line JSON for a span dict (byte-stable)."""
    return json.dumps(span_dict, sort_keys=True, separators=(",", ":"),
                      default=repr)


class InMemoryExporter:
    """Collects finished spans; test/sim sink."""

    def __init__(self):
        self.spans: List[Dict[str, Any]] = []

    def export(self, span: Span) -> None:
        self.spans.append(span.to_dict())

    def lines(self) -> List[str]:
        return [span_line(s) for s in self.spans]

    def digest(self) -> str:
        h = hashlib.blake2b(digest_size=16)
        for line in self.lines():
            h.update(line.encode())
            h.update(b"\n")
        return h.hexdigest()


class JsonlExporter:
    """Streams canonical span lines to a path or open file."""

    def __init__(self, path_or_file: Union[str, IO[str]]):
        if isinstance(path_or_file, str):
            self._f: IO[str] = open(path_or_file, "w")
            self._owns = True
        else:
            self._f = path_or_file
            self._owns = False

    def export(self, span: Span) -> None:
        self._f.write(span_line(span.to_dict()))
        self._f.write("\n")

    def close(self) -> None:
        self._f.flush()
        if self._owns:
            self._f.close()


def chrome_trace(span_dicts: List[Dict[str, Any]],
                 process_name: str = "repro") -> Dict[str, Any]:
    """Chrome trace-event JSON for a list of finished span dicts.

    Seconds -> microseconds; every span becomes one complete ``"X"``
    event, every span event an instant ``"i"`` marker. ``tid`` carries the
    root span id of each tree so one admission wave reads as one track.
    """
    roots: Dict[int, int] = {}
    by_id = {s["span_id"]: s for s in span_dicts}

    def root_of(sid: int) -> int:
        seen = []
        cur = sid
        while cur in by_id and by_id[cur]["parent_id"] is not None \
                and by_id[cur]["parent_id"] in by_id:
            seen.append(cur)
            cur = by_id[cur]["parent_id"]
        for s in seen:
            roots[s] = cur
        return cur

    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name},
    }]
    for s in span_dicts:
        tid = roots.get(s["span_id"]) or root_of(s["span_id"])
        end = s["end"] if s["end"] is not None else s["start"]
        events.append({
            "name": s["name"],
            "cat": s["name"].split(".", 1)[0],
            "ph": "X",
            "ts": round(s["start"] * 1e6, 3),
            "dur": round((end - s["start"]) * 1e6, 3),
            "pid": 0,
            "tid": tid,
            "args": dict(s["attrs"], span_id=s["span_id"],
                         parent_id=s["parent_id"]),
        })
        for ev in s["events"]:
            events.append({
                "name": ev["name"],
                "cat": "event",
                "ph": "i",
                "s": "t",
                "ts": round(ev["t"] * 1e6, 3),
                "pid": 0,
                "tid": tid,
                "args": dict(ev["attrs"]),
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, span_dicts: List[Dict[str, Any]],
                       process_name: str = "repro") -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(span_dicts, process_name), f,
                  sort_keys=True, separators=(",", ":"), default=repr)
        f.write("\n")


__all__ = [
    "InMemoryExporter",
    "JsonlExporter",
    "chrome_trace",
    "span_line",
    "write_chrome_trace",
]

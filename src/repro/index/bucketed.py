"""Signed-random-projection LSH over an EmbeddingBank: sublinear candidates.

The brute/Pallas backends still touch all N rows per lookup; at 1e6 cache
entries that is the Table 5 scaling cliff. This index hashes each row into
``n_tables`` independent ``n_bits``-bit signatures (sign patterns of
projections onto fixed random hyperplanes — SRP-LSH, per-bit collision
probability 1 - theta/pi for angle theta) and, at query time, scans only
the buckets within Hamming distance ``probe_hamming`` of the query's
signature in *each* table (multi-probe, multi-table). A neighbor is missed
only if it flips >probe_hamming bits in every table simultaneously: at
4 tables x 12 bits x 1-probe, recall at cosine 0.85 is ~0.9 versus ~0.4
for a single 16-bit table, while expected candidates stay
~ n_tables * (n_bits + 1) * N / 2^n_bits. By default ``n_bits`` adapts
(grows with the bank, ~log2(N)) so lookup cost stays roughly flat as N
scales; see ``__init__``.

Below ``scan_threshold`` live entries the index transparently falls back to
the exact brute scan — at small N the full matmul is both faster and
recall-perfect, so LSH only ever replaces the regime where it wins.

Maintenance is incremental: ``on_add``/``on_remove`` are
O(n_tables * n_bits) per key (one small matvec + set ops), called by
SimilarityIndex/EmbeddingBank users under their own locks.

Thread-safety contract: BucketedIndex has no lock of its own. Mutation
(``on_add`` / ``on_remove`` / ``clear``) must run under the owning bank's
lock — SimilarityIndex guarantees this — because it rewrites the bucket
dicts and may trigger an adaptive-geometry rebuild. Queries
(``best_slot`` / ``topk`` / ``candidates``) are unlocked reads; a caller
that interleaves queries with writers and needs a consistent view holds
``bank.lock`` across the query (PlanCache's RLock does this transitively).
The :class:`LSHTelemetry` counters on the query path are registry-backed
(each increment takes the counter's own lock) and never control-critical.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.index.bank import DIM, EmbeddingBank
from repro.obs import MetricsRegistry, pow2_buckets
from repro.obs import names as _names

NEG_INF = np.float32(-1e30)


def _tele_prop(field: str):
    def get(self):
        return int(self._counters[field].value)

    return property(get)


class LSHTelemetry:
    """Live quality/cost counters for one BucketedIndex.

    Serving reads ``snapshot()`` to auto-tune ``n_bits``/``probe_hamming``:
    rising ``avg_candidates`` means the tables are under-sized (grow
    ``n_bits``); a falling ``top1_agreement`` or rising
    ``empty_candidate_rate`` means probes miss too often (grow
    ``probe_hamming`` or ``n_tables``). Recall is measured *live* by
    re-answering every ``recall_sample_every``-th probed query with the
    exact brute scan and recording top-1 agreement — an amortized-O(1)
    overhead instead of an offline sweep (the f3 benchmark's job).

    Registry-backed view over :mod:`repro.obs` counters plus one pow-2
    histogram of per-query candidate counts; the historical field reads
    and the ``snapshot()`` schema are unchanged.
    """

    _FIELDS = {
        "queries": _names.LSH_QUERIES,
        "brute_fallback_queries": _names.LSH_BRUTE_FALLBACK_QUERIES,
        "probed_queries": _names.LSH_PROBED_QUERIES,
        "candidates_total": _names.LSH_CANDIDATES_TOTAL,
        "empty_candidate_queries": _names.LSH_EMPTY_CANDIDATE_QUERIES,
        "recall_checks": _names.LSH_RECALL_CHECKS,
        "recall_agreements": _names.LSH_RECALL_AGREEMENTS,
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 **labels: str):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._counters = {
            field: self.registry.counter(name, **labels)
            for field, name in self._FIELDS.items()
        }
        # per-query candidate counts: bucket ``le_2^b`` counts queries that
        # scanned (2^(b-1), 2^b] candidates (the first also holds 0)
        self._candidates = self.registry.histogram(
            _names.LSH_CANDIDATES, bounds=pow2_buckets(32), **labels
        )

    queries = _tele_prop("queries")
    brute_fallback_queries = _tele_prop("brute_fallback_queries")
    probed_queries = _tele_prop("probed_queries")
    candidates_total = _tele_prop("candidates_total")
    empty_candidate_queries = _tele_prop("empty_candidate_queries")
    recall_checks = _tele_prop("recall_checks")
    recall_agreements = _tele_prop("recall_agreements")

    def observe_brute(self) -> None:
        self._counters["queries"].inc()
        self._counters["brute_fallback_queries"].inc()

    def observe_probe(self, n_candidates: int) -> None:
        self._counters["queries"].inc()
        self._counters["probed_queries"].inc()
        self._counters["candidates_total"].inc(n_candidates)
        if n_candidates == 0:
            self._counters["empty_candidate_queries"].inc()
        self._candidates.observe(n_candidates)

    def observe_recall(self, agreed: bool) -> None:
        self._counters["recall_checks"].inc()
        self._counters["recall_agreements"].inc(int(agreed))

    def reset(self) -> None:
        """Fresh telemetry window (autotune calls this after acting);
        zeros only this view's series, never the whole registry."""
        for c in self._counters.values():
            c.reset()
        self._candidates.reset()

    def snapshot(self) -> Dict[str, Any]:
        probed = max(1, self.probed_queries)
        hist = self._candidates.snapshot()["buckets"]
        return {
            "queries": self.queries,
            "probed_queries": self.probed_queries,
            "brute_fallback_queries": self.brute_fallback_queries,
            "avg_candidates": round(self.candidates_total / probed, 2),
            "empty_candidate_rate": round(
                self.empty_candidate_queries / probed, 4
            ),
            "candidate_hist": {
                f"2^{b}": hist[k]
                for b, k in enumerate(
                    f"le_{bound:g}" for bound in self._candidates.bounds
                )
                if k in hist
            },
            "top1_agreement": (
                round(self.recall_agreements / self.recall_checks, 4)
                if self.recall_checks
                else None
            ),
            "recall_checks": self.recall_checks,
        }


def _brute_topk(
    matrix: np.ndarray, queries: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact numpy top-k over ``matrix`` rows; shared fallback path."""
    Q = queries.shape[0]
    N = matrix.shape[0]
    scores = np.full((Q, k), NEG_INF, np.float32)
    idx = np.full((Q, k), -1, np.int32)
    if N == 0 or Q == 0:
        return scores, idx
    s = queries.astype(np.float32) @ matrix.T  # (Q, N)
    kk = min(k, N)
    if kk < N:
        part = np.argpartition(-s, kk - 1, axis=1)[:, :kk]
    else:
        part = np.broadcast_to(np.arange(N), (Q, N)).copy()
    ps = np.take_along_axis(s, part, axis=1)
    order = np.argsort(-ps, axis=1, kind="stable")
    scores[:, :kk] = np.take_along_axis(ps, order, axis=1)
    idx[:, :kk] = np.take_along_axis(part, order, axis=1).astype(np.int32)
    return scores, idx


class BucketedIndex:
    """Multi-table multi-probe SRP-LSH + exact rerank over a bank."""

    MAX_BITS = 20
    TARGET_OCCUPANCY = 4  # resize when avg live entries per bucket exceeds this

    def __init__(
        self,
        bank: EmbeddingBank,
        *,
        n_tables: int = 4,
        n_bits: Optional[int] = None,
        seed: int = 0,
        probe_hamming: int = 1,
        scan_threshold: int = 2048,
        recall_sample_every: int = 64,
        obs: Optional[MetricsRegistry] = None,
        obs_labels: Optional[Dict[str, str]] = None,
    ):
        """``n_bits=None`` (default) adapts: start at 12 bits and rebuild
        with +2 bits whenever average bucket occupancy exceeds
        ``TARGET_OCCUPANCY`` — keeping n_bits ~ log2(N) so candidate count
        (and lookup cost) stays roughly flat as the bank grows. Rebuilds
        re-hash every live row in one vectorized matmul, amortized O(1)
        per insert. An explicit ``n_bits`` pins the table size."""
        self._adaptive = n_bits is None
        n_bits = 12 if n_bits is None else n_bits
        assert 1 <= n_bits <= 30 and n_tables >= 1
        # the probe ball is enumerated up to Hamming distance 2; reject
        # larger radii instead of silently under-probing
        assert 0 <= probe_hamming <= 2, probe_hamming
        self.bank = bank
        self.n_tables = n_tables
        self.probe_hamming = probe_hamming
        self.scan_threshold = scan_threshold
        # live quality counters; every recall_sample_every-th probed query
        # is re-answered exactly to measure recall in production (0: off)
        self.telemetry = LSHTelemetry(obs, **(obs_labels or {}))
        self._recall_every = recall_sample_every
        self._seed = seed
        self._set_geometry(n_bits)
        # bootstrap from whatever the bank already holds (batched hashing)
        self._rebuild()

    def _set_geometry(self, n_bits: int) -> None:
        self.n_bits = n_bits
        rs = np.random.RandomState(self._seed + n_bits)
        # one (DIM, n_bits) hyperplane block per table, drawn contiguously
        self._planes = rs.randn(DIM, self.n_tables * n_bits).astype(np.float32)
        self._buckets: List[Dict[int, Set[int]]] = [
            {} for _ in range(self.n_tables)
        ]
        self._sigs_of: Dict[int, Tuple[int, ...]] = {}
        self._bit_weights = (1 << np.arange(n_bits)).astype(np.int64)
        self._set_probe_masks()

    def _set_probe_masks(self) -> None:
        # XOR masks enumerating the probe ball once: [0, single bits, pairs]
        n_bits = self.n_bits
        masks = [0]
        if self.probe_hamming >= 1:
            masks += [1 << b for b in range(n_bits)]
        if self.probe_hamming >= 2:
            masks += [
                (1 << b1) ^ (1 << b2)
                for b1 in range(n_bits)
                for b2 in range(b1 + 1, n_bits)
            ]
        self._probe_masks = np.asarray(masks, np.int64)

    def _rebuild(self) -> None:
        keys = self.bank.keys()
        if not keys:
            return
        slots = [self.bank.slot_of(k) for k in keys]
        sig_mat = self._signatures(self.bank.matrix()[slots])
        for slot, sigs in zip(slots, sig_mat):
            self._insert_sigs(slot, tuple(int(s) for s in sigs))

    def _maybe_grow(self) -> None:
        if (
            self._adaptive
            and self.n_bits < self.MAX_BITS
            and len(self._sigs_of) > self.TARGET_OCCUPANCY << self.n_bits
        ):
            self._set_geometry(min(self.n_bits + 2, self.MAX_BITS))
            self._rebuild()

    # -- maintenance ------------------------------------------------------

    def _signatures(self, vecs: np.ndarray) -> np.ndarray:
        """(M, DIM) -> (M, n_tables) int64 signatures."""
        bits = (np.atleast_2d(vecs) @ self._planes) > 0  # (M, T*b)
        return bits.reshape(-1, self.n_tables, self.n_bits) @ self._bit_weights

    def _insert_sigs(self, slot: int, sigs: Tuple[int, ...]) -> None:
        self._sigs_of[slot] = sigs
        for t, sig in enumerate(sigs):
            self._buckets[t].setdefault(sig, set()).add(slot)

    def on_add(self, slot: int, vec: np.ndarray) -> None:
        self.on_remove(slot)  # slot reuse: drop any stale signature first
        sigs = self._signatures(np.asarray(vec, np.float32))[0]
        self._insert_sigs(slot, tuple(int(s) for s in sigs))
        self._maybe_grow()

    def on_remove(self, slot: int) -> None:
        sigs = self._sigs_of.pop(slot, None)
        if sigs is None:
            return
        for t, sig in enumerate(sigs):
            b = self._buckets[t].get(sig)
            if b is not None:
                b.discard(slot)
                if not b:
                    del self._buckets[t][sig]

    def clear(self) -> None:
        for b in self._buckets:
            b.clear()
        self._sigs_of.clear()

    # -- auto-tuning (closes the telemetry loop) --------------------------

    def autotune(
        self,
        *,
        target_candidates: float = 96.0,
        min_recall: float = 0.92,
        min_queries: int = 64,
    ) -> Optional[str]:
        """One tuning step from the LIVE telemetry window; returns the
        action taken (or None if the window is thin or the geometry is
        already converged). Call periodically from a serving loop — each
        action resets the telemetry window so the next call measures the
        NEW geometry, and a drifting workload converges in a few windows:

        1. sampled top-1 recall below ``min_recall`` -> widen the probe
           ball (``probe_hamming`` +1, masks-only rebuild) — growing bits
           here would make recall *worse*;
        2. ``avg_candidates`` above ``target_candidates`` -> grow
           ``n_bits`` by 2 (full re-hash, amortized by the window length)
           so lookup cost stays flat as the bank grows;
        3. >10% of probed queries found an EMPTY candidate set -> widen
           the probe ball (the tables are over-partitioned for N).

        Callers must hold ``bank.lock`` (SimilarityIndex.autotune does):
        rules 1-3 rewrite probe masks or buckets under queries' feet.
        """
        t = self.telemetry
        if t.probed_queries < min_queries:
            return None
        recall = (
            t.recall_agreements / t.recall_checks if t.recall_checks else None
        )
        avg_candidates = t.candidates_total / t.probed_queries
        empty_rate = t.empty_candidate_queries / t.probed_queries
        action = None
        if recall is not None and recall < min_recall and self.probe_hamming < 2:
            self.probe_hamming += 1
            self._set_probe_masks()
            action = f"probe_hamming->{self.probe_hamming}"
        elif avg_candidates > target_candidates and self.n_bits < self.MAX_BITS:
            self._set_geometry(min(self.n_bits + 2, self.MAX_BITS))
            self._rebuild()
            action = f"n_bits->{self.n_bits}"
        elif empty_rate > 0.10 and self.probe_hamming < 2:
            self.probe_hamming += 1
            self._set_probe_masks()
            action = f"probe_hamming->{self.probe_hamming}"
        if action is not None:
            self.telemetry.reset()  # fresh window for new geometry
        return action

    # -- search -----------------------------------------------------------

    def _candidates_raw(self, query: np.ndarray) -> np.ndarray:
        """Probed slots, possibly duplicated across tables (argmax-safe)."""
        sigs = self._signatures(query)[0]
        out: List[int] = []
        for t in range(self.n_tables):
            get = self._buckets[t].get
            for s in (int(sigs[t]) ^ self._probe_masks).tolist():
                b = get(s)
                if b:
                    out.extend(b)
        return np.asarray(out, np.int64)

    def candidates(self, query: np.ndarray) -> np.ndarray:
        """Slot ids in probed buckets across all tables (sorted, deduped)."""
        raw = self._candidates_raw(np.asarray(query, np.float32))
        return np.unique(raw) if raw.size else raw

    def best_slot(self, query: np.ndarray) -> Tuple[float, int]:
        """Lean single-query argmax: (score, slot) or (-1e30, -1).

        The plan-cache lookup hot path — no (Q, k) result arrays, no
        candidate dedup (duplicates can't change an argmax)."""
        M = self.bank.matrix()
        if len(self.bank) <= self.scan_threshold:
            self.telemetry.observe_brute()
            if M.shape[0] == 0:
                return float(NEG_INF), -1
            s = M @ query
            j = int(np.argmax(s))
            return float(s[j]), j
        cand = self._candidates_raw(query)
        self.telemetry.observe_probe(int(cand.size))
        if cand.size == 0:
            return float(NEG_INF), -1
        s = M[cand] @ query
        j = int(np.argmax(s))
        slot = int(cand[j])
        if (
            self._recall_every
            and self.telemetry.probed_queries % self._recall_every == 0
        ):
            # live recall sample: re-answer this query exactly (amortized
            # O(N / recall_sample_every) per query). Compare *scores* over
            # *live* slots only (``_sigs_of`` keys are exactly the hashed
            # live set): an argmax over the raw matrix would pick a
            # tombstoned zero row whenever the best live cosine is
            # negative, and slot comparison would count exact ties as
            # misses — both are false disagreements.
            live = np.fromiter(self._sigs_of.keys(), np.int64)
            exact_best = float(np.max(M[live] @ query))
            self.telemetry.observe_recall(float(s[j]) >= exact_best - 1e-6)
        return float(s[j]), slot

    def topk(
        self, queries: np.ndarray, k: int = 1
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(scores (Q, k) f32, slots (Q, k) i32), -1/-1e30 padded.

        Exact within the probed candidate set; exact over the whole bank
        when it is smaller than ``scan_threshold``.
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        M = self.bank.matrix()
        if len(self.bank) <= self.scan_threshold:
            for _ in range(queries.shape[0]):
                self.telemetry.observe_brute()
            return _brute_topk(M, queries, k)
        Q = queries.shape[0]
        scores = np.full((Q, k), NEG_INF, np.float32)
        slots = np.full((Q, k), -1, np.int32)
        for r in range(Q):
            if k == 1:  # argmax path (dup candidates are harmless)
                sc, slot = self.best_slot(queries[r])
                scores[r, 0] = sc
                slots[r, 0] = slot
                continue
            cand = self.candidates(queries[r])
            self.telemetry.observe_probe(int(cand.size))
            if cand.size == 0:
                continue
            s, i = _brute_topk(M[cand], queries[r : r + 1], k)
            scores[r] = s[0]
            valid = i[0] >= 0
            slots[r, valid] = cand[i[0][valid]].astype(np.int32)
        return scores, slots

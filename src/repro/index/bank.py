"""EmbeddingBank: a contiguous float32 slot arena for similarity search.

This is the storage half of the ``repro.index`` subsystem. Keys live in a
preallocated ``(capacity, DIM)`` arena with a freelist, so add/remove are
O(1) and — unlike the seed ``FuzzyMatcher`` — no ``np.stack`` matrix rebuild
ever happens on the lookup path: search backends (brute numpy, the Pallas
``batch_topk`` kernel, the bucketed LSH index) all read ``bank.matrix()``,
which is just a zero-copy view of the live prefix of the arena.

Freed slots are zeroed, so they score exactly 0.0 under cosine and can never
exceed a positive match threshold; top-k consumers additionally filter via
``bank.key_of(slot) is None``.

The hashed character-ngram embedding from the paper's prototype also lives
here, in *batched* form: gram -> (dim index, sign) hashing is memoized and
the accumulation is a single vectorized ``np.add.at`` scatter instead of the
seed's per-gram Python loop. Because gram contributions are exact +/-1.0
float32 integers, the batched path is bit-identical to the sequential one.

Thread-safety contract: every mutator (``add`` / ``remove`` / ``clear``)
takes ``self.lock`` (an RLock) internally, so interleaved mutation from
multiple threads is always safe. Reads of ``matrix()`` / ``arena()`` /
``vector()`` return live views, NOT copies: a reader that must not observe
concurrent writes holds ``bank.lock`` around the read and everything
derived from it. Higher layers compose on this single lock — a
SimilarityIndex nests its bucket/device-arena updates inside it, and
PlanCache's own RLock wraps every index call — so "hold ``bank.lock``"
is the one rule that makes the whole index stack consistent.
"""

from __future__ import annotations

import hashlib
import re
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DIM = 384  # matches MiniLM-L6 dim (the paper prototype's encoder)


# ---------------------------------------------------------------------------
# hashed-ngram embedding (batched)
# ---------------------------------------------------------------------------

_GRAM_CACHE: Dict[str, Tuple[int, np.float32]] = {}
_GRAM_CACHE_MAX = 1 << 20  # bound memory on adversarial workloads


def _tokens(text: str) -> List[str]:
    text = text.lower()
    words = re.findall(r"[a-z0-9]+", text)
    grams = list(words)
    for w in words:
        for i in range(len(w) - 2):
            grams.append(w[i : i + 3])
    for a, b in zip(words, words[1:]):
        grams.append(a + "_" + b)
    return grams


def _gram_slot(g: str) -> Tuple[int, np.float32]:
    hit = _GRAM_CACHE.get(g)
    if hit is None:
        h = int.from_bytes(
            hashlib.blake2b(g.encode(), digest_size=8).digest(), "little"
        )
        hit = (h % DIM, np.float32(1.0 if (h >> 62) & 1 else -1.0))
        if len(_GRAM_CACHE) < _GRAM_CACHE_MAX:
            _GRAM_CACHE[g] = hit
    return hit


def embed_batch(texts: Sequence[str]) -> np.ndarray:
    """(len(texts), DIM) float32, rows L2-normalized (zero rows stay zero)."""
    out = np.zeros((len(texts), DIM), np.float32)
    rows: List[int] = []
    cols: List[int] = []
    signs: List[np.float32] = []
    for r, t in enumerate(texts):
        for g in _tokens(t):
            c, s = _gram_slot(g)
            rows.append(r)
            cols.append(c)
            signs.append(s)
    if rows:
        np.add.at(
            out,
            (np.asarray(rows, np.intp), np.asarray(cols, np.intp)),
            np.asarray(signs, np.float32),
        )
    norms = np.linalg.norm(out, axis=1, keepdims=True)
    np.divide(out, norms, out=out, where=norms > 0)
    return out


def embed(text: str) -> np.ndarray:
    """Single-text convenience wrapper over :func:`embed_batch`."""
    return embed_batch([text])[0]


# ---------------------------------------------------------------------------
# the bank
# ---------------------------------------------------------------------------


class EmbeddingBank:
    """Slot arena mapping keys -> L2-normalized embedding rows.

    O(1) ``add``/``remove`` (freelist, no matrix rebuild); ``matrix()`` is a
    view of rows ``[0, high_water)``. Thread-safe for interleaved mutation;
    search backends should snapshot ``matrix()`` under ``bank.lock`` when
    racing with writers (``PlanCache`` already serializes via its own lock).
    """

    def __init__(self, initial_capacity: int = 64):
        cap = max(1, int(initial_capacity))
        self._arena = np.zeros((cap, DIM), np.float32)
        self._slot_of: Dict[str, int] = {}
        self._key_of: List[Optional[str]] = [None] * cap
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self._high_water = 0
        self.lock = threading.RLock()

    # -- introspection ----------------------------------------------------

    def __len__(self) -> int:
        return len(self._slot_of)

    def __contains__(self, key: str) -> bool:
        return key in self._slot_of

    @property
    def high_water(self) -> int:
        return self._high_water

    def keys(self) -> List[str]:
        with self.lock:
            return list(self._slot_of)

    def slot_of(self, key: str) -> Optional[int]:
        return self._slot_of.get(key)

    def key_of(self, slot: int) -> Optional[str]:
        """Key occupying ``slot``, or None for freed/never-used slots."""
        if 0 <= slot < self._high_water:
            return self._key_of[slot]
        return None

    def matrix(self) -> np.ndarray:
        """Zero-copy (high_water, DIM) view; freed rows are all-zero."""
        return self._arena[: self._high_water]

    def arena(self) -> np.ndarray:
        """The full (capacity, DIM) arena; rows beyond high_water are zero.

        Device-call consumers (the Pallas backend) search this instead of
        ``matrix()``: capacity only changes on doubling, so a jit'd kernel
        sees O(log N) distinct shapes instead of one per insert."""
        return self._arena

    def vector(self, key: str) -> Optional[np.ndarray]:
        slot = self._slot_of.get(key)
        return None if slot is None else self._arena[slot]

    # -- mutation ---------------------------------------------------------

    def _grow(self) -> None:
        old = self._arena
        cap = old.shape[0] * 2
        self._arena = np.zeros((cap, DIM), np.float32)
        self._arena[: old.shape[0]] = old
        self._free.extend(range(cap - 1, old.shape[0] - 1, -1))
        self._key_of.extend([None] * (cap - old.shape[0]))

    def add(self, key: str, vector: Optional[np.ndarray] = None) -> int:
        """Insert ``key`` (embedding its text unless ``vector`` is given).

        Returns the slot. Re-adding an existing key is a no-op unless a new
        vector is supplied, in which case the row is overwritten in place.
        """
        with self.lock:
            slot = self._slot_of.get(key)
            if slot is not None:
                if vector is not None:
                    self._arena[slot] = np.asarray(vector, np.float32)
                return slot
            if vector is None:
                vector = embed(key)
            if not self._free:
                self._grow()
            slot = self._free.pop()
            self._slot_of[key] = slot
            self._key_of[slot] = key
            self._arena[slot] = np.asarray(vector, np.float32)
            self._high_water = max(self._high_water, slot + 1)
            return slot

    def remove(self, key: str) -> Optional[int]:
        """O(1) tombstone: zero the row, recycle the slot. Returns the slot."""
        with self.lock:
            slot = self._slot_of.pop(key, None)
            if slot is None:
                return None
            self._key_of[slot] = None
            self._arena[slot] = 0.0
            self._free.append(slot)
            return slot

    def clear(self) -> None:
        with self.lock:
            cap = self._arena.shape[0]
            self._arena[:] = 0.0
            self._slot_of.clear()
            self._key_of = [None] * cap
            self._free = list(range(cap - 1, -1, -1))
            self._high_water = 0

"""DeviceBank: device-resident mirror of an :class:`EmbeddingBank` arena.

The host :class:`~repro.index.bank.EmbeddingBank` is a numpy slot arena; the
``pallas`` search backend passes that numpy array to ``ops.batch_topk`` on
every call, which re-uploads ``capacity * DIM * 4`` bytes of bank to the
device per lookup — the dominant data-movement cost once the cache holds
tens of thousands of plans. DeviceBank removes that traffic: the arena
lives on-device as a jax array and is updated *in place* (donated buffers,
so XLA reuses the storage instead of allocating a fresh arena per write):

* ``set_row(slot, vec)``      — one donated ``arena.at[slot].set(vec)``
  scatter per insert; uploads exactly one row (``dim * 4`` bytes).
* ``set_rows(slots, vecs)``   — one donated multi-slot scatter for a whole
  admission wave (``lookup_batch`` miss-fill / ``insert_batch``).
* ``clear_row`` / ``clear``   — tombstone/reset with device-generated
  zeros: **zero** host-to-device bytes.
* ``grow``                    — capacity doubling via a device-side pad
  (device-to-device copy, zero H2D).

Steady-state lookups therefore move only the query batch
(``Q * dim * 4`` bytes) to the device; the bank itself never travels
again. Every transfer this class *does* perform is accounted in
``h2d_bytes_total`` so benchmarks (``t5``, ``kernel_bench``) can report
bytes-moved-per-lookup per backend.

Thread-safety contract: DeviceBank itself is NOT locked. It is owned by a
:class:`~repro.index.SimilarityIndex`, which mutates it only under
``bank.lock`` — the same lock serializing host-arena writes — so the host
and device arenas can never be observed out of lockstep by a consumer that
follows the lock protocol (PlanCache holds its own lock around every index
call, which nests the bank lock). Readers of ``arena`` must hold that same
lock across their device dispatch: a donated update does not leave the old
buffer stale, it *deletes* it, so an unserialized reader on TPU crashes
rather than reading a snapshot.

Slot layout is identical to the host arena by construction: slot ``i`` on
the host is row ``i`` on the device, so top-k indices from a device search
resolve through ``EmbeddingBank.key_of`` unchanged.
"""

from __future__ import annotations

import functools
import warnings
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.bank import DIM
from repro.obs import MetricsRegistry
from repro.obs import names as _names


def _donated(fn, *args):
    """Call a donating jit'd helper with the CPU donation notice silenced.

    CPU jax cannot honor buffer donation and warns per call; the donation
    is a TPU-side optimization, so the notice is pure noise here (and a
    module-level filter would not survive pytest's per-test filter reset).
    """
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return fn(*args)


@functools.partial(jax.jit, donate_argnums=(0,))
def _set_row(arena, slot, vec):
    return arena.at[slot].set(vec)


@functools.partial(jax.jit, donate_argnums=(0,))
def _set_rows(arena, slots, vecs):
    return arena.at[slots].set(vecs)


@functools.partial(jax.jit, donate_argnums=(0,))
def _clear_row(arena, slot):
    return arena.at[slot].set(jnp.zeros((arena.shape[1],), arena.dtype))


@functools.partial(jax.jit, donate_argnums=(0,))
def _clear_all(arena):
    return jnp.zeros_like(arena)


@functools.partial(jax.jit, static_argnames=("new_cap",), donate_argnums=(0,))
def _grow(arena, *, new_cap):
    return jnp.pad(arena, ((0, new_cap - arena.shape[0]), (0, 0)))


class DeviceBank:
    """Device-resident ``(capacity, dim)`` float32 arena with donated writes.

    Capacity only ever doubles (mirroring ``EmbeddingBank._grow``), so the
    jit caches for search kernels and the scatter helpers see O(log N)
    distinct arena shapes, never one per insert.
    """

    _COUNTERS = {
        "h2d_bytes_total": _names.DEVICE_H2D_BYTES,
        "row_updates": _names.DEVICE_ROW_UPDATES,
        "batched_updates": _names.DEVICE_BATCHED_UPDATES,
        "clears": _names.DEVICE_CLEARS,
        "grows": _names.DEVICE_GROWS,
    }

    def __init__(self, capacity: int = 64, dim: int = DIM,
                 *, obs: Optional[MetricsRegistry] = None,
                 obs_labels: Optional[Dict[str, str]] = None):
        cap = max(1, int(capacity))
        self.dim = dim
        self._arena = jnp.zeros((cap, dim), jnp.float32)
        # telemetry: every host->device byte this bank moves, by cause —
        # registry-backed counters (repro.obs); the historical int attrs
        # are read-only property views below
        reg = obs if obs is not None else MetricsRegistry()
        labels = obs_labels or {}
        self._c = {
            field: reg.counter(name, **labels)
            for field, name in self._COUNTERS.items()
        }
        self._cap_gauge = reg.gauge(_names.DEVICE_CAPACITY, **labels)
        self._cap_gauge.set(cap)

    # -- introspection ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self._arena.shape[0]

    @property
    def h2d_bytes_total(self) -> int:
        return int(self._c["h2d_bytes_total"].value)

    @property
    def row_updates(self) -> int:
        return int(self._c["row_updates"].value)

    @property
    def batched_updates(self) -> int:
        return int(self._c["batched_updates"].value)

    @property
    def clears(self) -> int:
        return int(self._c["clears"].value)

    @property
    def grows(self) -> int:
        return int(self._c["grows"].value)

    @property
    def arena(self) -> jnp.ndarray:
        """The live device buffer. Do not mutate; donated helpers own it."""
        return self._arena

    def telemetry(self) -> Dict[str, Any]:
        return {
            "capacity": self.capacity,
            "h2d_bytes_total": self.h2d_bytes_total,
            "row_updates": self.row_updates,
            "batched_updates": self.batched_updates,
            "clears": self.clears,
            "grows": self.grows,
        }

    def note_h2d(self, nbytes: int) -> None:
        """Account a transfer performed on this bank's behalf (queries)."""
        self._c["h2d_bytes_total"].inc(int(nbytes))

    # -- mutation (caller holds the host bank's lock) ---------------------

    def ensure_capacity(self, capacity: int) -> None:
        """Grow (device-side, zero H2D) until at least ``capacity`` rows."""
        if capacity > self.capacity:
            new_cap = self.capacity
            while new_cap < capacity:
                new_cap *= 2
            self._arena = _donated(
                functools.partial(_grow, new_cap=new_cap), self._arena
            )
            self._c["grows"].inc()
            self._cap_gauge.set(new_cap)

    def set_row(self, slot: int, vec: np.ndarray) -> None:
        self.ensure_capacity(slot + 1)
        v = np.asarray(vec, np.float32)
        self._arena = _donated(_set_row, self._arena, np.int32(slot), v)
        self._c["h2d_bytes_total"].inc(v.nbytes)
        self._c["row_updates"].inc()

    def set_rows(self, slots: Sequence[int], vecs: np.ndarray) -> None:
        """One donated scatter for a whole admission wave.

        ``slots`` is padded to the next power of two (by repeating the last
        slot/vector pair — a duplicate ``set`` of an identical value is a
        no-op) so the jit cache sees O(log Q) wave shapes.
        """
        if len(slots) == 0:
            return
        self.ensure_capacity(max(slots) + 1)
        s = np.asarray(slots, np.int32)
        v = np.asarray(vecs, np.float32)
        n = s.shape[0]
        pad = (1 << max(0, n - 1).bit_length()) - n
        if pad:
            s = np.concatenate([s, np.repeat(s[-1:], pad)])
            v = np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
        self._arena = _donated(_set_rows, self._arena, s, v)
        self._c["h2d_bytes_total"].inc(v.nbytes + s.nbytes)
        self._c["batched_updates"].inc()

    def clear_row(self, slot: int) -> None:
        """Tombstone a slot with device-generated zeros (zero H2D)."""
        if slot < self.capacity:
            self._arena = _donated(_clear_row, self._arena, np.int32(slot))

    def clear(self) -> None:
        self._arena = _donated(_clear_all, self._arena)
        self._c["clears"].inc()

"""repro.index — the shared similarity-index subsystem.

Every fuzzy consumer (PlanCache, the semantic baseline, distributed shards,
the serving router) plugs into this layer instead of rolling its own cosine
scan:

* :class:`~repro.index.bank.EmbeddingBank` — contiguous float32 slot arena
  with a freelist (O(1) add/remove, zero-copy ``matrix()`` view, batched
  hashed-ngram embedding).
* ``kernels/similarity.py`` via ``ops.batch_topk`` — Pallas blocked cosine
  top-k, one device call per request batch (interpret on CPU, Mosaic on
  TPU).
* :class:`~repro.index.bucketed.BucketedIndex` — multi-probe SRP-LSH for
  sublinear candidate generation at 1e6 entries.
* :class:`~repro.index.device.DeviceBank` — device-resident mirror of the
  host arena (donated in-place updates), searched by ``ops.resident_topk``
  with zero bank bytes re-uploaded per lookup.

:class:`SimilarityIndex` is the facade: pick a backend (``brute`` |
``pallas`` | ``bucketed`` | ``device`` | ``auto``) and get
add/remove/topk/best_match over keys. ``auto`` serves exact brute scans
while the bank is small and switches to the bucketed index beyond
``auto_bucketed_min`` live entries; ``device`` keeps host and device
arenas in lockstep and answers whole query batches in one device call
with zero steady-state H2D bank traffic.

Thread-safety contract: all mutation (``add`` / ``add_batch`` / ``remove``
/ ``clear``) takes ``self.bank.lock`` and updates the host arena, the LSH
buckets, and the device arena atomically with respect to other
lock-holders. Host-side searches (``brute`` / ``bucketed``) are lock-free
reads of the arena snapshot: callers that interleave searches with writers
and need a consistent view must hold ``bank.lock`` across the search —
PlanCache does exactly this by wrapping every index call in its own RLock,
which is the supported pattern. ``device``-backend searches always
dispatch under ``bank.lock`` internally: a donating write does not merely
race a reader, it *deletes* the arena buffer the reader captured (buffer
donation is in-place on TPU), so search-vs-mutation serialization is
mandatory there, not optional.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.index.bank import DIM, EmbeddingBank, embed, embed_batch
from repro.index.bucketed import NEG_INF, BucketedIndex, _brute_topk
from repro.index.device import DeviceBank
from repro.obs import MetricsRegistry, trace_span
from repro.obs.names import SPAN_INDEX_TOPK

BACKENDS = ("auto", "brute", "pallas", "bucketed", "device")


class SimilarityIndex:
    """Key -> embedding store with pluggable top-k search backend."""

    def __init__(
        self,
        *,
        backend: str = "auto",
        bank: Optional[EmbeddingBank] = None,
        initial_capacity: int = 64,
        n_tables: int = 4,
        n_bits: Optional[int] = None,  # None: adaptive, ~log2(N) (bucketed.py)
        lsh_seed: int = 0,
        probe_hamming: int = 1,
        auto_bucketed_min: int = 4096,
        obs: Optional[MetricsRegistry] = None,
        obs_labels: Optional[Dict[str, str]] = None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        self.backend = backend
        self.bank = bank if bank is not None else EmbeddingBank(initial_capacity)
        # obs: where backend telemetry (LSH counters, device H2D bytes)
        # registers; shared by a traced serving path, private otherwise
        self.obs_labels = dict(obs_labels or {})
        self._bucketed: Optional[BucketedIndex] = None
        self._device: Optional[DeviceBank] = None
        if backend in ("bucketed", "auto"):
            self._bucketed = BucketedIndex(
                self.bank,
                n_tables=n_tables,
                n_bits=n_bits,
                seed=lsh_seed,
                probe_hamming=probe_hamming,
                scan_threshold=auto_bucketed_min if backend == "auto" else 2048,
                obs=obs,
                obs_labels=self.obs_labels,
            )
        elif backend == "device":
            with self.bank.lock:
                self._device = DeviceBank(
                    self.bank.arena().shape[0],
                    obs=obs,
                    obs_labels=self.obs_labels,
                )
                if len(self.bank):  # bootstrap: one upload of existing rows
                    slots = [self.bank.slot_of(k) for k in self.bank.keys()]
                    self._device.set_rows(slots, self.bank.arena()[slots])

    # -- mutation (O(1) amortized; keeps LSH buckets in sync) -------------

    def __len__(self) -> int:
        return len(self.bank)

    def __contains__(self, key: str) -> bool:
        return key in self.bank

    def add(self, key: str, vector: Optional[np.ndarray] = None) -> int:
        with self.bank.lock:
            slot = self.bank.add(key, vector)
            if self._bucketed is not None:
                self._bucketed.on_add(slot, self.bank.matrix()[slot])
            if self._device is not None:
                self._device.set_row(slot, self.bank.matrix()[slot])
            return slot

    def add_batch(
        self, keys: Sequence[str], vectors: Optional[np.ndarray] = None
    ) -> List[int]:
        """Insert a whole admission wave: one embedding batch and — on the
        ``device`` backend — one donated multi-slot scatter instead of one
        device write per key."""
        keys = list(keys)
        if not keys:
            return []
        if vectors is None:
            vectors = embed_batch(keys)
        vectors = np.asarray(vectors, np.float32)
        # dedupe with last-wins (the sequential host semantics): a repeated
        # slot in one device scatter has an *unspecified* winner, which
        # would let the device row diverge from the host arena
        vec_of = {key: vec for key, vec in zip(keys, vectors)}
        with self.bank.lock:
            slot_of = {}
            for key, vec in vec_of.items():
                slot = self.bank.add(key, vec)
                slot_of[key] = slot
                if self._bucketed is not None:
                    self._bucketed.on_add(slot, self.bank.matrix()[slot])
            if self._device is not None:
                self._device.set_rows(
                    list(slot_of.values()),
                    np.stack([vec_of[k] for k in slot_of]),
                )
            return [slot_of[k] for k in keys]

    def remove(self, key: str) -> None:
        with self.bank.lock:
            slot = self.bank.remove(key)
            if slot is not None:
                if self._bucketed is not None:
                    self._bucketed.on_remove(slot)
                if self._device is not None:
                    self._device.clear_row(slot)

    def clear(self) -> None:
        with self.bank.lock:
            self.bank.clear()
            if self._bucketed is not None:
                self._bucketed.clear()
            if self._device is not None:
                self._device.clear()

    def autotune(self, **thresholds) -> Optional[str]:
        """One LSH auto-tuning step from live telemetry (bucketed/auto
        backends; no-op None otherwise). Serializes against writers via
        ``bank.lock``; see :meth:`BucketedIndex.autotune` for the rules."""
        if self._bucketed is None:
            return None
        with self.bank.lock:
            return self._bucketed.autotune(**thresholds)

    def telemetry(self) -> dict:
        """Live counters for serving dashboards / auto-tuning: device-bank
        H2D accounting and (on bucketed backends) LSH recall/candidate
        stats."""
        out: dict = {"backend": self.backend, "size": len(self.bank)}
        if self._device is not None:
            out["device"] = self._device.telemetry()
        if self._bucketed is not None:
            out["bucketed"] = self._bucketed.telemetry.snapshot()
        return out

    # -- search -----------------------------------------------------------

    def _as_queries(self, queries: Union[Sequence[str], np.ndarray]) -> np.ndarray:
        if isinstance(queries, np.ndarray):
            return np.atleast_2d(queries.astype(np.float32, copy=False))
        return embed_batch(list(queries))

    def topk(
        self, queries: Union[Sequence[str], np.ndarray], k: int = 1
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over live keys: (scores (Q, k) f32, slots (Q, k) i32).

        ``queries`` is either raw texts (embedded in one batch) or an
        already-embedded (Q, DIM) array. Slots map to keys via
        ``bank.key_of``; every returned ``slot >= 0`` is a live key.
        Freed/empty arena rows score 0.0 in the underlying scan; their
        result positions are masked to (-1, NEG_INF) here rather than
        re-compacted, so with tombstones present fewer than k live entries
        may be returned even when k live keys exist — over-request k if an
        exact count matters.
        """
        q = self._as_queries(queries)
        with trace_span(SPAN_INDEX_TOPK, backend=self.backend,
                        q=int(q.shape[0]), k=k, **self.obs_labels) as sp:
            if self.backend in ("pallas", "device"):
                from repro.kernels import ops  # lazy: keep core import jax-free

                # search the full arena, not matrix(): its capacity changes
                # only on doubling, so the jit'd kernel sees O(log N) shapes
                # instead of retracing on every insert; pad Q likewise
                nq = q.shape[0]
                qp = max(8, 1 << max(0, nq - 1).bit_length())
                if qp != nq:
                    q = np.pad(q, ((0, qp - nq), (0, 0)))
                if self._device is not None:
                    # resident bank: only the query batch crosses to the
                    # device. Dispatch under bank.lock — a concurrent
                    # donating write would DELETE the arena buffer captured
                    # here (donation is in-place on TPU), which is a crash,
                    # not a stale read.
                    with self.bank.lock:
                        self._device.note_h2d(q.nbytes)
                        sp.set(h2d_bytes=int(q.nbytes))
                        s, i = ops.resident_topk(q, self._device.arena, k=k)
                else:
                    s, i = ops.batch_topk(q, self.bank.arena(), k=k)
                scores, slots = np.array(s[:nq]), np.array(i[:nq])
            elif self._bucketed is not None:  # bucketed | auto
                cand0 = self._bucketed.telemetry.candidates_total
                scores, slots = self._bucketed.topk(q, k)
                sp.set(
                    lsh_candidates=(
                        self._bucketed.telemetry.candidates_total - cand0
                    )
                )
            else:
                scores, slots = _brute_topk(self.bank.matrix(), q, k)
        # mask tombstoned / beyond-high-water slots: slot >= 0 => live key
        for r in range(slots.shape[0]):
            for c in range(slots.shape[1]):
                slot = slots[r, c]
                if slot >= 0 and self.bank.key_of(int(slot)) is None:
                    slots[r, c] = -1
                    scores[r, c] = NEG_INF
        return scores, slots

    def best_match_batch(
        self,
        queries: Union[Sequence[str], np.ndarray],
        threshold: float = 0.8,
    ) -> List[Optional[str]]:
        """Per query: the best live key with cosine >= threshold, else None."""
        scores, slots = self.topk(queries, k=1)
        out: List[Optional[str]] = []
        for r in range(scores.shape[0]):
            key = None
            if slots[r, 0] >= 0 and scores[r, 0] >= threshold:
                key = self.bank.key_of(int(slots[r, 0]))
            out.append(key)
        return out

    def best_match(
        self, query: Union[str, np.ndarray], threshold: float = 0.8
    ) -> Optional[str]:
        if isinstance(query, str):
            query = embed(query)
        # device/pallas answer through the batched device call; the rest
        # take the lean host single-lookup path (no (Q, k) arrays)
        if self.backend not in ("pallas", "device"):
            q = query.astype(np.float32, copy=False).reshape(-1)
            if self._bucketed is not None:
                score, slot = self._bucketed.best_slot(q)
            else:
                M = self.bank.matrix()
                if M.shape[0] == 0:
                    return None
                s = M @ q
                slot = int(np.argmax(s))
                score = float(s[slot])
            if slot >= 0 and score >= threshold:
                return self.bank.key_of(slot)
            return None
        return self.best_match_batch(query.reshape(1, -1), threshold)[0]


__all__ = [
    "BACKENDS",
    "DIM",
    "NEG_INF",
    "BucketedIndex",
    "DeviceBank",
    "EmbeddingBank",
    "SimilarityIndex",
    "embed",
    "embed_batch",
]

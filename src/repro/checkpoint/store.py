"""Sharded, atomic, versioned checkpointing (numpy-backed, no orbax).

Layout:
    <dir>/step_000123/
        manifest.json         # tree structure, shapes, dtypes, shard map
        shard_00000.npz       # flat arrays owned by host 0
        ...
        COMMITTED             # written LAST -> torn checkpoints are invisible

Fault-tolerance properties:
  * atomic: a checkpoint is valid iff COMMITTED exists (crash mid-write
    leaves a garbage dir that restore() skips and gc() removes);
  * versioned: restore() picks the newest committed step; keep_last prunes;
  * integrity: per-array crc32 in the manifest, verified on load;
  * multi-host: each host writes only arrays it owns (shard_id = hash of
    path); on restore every host reads all shards it needs (single-host in
    this container, but the layout is the multi-host one).
"""

from __future__ import annotations

import hashlib
import json
import shutil
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2"}


def _to_storable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """npz can't hold bf16/fp8: store a bit-identical uint view + dtype tag."""
    dt = str(arr.dtype)
    if dt in _EXOTIC:
        return arr.view(np.uint16 if dt == "bfloat16" else np.uint8), dt
    return arr, dt


def _from_storable(arr: np.ndarray, dtype_tag: str) -> np.ndarray:
    if dtype_tag in _EXOTIC:
        return arr.view(getattr(ml_dtypes, dtype_tag))
    return arr


def _flatten(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, np.asarray(leaf)))
    return out


class CheckpointStore:
    def __init__(
        self,
        directory: str,
        *,
        n_shards: int = 1,
        keep_last: int = 3,
        pin_check: Optional[Callable[[int], bool]] = None,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        self.keep_last = keep_last
        # pin_check(step) -> True means the step is live (externally
        # referenced — e.g. a cold-tier segment with manifest entries) and
        # must survive gc regardless of age; keep_last rotation applies
        # only to unpinned steps. Training checkpoints (no pin_check)
        # keep the pure age-rotation semantics.
        self.pin_check = pin_check

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:09d}"

    def save(self, step: int, tree: Any, *, extra: Optional[Dict] = None) -> Path:
        tmp = self.dir / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(tree)
        shards: Dict[int, Dict[str, np.ndarray]] = {i: {} for i in range(self.n_shards)}
        manifest = {"step": step, "extra": extra or {}, "arrays": {}, "n_shards": self.n_shards}
        for key, arr in flat:
            sid = int(hashlib.blake2b(key.encode(), digest_size=2).digest()[0]) % self.n_shards
            safe = key.replace("/", "__")
            storable, dtype_tag = _to_storable(arr)
            shards[sid][safe] = storable
            manifest["arrays"][key] = {
                "shard": sid,
                "name": safe,
                "shape": list(arr.shape),
                "dtype": dtype_tag,
                "crc32": zlib.crc32(np.ascontiguousarray(storable).tobytes()),
            }
        for sid, arrs in shards.items():
            np.savez(tmp / f"shard_{sid:05d}.npz", **arrs)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        (final / "COMMITTED").write_text("ok")  # commit point
        self.gc()
        return final

    # ------------------------------------------------------------------

    def committed_steps(self) -> List[int]:
        steps = []
        for d in self.dir.glob("step_*"):
            if (d / "COMMITTED").exists():
                steps.append(int(d.name.split("_")[1]))
        return sorted(steps)

    def restore(
        self, template: Any, *, step: Optional[int] = None, strict: bool = True
    ) -> Tuple[Any, Dict]:
        steps = self.committed_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        step = step if step is not None else steps[-1]
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        shard_data: Dict[int, Any] = {}

        def load_arr(key: str) -> np.ndarray:
            info = manifest["arrays"][key]
            sid = info["shard"]
            if sid not in shard_data:
                shard_data[sid] = np.load(d / f"shard_{sid:05d}.npz")
            arr = shard_data[sid][info["name"]]
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != info["crc32"]:
                raise IOError(f"checkpoint corruption detected for {key!r}")
            return _from_storable(arr, info["dtype"])

        flat_t = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat_t[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            if key not in manifest["arrays"]:
                if strict:
                    raise KeyError(f"missing {key!r} in checkpoint step {step}")
                leaves.append(leaf)
                continue
            arr = load_arr(key)
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(flat_t[1], leaves)
        return tree, manifest["extra"]

    def gc(self) -> None:
        steps = self.committed_steps()
        # pinned steps are live (see pin_check in __init__): age rotation
        # only ever considers the unpinned ones, so a referenced cold-tier
        # segment can never be deleted out from under its manifest no
        # matter how many newer steps land
        unpinned = (
            steps if self.pin_check is None
            else [s for s in steps if not self.pin_check(s)]
        )
        for s in unpinned[: -self.keep_last] if self.keep_last > 0 else unpinned:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # remove torn checkpoints (no COMMITTED marker)
        for d in self.dir.glob("step_*"):
            if not (d / "COMMITTED").exists():
                shutil.rmtree(d, ignore_errors=True)
        for d in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(d, ignore_errors=True)

"""Seeded step scheduler: deterministic interleaving of concurrent clients.

FoundationDB-style simulation reduces concurrency to a *seeded choice of
interleaving*: each logical client is a queue of operations; at every step
the scheduler (a) applies deferred actions that came due (lagged replica
writes), (b) fires faults scheduled for this step, then (c) picks ONE
runnable client with the seeded RNG and executes its next operation
atomically. Operations are atomic because the stores under test serialize
them under their documented locks — the scheduler explores the space of
*orderings between* lock-grained operations, which is exactly where
distributed-cache races live (admission vs. eviction, crash vs. lookup,
lag vs. fallthrough).

The step counter is the virtual-time axis: fault plans and deferred writes
are indexed by step, and the virtual clock advances a fixed tick per step
(plus whatever per-call latency the fault interceptor charges).
"""

from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.distributed.fault import FaultSchedule, FaultSpec
from repro.sim.clock import VirtualClock


class StepScheduler:
    """Drives clients/faults/deferred-actions in one deterministic order."""

    def __init__(
        self,
        seed: int,
        clock: VirtualClock,
        *,
        tick_s: float = 1e-3,
    ):
        self.rng = random.Random(("sim-sched", seed).__repr__())
        self.clock = clock
        self.tick_s = tick_s
        self.step = 0
        self._clients: List[Tuple[str, List[Dict[str, Any]]]] = []
        self._queues: Dict[str, List[Dict[str, Any]]] = {}
        self._cursor: Dict[str, int] = {}
        self._deferred: List[Tuple[int, int, Callable[[], None]]] = []
        self._seq = 0  # tie-break so same-step deferred actions keep order

    def add_client(self, name: str, ops: List[Dict[str, Any]]) -> None:
        self._clients.append((name, ops))
        self._queues[name] = ops
        self._cursor[name] = 0

    def extend_client(self, name: str, ops: List[Dict[str, Any]]) -> None:
        """Append ops to an existing client's queue mid-run.

        This is how dynamically-spawned work enters the interleaving: the
        sim's async cache-generation pool registers idle worker clients up
        front and feeds them tasks as the router submits waves, so a
        worker's op competes for scheduling like any client op (the seeded
        RNG owns the admission race). A client with new ops becomes
        runnable again on the next step — quiescence is only declared when
        every queue (static and dynamically extended) is drained."""
        if name not in self._queues:
            raise KeyError(f"unknown scheduler client {name!r}")
        self._queues[name].extend(ops)

    def defer(self, delay_steps: int, fn: Callable[[], None]) -> None:
        """Schedule fn to run at the START of step ``now + delay_steps``
        (lagged replica writes, delayed restarts)."""
        self._seq += 1
        self._deferred.append((self.step + max(1, delay_steps), self._seq, fn))

    def _runnable(self) -> List[Tuple[str, List[Dict[str, Any]]]]:
        return [(n, ops) for n, ops in self._clients if self._cursor[n] < len(ops)]

    def run(
        self,
        on_op: Callable[[int, str, Dict[str, Any]], None],
        *,
        faults: Optional[FaultSchedule] = None,
        on_fault: Optional[Callable[[int, FaultSpec], None]] = None,
        max_steps: int = 100_000,
    ) -> int:
        """Run to quiescence: all client ops applied, deferred queue empty,
        fault schedule drained. Returns the number of steps executed."""
        while self.step < max_steps:
            # (a) deferred actions due now, in (due, seq) order
            due = sorted(
                [d for d in self._deferred if d[0] <= self.step],
                key=lambda d: (d[0], d[1]),
            )
            if due:
                self._deferred = [d for d in self._deferred if d[0] > self.step]
                for _, _, fn in due:
                    fn()
            # (b) faults scheduled for this step
            if faults is not None:
                for spec in faults.pop(self.step):
                    if on_fault is not None:
                        on_fault(self.step, spec)
            # (c) one seeded client op; idle steps still tick virtual time
            # (the run stays live until a future fault/deferred action lands)
            runnable = self._runnable()
            if runnable:
                name, ops = runnable[self.rng.randrange(len(runnable))]
                op = ops[self._cursor[name]]
                self._cursor[name] += 1
                on_op(self.step, name, op)
            elif not self._deferred and not (faults and faults.pending()):
                break  # quiescent
            self.clock.advance(self.tick_s)
            self.step += 1
        return self.step


__all__ = ["StepScheduler"]

"""The simulation harness: wire workload + store + router + faults + oracles.

One :func:`run_sim` call is one deterministic universe: a seeded virtual
clock and step scheduler drive concurrent ``lookup_batch`` /
``insert_batch`` / ``remove`` / ``autotune`` / ``keys`` / ``len`` traffic
(and, for router scenarios, whole ``route_batch`` admission waves through
a ``TwoTierRouter`` over hedged ``TierPool``\\ s, with async
cache-generation workers modeled as scheduler clients) against a
``DistributedPlanCache`` while a fault plan crashes/restarts shards,
joins/drains membership, injects replica lag, rejects cachegen
submissions, or times out tier engines. Every applied operation is
simultaneously replayed on the sequential :class:`~repro.sim.oracle.
ModelStore`; divergence is a :class:`~repro.sim.oracle.Violation`.

Determinism contract: ``run_sim(cfg)`` twice returns the identical
``trace_hash`` AND the identical ``span_digest`` — the run executes under
a ``repro.obs`` tracer bound to the virtual clock, so the exported span
stream (ids, timestamps, attributes) is a pure function of ``(seed,
config)``, byte-identical across reruns. On violations the report carries
a replayable repro file (see ``repro.sim.trace``).
"""

from __future__ import annotations

import shutil
import tempfile
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.core.distributed_cache import DistributedPlanCache
from repro.envs.base import Workspace, det_rng
from repro.envs.workloads import SIM_SCENARIOS, sim_traffic
from repro.obs import InMemoryExporter, Tracer, use_tracer
from repro.serving.router import TierPool, TwoTierRouter
from repro.sim.clock import VirtualClock
from repro.sim.faults import (
    ABLATION_OF,
    ALL_ABLATIONS,
    FAULT_PLANS,
    SCENARIO_ABLATION_OF,
    EngineFaultState,
    SimCachegenPool,
    SimInterceptor,
    build_fault_schedule,
)
from repro.sim.oracle import ModelStore, Violation, make_value, value_torn
from repro.sim.scheduler import StepScheduler
from repro.sim.trace import TraceRecorder

# ablation keys consumed by DistributedPlanCache's own seams (the rest are
# consumed by the harness/router wiring below)
_STORE_ABLATIONS = ("crash_fallthrough", "evict_after_wave", "churn_rehome",
                    "fuzzy_scatter", "cold_gc_refcount", "ttl_expiry")


@dataclass
class SimConfig:
    seed: int = 0
    scenario: str = "skewed_reuse"  # see envs.workloads.SIM_SCENARIOS
    fault: str = "none"  # see faults.FAULT_PLANS
    n_ops: int = 60  # ops per client
    n_clients: int = 4
    batch: int = 4
    n_nodes: int = 4
    replication: int = 2
    capacity_per_node: int = 512
    eviction: str = "lru"
    fuzzy: bool = False
    fuzzy_threshold: float = 0.8
    router: bool = False  # drive route_batch through TwoTierRouter
    async_cachegen: bool = False  # model the cachegen pool as sim clients
    cachegen_workers: int = 2
    # speculative near-hit execution: fuzzy near-hits are served
    # immediately while a verify task (riding the cachegen pool, p~0.7
    # agreement) races them; the journaled effects commit or roll back
    speculate: bool = False
    lag_steps: int = 6
    ablate: Tuple[str, ...] = ()  # guard ablations (faults.ALL_ABLATIONS)
    # tiered-memory knobs: cold_tier spills capacity victims to an on-disk
    # segment tier (a per-run temp directory — the flag, not a path, lives
    # here so replay JSON stays machine-independent); ttl_s wraps the
    # eviction policy in expire-on-touch
    cold_tier: bool = False
    ttl_s: Optional[float] = None

    def normalized(self) -> "SimConfig":
        """Fill in plan-specific defaults (documented per fault plan)."""
        cfg = self
        if cfg.fault == "speculative_exec":
            # paraphrase traffic against a small fuzzy cluster: every
            # variant lookup that resolves fuzzily opens a speculation,
            # and the pool-saturation bursts force rejected verify
            # submissions through the sync-fallback guard. The short TTL
            # is load-bearing: a fuzzy hit promotes the variant to an
            # exact alias (and a variant-first miss admits under the
            # variant keyword), so without expiry the fuzzy window only
            # exists once per variant and some seeds never speculate —
            # churn re-opens it all run long
            cfg = replace(cfg, scenario="paraphrase_burst", speculate=True,
                          n_nodes=2, replication=1,
                          ttl_s=cfg.ttl_s if cfg.ttl_s is not None else 0.05)
        if cfg.speculate and not (cfg.router and cfg.async_cachegen):
            cfg = replace(cfg, router=True, async_cachegen=True)
        if cfg.fault == "hedge_timeout" and not cfg.router:
            cfg = replace(cfg, router=True)
        if cfg.fault == "async_cachegen":
            cfg = replace(cfg, router=True, async_cachegen=True)
        if cfg.async_cachegen and not cfg.router:
            cfg = replace(cfg, router=True)
        if cfg.fault == "mid_wave_evict":
            # single-shard store under real eviction pressure: waves are
            # larger than capacity so evict-after-wave vs. during-wave
            # produce different survivor sets
            cfg = replace(
                cfg,
                scenario="evict_then_hit",
                n_nodes=1,
                replication=1,
                capacity_per_node=min(cfg.capacity_per_node, 8),
                batch=max(cfg.batch, 12),
            )
        if cfg.fault == "cold_tier":
            # single-shard, exact-match, heavy eviction pressure: every
            # wave spills, immediate re-lookups promote. Exact-only keeps
            # the model's per-key promote replay aligned with the store's
            # in-wave cold stage (fuzzy would re-resolve mid-wave against
            # an index the store only updates at wave end)
            cfg = replace(
                cfg,
                scenario="evict_then_hit",
                fuzzy=False,
                n_nodes=1,
                replication=1,
                capacity_per_node=min(cfg.capacity_per_node, 8),
                batch=max(cfg.batch, 12),
                cold_tier=True,
            )
        if cfg.fault == "ttl_churn":
            # expiry-vs-lookup races: skewed reuse gaps straddle a short
            # TTL so hot keys survive while the tail expires under
            # concurrent lookups. Exact-only: an intra-wave expiry deletes
            # a key from the store's fuzzy index between two queries of
            # the SAME wave, which the model (per-key replay) cannot
            # mirror — the exact pipeline has no such coupling
            cfg = replace(
                cfg,
                scenario="skewed_reuse",
                fuzzy=False,
                n_nodes=1,
                replication=1,
                ttl_s=cfg.ttl_s if cfg.ttl_s is not None else 0.05,
            )
        if cfg.scenario == "paraphrase_burst":
            cfg = replace(cfg, fuzzy=True)
        return cfg


@dataclass
class SimReport:
    config: SimConfig
    trace_hash: str
    steps: int
    ops_applied: int
    lookups: int
    inserts: int
    violations: List[Violation]
    store_stats: Dict[str, Any]
    router_metrics: Optional[Dict[str, Any]] = None
    interceptor: Dict[str, int] = field(default_factory=dict)
    cachegen: Optional[Dict[str, int]] = None
    trace_tail: List[Dict[str, Any]] = field(default_factory=list)
    # observability: blake2b of the canonical span stream (joins the
    # determinism contract alongside trace_hash), span count, and a
    # per-span-kind census of the run
    span_digest: str = ""
    n_spans: int = 0
    span_summary: Dict[str, int] = field(default_factory=dict)
    # tiered-memory accounting (all 0 unless cold_tier/ttl was configured)
    cold_stats: Dict[str, int] = field(default_factory=dict)
    # speculation accounting (None unless cfg.speculate)
    speculation: Optional[Dict[str, int]] = None

    @property
    def ok(self) -> bool:
        return not self.violations


class _FakeEngine:
    """A tier engine for router scenarios: instant plans, fault-armable."""

    def __init__(self, name: str, state: EngineFaultState):
        self.name = name
        self.state = state

    def plan(self, req: Dict[str, Any]) -> Dict[str, Any]:
        if self.state.should_timeout(self.name):
            raise TimeoutError(f"{self.name}: injected engine timeout")
        return {"plan": f"{self.name}:{req['kw']}"}


class _RecordingStore:
    """Forwarding proxy over the store under test that records every
    ``insert_batch`` wave. The router (sync OR async cachegen) distills
    misses through this seam, so the harness can mirror each admission
    wave into the sequential model at the exact step it actually lands —
    which is precisely what makes the async admission race checkable."""

    def __init__(self, store: DistributedPlanCache):
        self._store = store
        # (wave, unless_written_since token, kind) — the token travels
        # with the wave so the model's conditional-admission replay sees
        # exactly the timestamp each shard compared against; kind
        # separates distilled miss waves ("distill", owed by the
        # cachegen_loss account) from committed speculation promotions
        # ("spec", owed by the spec_leak account)
        self._waves: List[
            Tuple[List[Tuple[str, Any]], Optional[float], str]
        ] = []

    def insert_batch(self, items, **kw):
        items = list(items)
        self._waves.append((items, kw.get("unless_written_since"), "distill"))
        return self._store.insert_batch(items, **kw)

    def insert(self, keyword, value, *, context=None, vector=None,
               unless_written_since=None):
        """Single-key admission — the router's committed-speculation
        promotion path. Recorded like a wave (the model must mirror it at
        the step it lands) but tagged ``spec`` so the distillation ledger
        doesn't count it as an owed miss wave."""
        self._waves.append(([(keyword, value)], unless_written_since, "spec"))
        return self._store.insert(
            keyword, value, context=context, vector=vector,
            unless_written_since=unless_written_since,
        )

    def drain_waves(
        self,
    ) -> List[Tuple[List[Tuple[str, Any]], Optional[float], str]]:
        waves, self._waves = self._waves, []
        return waves

    def __getattr__(self, name: str) -> Any:
        return getattr(self._store, name)


def run_sim(config: SimConfig) -> SimReport:
    cfg = config.normalized()
    # the cold tier is REAL on-disk state (CheckpointStore segments): each
    # universe gets a throwaway directory whose path never reaches the
    # trace/span streams, so determinism digests stay machine-independent
    cold_dir = tempfile.mkdtemp(prefix="sim-cold-") if cfg.cold_tier else None
    try:
        return _run_sim(cfg, cold_dir)
    finally:
        if cold_dir is not None:
            shutil.rmtree(cold_dir, ignore_errors=True)


def _run_sim(cfg: SimConfig, cold_dir: Optional[str]) -> SimReport:
    if cfg.scenario not in SIM_SCENARIOS:
        raise ValueError(f"unknown scenario {cfg.scenario!r}")
    if cfg.fault not in FAULT_PLANS:
        raise ValueError(f"unknown fault plan {cfg.fault!r}")

    clock = VirtualClock()
    scheduler = StepScheduler(cfg.seed, clock)
    trace = TraceRecorder()
    violations: List[Violation] = []
    engine_faults = EngineFaultState()

    unknown = set(cfg.ablate) - set(ALL_ABLATIONS)
    if unknown:
        raise ValueError(
            f"unknown ablation key(s) {sorted(unknown)}; "
            f"valid: {list(ALL_ABLATIONS)}"
        )

    # spans bind to the virtual clock: ids are sequential, timestamps are
    # scheduler-owned, so the exported stream is byte-identical per seed
    span_exporter = InMemoryExporter()
    tracer = Tracer(clock=clock, exporters=[span_exporter])

    interceptor = SimInterceptor(scheduler, clock)
    store = DistributedPlanCache(
        cfg.n_nodes,
        replication=cfg.replication,
        capacity_per_node=cfg.capacity_per_node,
        fuzzy=cfg.fuzzy,
        fuzzy_threshold=cfg.fuzzy_threshold,
        eviction=cfg.eviction,
        clock=clock,
        interceptor=interceptor,
        ack_policy="primary" if "replica_ack" in cfg.ablate else "all",
        ablate=[a for a in cfg.ablate if a in _STORE_ABLATIONS],
        ttl_s=cfg.ttl_s,
        cold_dir=cold_dir,
        # tiny rotation horizon so the ablated (age-based) gc actually
        # deletes still-referenced segments within a short run — under the
        # refcount guard the same horizon never touches a live segment
        cold_keep_last=2,
    )
    interceptor.lag_steps = cfg.lag_steps

    model = ModelStore(
        replication=cfg.replication,
        capacity_per_node=cfg.capacity_per_node,
        eviction=cfg.eviction,
        exact_only=not cfg.fuzzy,
        fuzzy=cfg.fuzzy,
        fuzzy_threshold=cfg.fuzzy_threshold,
        clock=clock,
        # the model ALWAYS encodes the spec — an ablated store diverges
        # from it, which is exactly what the audit cells assert
        ttl_s=cfg.ttl_s,
        cold_enabled=cfg.cold_tier,
    )
    for name in list(store.shards):
        model.add_node(name)

    # the worker clients must exist before client traffic is added so the
    # scheduler's seeded choice set is stable in both router modes
    cachegen_pool: Optional[SimCachegenPool] = None
    if cfg.router and cfg.async_cachegen:
        cachegen_pool = SimCachegenPool(
            scheduler, clock, workers=cfg.cachegen_workers
        )

    # speculation side-state: the env-effect surface (a Workspace written
    # through the journal, one unique key per speculation) and the
    # verifier's own ledger of verdicts — the ground truth the spec_leak
    # oracle settles the workspace, store, and metrics against
    spec_ws = Workspace()
    spec_ledger: List[Dict[str, Any]] = []
    spec_seq = {"n": 0}

    def spec_effect(request: Dict[str, Any], kw: str):
        """Apply one speculation's eager env write; return its undo. The
        unique workspace key rides on the request so the verify call (same
        request object) can correlate verdict with effect."""
        spec_seq["n"] += 1
        ws_key = f"spec/{spec_seq['n']:04d}/{kw}"
        request["spec_ws_key"] = ws_key
        return spec_ws.write(ws_key, kw)

    def spec_verify(request: Dict[str, Any], matched_key) -> bool:
        """The background verifier: seeded ~70% agreement, deterministic
        per speculation (the workspace key is assigned in begin order,
        which the scheduler owns)."""
        agree = det_rng(
            cfg.seed, "spec-verify", request["spec_ws_key"]
        ).random() < 0.7
        spec_ledger.append({
            "kw": request["kw"], "ws_key": request["spec_ws_key"],
            "agree": agree,
        })
        return agree

    router: Optional[TwoTierRouter] = None
    rec: Optional[_RecordingStore] = None
    if cfg.router:
        rec = _RecordingStore(store)
        large = TierPool(
            "large",
            replicas=[_FakeEngine("large-0", engine_faults),
                      _FakeEngine("large-1", engine_faults)],
            hedge_timeout_s=5.0,
            hedge_failover="hedge_failover" not in cfg.ablate,
        )
        small = TierPool(
            "small", replicas=[_FakeEngine("small-0", engine_faults)]
        )
        router = TwoTierRouter(
            rec,
            extract_keyword=lambda r: r["kw"],
            plan_large=lambda r: large.dispatch(
                lambda eng: eng.plan(r), hedge=True
            ),
            plan_small_with_template=lambda r, tpl: {
                "plan": f"small:{r['kw']}", "tpl": tpl
            },
            make_template=lambda r, res: make_value(r["kw"], 0),
            # async: the sim pool's workers are scheduler clients, so the
            # seeded scheduler owns the admission-race interleavings; sync:
            # the wave lands inside the route op itself
            async_cachegen=cfg.async_cachegen,
            cachegen_pool=cachegen_pool,
            cachegen_fallback="cachegen_fallback" not in cfg.ablate,
            clock=clock,
            obs=store.obs,
            # speculative near-hit execution: verify tasks ride the same
            # sim pool, so the seeded scheduler owns the commit/rollback
            # races too. Both guards are ablatable.
            spec_verify=spec_verify if cfg.speculate else None,
            spec_effect=spec_effect if cfg.speculate else None,
            spec_rollback="spec_rollback" not in cfg.ablate,
            spec_verify_fallback="spec_verify_timeout" not in cfg.ablate,
        )

    versions: Dict[str, int] = {}
    counters = {"ops": 0, "lookups": 0, "inserts": 0}
    distill = {"expected": 0, "landed": 0}
    spec_landed = {"waves": 0, "stale_races": 0}

    def mirror_recorded_waves() -> None:
        """Replay the router's recorded admission waves on the model at
        the step they landed (sync: inside the route op; async: inside the
        cachegen worker op the scheduler chose to run). Committed
        speculation promotions mirror the same way but settle against the
        speculation ledger, not the miss-distillation account."""
        for wave, token, kind in rec.drain_waves():
            for kw, _ in wave:
                versions.setdefault(kw, 0)
            if kind == "spec" and token is not None:
                # the nastiest race made observable: a committed
                # speculation whose cached source entry was (re)written
                # after the route-time token — conditional admission must
                # lose to the newer write on that owner (the model
                # replays the same per-node skip, so a store that
                # clobbered would diverge into linearizability red)
                for kw, _ in wave:
                    if any(
                        kw in model.nodes[n]
                        and model.wtime[n][kw] >= token
                        for n in model._live_owners(kw)
                        if n not in model.crashed
                    ):
                        spec_landed["stale_races"] += 1
            model.insert_wave(wave, unless_written_since=token)
            counters["inserts"] += len(wave)
            if kind == "spec":
                spec_landed["waves"] += len(wave)
            else:
                distill["landed"] += len(wave)

    # ---- op application ----------------------------------------------------

    def check_lookup(step: int, kws: List[str], got: List[Optional[Any]]) -> None:
        # wave-level replay: the model mirrors the store's stage structure
        # (hot pass for every query, then the cold pass), not key-by-key
        for kw, real, (expected, strict) in zip(kws, got, model.lookup_wave(kws)):
            if real is not None and value_torn(real):
                violations.append(Violation(step, "torn_entry",
                                            f"{kw!r} -> corrupt value {real!r}"))
                continue
            if expected is not None and real is None:
                violations.append(Violation(
                    step, "durability",
                    f"{kw!r} acked as {expected['k']!r} v{expected['v']} "
                    "but came back MISS"))
            elif expected is not None and real is not None:
                if real.get("k") != expected["k"]:
                    violations.append(Violation(
                        step, "resolution",
                        f"{kw!r} resolved to {real.get('k')!r}, model "
                        f"resolves to {expected['k']!r}"))
                elif real.get("v") != expected["v"]:
                    violations.append(Violation(
                        step, "linearizability",
                        f"{kw!r} stale read: got v{real.get('v')}, "
                        f"acked v{expected['v']}"))
            elif expected is None and strict and real is not None:
                violations.append(Violation(
                    step, "phantom",
                    f"{kw!r} returned {real!r} but model says absent "
                    "(eviction/removal not honored)"))

    def apply_store_op(step: int, client: str, op: Dict[str, Any]) -> None:
        kind = op["op"]
        if kind == "lookup":
            got = store.lookup_batch(op["kws"])
            counters["lookups"] += len(op["kws"])
            check_lookup(step, op["kws"], got)
            trace.record(step, client, "lookup", op["kws"],
                         [None if v is None else v.get("v") for v in got])
        elif kind == "insert":
            items = []
            for kw in op["kws"]:
                versions[kw] = versions.get(kw, 0) + 1
                items.append((kw, make_value(kw, versions[kw])))
            store.insert_batch(items)
            model.insert_wave(items)
            counters["inserts"] += len(items)
            trace.record(step, client, "insert",
                         [(kw, v["v"]) for kw, v in items])
        elif kind == "remove":
            removed = store.remove(op["kw"])
            model.remove(op["kw"])
            trace.record(step, client, "remove", op["kw"], removed)
        elif kind == "autotune":
            actions = store.autotune()
            trace.record(step, client, "autotune", None, actions)
        elif kind == "keys":
            # control-plane scan: pays one seam RPC per reachable shard
            got = store.keys()
            want = model.visible_keys()
            if got != want:
                diff = sorted(set(got) ^ set(want))
                violations.append(Violation(
                    step, "control_plane",
                    f"keys() saw {len(got)} keys, model says {len(want)} "
                    f"(diff {diff[:4]}...)"))
            trace.record(step, client, "keys", None, len(got))
        elif kind == "len":
            got = len(store)
            want = len(model.visible_keys())
            if got != want:
                violations.append(Violation(
                    step, "control_plane",
                    f"len() == {got}, model says {want}"))
            trace.record(step, client, "len", None, got)
        else:
            raise ValueError(f"unknown sim op {kind!r}")

    def apply_router_op(step: int, client: str, op: Dict[str, Any]) -> None:
        kws = op["kws"] if "kws" in op else [op.get("kw", "")]
        reqs = [{"kw": kw} for kw in kws]
        counters["lookups"] += len(reqs)
        try:
            out = router.route_batch(reqs)
        except Exception as e:  # dropped wave: completeness oracle fires
            violations.append(Violation(
                step, "completeness",
                f"route_batch dropped {len(reqs)} request(s): {e!r}"))
            trace.record(step, client, "route", kws, f"ERROR:{type(e).__name__}")
            return
        for kw, res in zip(kws, out):
            if res is None:
                violations.append(Violation(
                    step, "completeness", f"request {kw!r} got no response"))
        # every large-tier miss owes the cache exactly one distilled
        # template (make_template above never returns None); the
        # cachegen_loss oracle settles the account at quiescence
        distill["expected"] += sum(
            1 for res in out
            if res is not None and res["plan"].startswith("large")
        )
        # sync mode (and the guarded saturated-pool fallback) lands the
        # wave inside this op; async waves land in a cachegen worker op
        mirror_recorded_waves()
        # record the TIER only: which hedged replica won a two-success race
        # is real concurrency the sim tolerates; the tier (and everything
        # downstream of it) must be deterministic
        trace.record(step, client, "route", kws,
                     [None if r is None
                      else ("small" if r["plan"].startswith("small") else "large")
                      for r in out])

    def apply_cachegen_op(step: int, client: str, op: Dict[str, Any]) -> None:
        try:
            items = op["fn"]()
        except Exception as e:
            op["future"].set_result(None)
            violations.append(Violation(
                step, "cachegen_error",
                f"async cache generation raised {e!r}"))
            trace.record(step, client, "cachegen", None,
                         f"ERROR:{type(e).__name__}")
            return
        op["future"].set_result(items)
        mirror_recorded_waves()
        if isinstance(items, str):
            # a speculation verify task (rides the same pool): the result
            # is its outcome, and any committed promotion wave was just
            # mirrored above at this exact step
            trace.record(step, client, "spec_verify", None, items)
            return
        trace.record(step, client, "cachegen",
                     [kw for kw, _ in (items or [])], len(items or []))

    def on_op(step: int, client: str, op: Dict[str, Any]) -> None:
        counters["ops"] += 1
        if op["op"] == "cachegen":
            apply_cachegen_op(step, client, op)
        elif router is not None and op["op"] in ("lookup", "insert"):
            apply_router_op(step, client, op)
        else:
            apply_store_op(step, client, op)

    # ---- fault firing ------------------------------------------------------

    def on_fault(step: int, spec) -> None:
        d = spec.details
        if spec.kind == "crash":
            interceptor.crash(d["node"])
            model.crash(d["node"])
        elif spec.kind == "restart":
            interceptor.restore(d["node"])
            repaired = store.restart_node(d["node"], recover=d.get("recover", True))
            model.restart(d["node"], recover=d.get("recover", True))
            trace.record(step, "fault", "restart",
                         d["node"], {"repaired": repaired})
            return
        elif spec.kind == "lag":
            interceptor.lag_steps = d["steps"]
        elif spec.kind == "hedge_timeout":
            engine_faults.arm(d["engine"], d["calls"])
        elif spec.kind == "join":
            # elastic scale-out: the facade rebalances (unless the
            # churn_rehome guard is ablated); the model mirrors the ring
            # change with the CORRECT re-home semantics
            store.add_node(d["node"])
            model.join(d["node"])
        elif spec.kind == "drain":
            store.remove_node(d["node"])
            model.drain(d["node"])
        elif spec.kind == "pool_saturate":
            if cachegen_pool is not None:
                cachegen_pool.arm_saturation(d["calls"])
        elif spec.kind == "cold_crash":
            # arm BOTH sides: the store's next spill wave dies between
            # segment write and manifest commit; the model drops the same
            # wave, so the loss is deterministic and the oracles prove it
            # is whole-wave (nothing both lost and unevicted)
            store.arm_cold_crash(d["calls"])
            model.arm_cold_crash(d["calls"])
        trace.record(step, "fault", spec.kind, d)

    # ---- run ---------------------------------------------------------------

    for ci, ops in enumerate(
        sim_traffic(cfg.scenario, cfg.seed, n_ops=cfg.n_ops,
                    n_clients=cfg.n_clients, batch=cfg.batch)
    ):
        scheduler.add_client(f"client-{ci}", ops)

    faults = build_fault_schedule(
        cfg.fault, cfg.n_ops * cfg.n_clients, lag_steps=cfg.lag_steps
    )
    with use_tracer(tracer):
        steps = scheduler.run(on_op, faults=faults, on_fault=on_fault)

        # drain inside the traced region so late cachegen spans land in the
        # exported stream before the digest is taken
        if router is not None:
            router.drain()
    tracer.close()

    # ---- terminal oracles --------------------------------------------------

    if router is not None:
        m = router.metrics
        dropped = any(v.oracle == "completeness" for v in violations)
        if m.hits + m.misses != m.requests and not dropped:
            violations.append(Violation(
                steps, "stats_conservation",
                f"router hits+misses={m.hits + m.misses} != requests={m.requests}"))
        if distill["landed"] != distill["expected"]:
            violations.append(Violation(
                steps, "cachegen_loss",
                f"{distill['expected']} miss distillation(s) owed, "
                f"{distill['landed']} landed — admission waves were "
                "dropped"))
    if cfg.speculate and router is not None and router.speculator is not None:
        spec = router.speculator
        m = router.metrics
        agrees = sum(1 for e in spec_ledger if e["agree"])
        # spec_leak: a speculation the verifier REJECTED must leave no
        # side effect behind — its journaled env write compensated, its
        # deferred cache promotion and metric bump never run. The dual
        # obligation holds too: a committed speculation's effect must
        # survive (the journal must not undo finalized steps).
        for e in spec_ledger:
            present = e["ws_key"] in spec_ws
            if e["agree"] and not present:
                violations.append(Violation(
                    steps, "spec_leak",
                    f"committed speculation on {e['kw']!r} LOST its env "
                    f"write {e['ws_key']!r} (journal undid a finalized "
                    "step)"))
            elif not e["agree"] and present:
                violations.append(Violation(
                    steps, "spec_leak",
                    f"rolled-back speculation on {e['kw']!r} leaked env "
                    f"write {e['ws_key']!r} into the workspace"))
        if m.spec_commits != agrees:
            violations.append(Violation(
                steps, "spec_leak",
                f"metrics registry saw {m.spec_commits} speculation "
                f"commit(s) but the verifier agreed {agrees} time(s) — "
                "a rolled-back speculation leaked into the metrics"))
        # still-pending speculations legitimately hold their (unresolved)
        # keys — they are spec_liveness's business, not a leak
        if (spec_ws.writes != spec.begun
                or len(spec_ws) != agrees + spec.pending()):
            violations.append(Violation(
                steps, "spec_leak",
                f"workspace holds {len(spec_ws)} key(s) after "
                f"{spec_ws.writes} speculative write(s); exactly "
                f"{agrees} committed + {spec.pending()} pending key(s) "
                "may remain"))
        # spec_liveness: every speculation begun must be resolved by
        # quiescence — a dropped verify task (the ablated fallback) or a
        # lost pool submission leaves the journal open forever
        if spec.pending() != 0:
            violations.append(Violation(
                steps, "spec_liveness",
                f"{spec.pending()} speculation(s) never resolved: "
                f"{spec.pending_keys()[:4]}"))
        resolved = spec.commits + spec.rollbacks + spec.forced_commits
        if spec.begun != resolved + spec.pending():
            violations.append(Violation(
                steps, "spec_liveness",
                f"speculation conservation broken: begun={spec.begun} != "
                f"resolved={resolved} + pending={spec.pending()}"))
    s = store.stats
    if s.hits + s.misses != counters["lookups"]:
        violations.append(Violation(
            steps, "stats_conservation",
            f"store hits+misses={s.hits + s.misses} != "
            f"lookups issued={counters['lookups']}"))
    for name, shard in sorted(store.shards.items()):
        if len(shard) > cfg.capacity_per_node:
            violations.append(Violation(
                steps, "capacity",
                f"{name} holds {len(shard)} > capacity {cfg.capacity_per_node}"))
    if not cfg.router and cfg.fault in ("none", "mid_wave_evict",
                                        "cold_tier", "ttl_churn"):
        # eviction conservation: the store must evict exactly the victims
        # the sequential policy replay evicts. Runs on fuzzy cells too —
        # the model mirrors the store's grouped per-shard per-tier
        # intra-wave touch order (see oracle.lookup_wave) — but not on
        # crash plans (a shard restart resets shard counters) or router
        # cells (route lookups touch recency the model never sees; only
        # admission waves are mirrored there)
        shard_evictions = sum(sh.stats.evictions for sh in store.shards.values())
        if shard_evictions != model.evictions:
            violations.append(Violation(
                steps, "eviction_order",
                f"store evicted {shard_evictions} entries, policy replay "
                f"says {model.evictions}"))
    if cfg.fault == "none" and not cfg.ablate:
        if store.keys() != model.keys():
            violations.append(Violation(
                steps, "linearizability",
                "final key set diverges from the sequential model"))

    if router is not None:
        router.close()

    span_summary: Dict[str, int] = {}
    for sp in span_exporter.spans:
        span_summary[sp["name"]] = span_summary.get(sp["name"], 0) + 1

    return SimReport(
        config=cfg,
        trace_hash=trace.trace_hash,
        steps=steps,
        ops_applied=counters["ops"],
        lookups=counters["lookups"],
        inserts=counters["inserts"],
        violations=violations,
        store_stats=s.snapshot(),
        router_metrics=(router.metrics.snapshot() if router is not None else None),
        interceptor={
            "calls": interceptor.calls,
            "failed_calls": interceptor.failed_calls,
            "deferred_writes": interceptor.deferred_writes,
        },
        cachegen=(
            None if cachegen_pool is None else {
                "submitted": cachegen_pool.submitted,
                "rejected": cachegen_pool.rejected,
            }
        ),
        trace_tail=trace.tail,
        span_digest=span_exporter.digest(),
        n_spans=tracer.n_spans,
        span_summary=span_summary,
        # spill/promote accounting lands on the shard-labeled counters
        # (spills happen inside shard insert waves), so aggregate those
        cold_stats={
            k: sum(sh.stats.cold_snapshot()[k]
                   for sh in store.shards.values())
            for k in s.cold_snapshot()
        },
        speculation=(
            None
            if router is None or router.speculator is None
            else {
                **router.speculator.stats(),
                "verifier_agreed": sum(1 for e in spec_ledger if e["agree"]),
                "landed": spec_landed["waves"],
                "stale_admit_races": spec_landed["stale_races"],
                "ws_writes": spec_ws.writes,
                "ws_compensations": spec_ws.compensations_run,
                "ws_keys": len(spec_ws),
            }
        ),
    )


# re-export for CLI/tests convenience
__all__ = ["ABLATION_OF", "ALL_ABLATIONS", "FAULT_PLANS",
           "SCENARIO_ABLATION_OF", "SimConfig", "SimReport", "run_sim"]

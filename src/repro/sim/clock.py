"""Virtual time for deterministic simulation.

Every time-dependent seam in the serving/distributed layers (PlanCache TTL
expiry, FaultTolerantRunner straggler deadlines, router latency metrics)
accepts a ``clock`` callable. In production that is ``time.time`` /
``time.perf_counter``; under simulation it is a :class:`VirtualClock`
advanced explicitly by the step scheduler — no BEHAVIOR-affecting
wall-clock read reaches the system under test, so a run's observable
behavior (and its trace hash) is a pure function of ``(seed, config)``.
Every simulated cost charges this clock: the fault interceptor's
per-shard RPC latency (data-plane AND control-plane ops), the cachegen
pool's submit latency, and the scheduler's per-step tick. Pure
wall-latency metrics (``CacheStats.lookup_time_s``) still read the
perf counter; they feed no decision and are excluded from the trace.
"""

from __future__ import annotations


class VirtualClock:
    """Monotonic virtual seconds; advanced explicitly, never by the OS."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def time(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"virtual time cannot go backwards (dt={dt})")
        self.t += dt
        return self.t

    def __repr__(self) -> str:
        return f"VirtualClock(t={self.t:.6f})"


__all__ = ["VirtualClock"]

"""Trace recording + replayable failure seeds.

Every applied operation (client ops, control-plane ``keys``/``len``
scans, fault firings — including membership ``join``/``drain`` — async
cachegen worker ops, deferred lag writes) is folded into a running
blake2b hash and kept in an in-memory ring. Two runs of the same
``(seed, config)`` must produce the identical hash — that IS the
determinism contract ``python -m repro.sim --seed N`` verifies. Real
concurrency the sim tolerates stays OUT of the fold: a hedged dispatch
records the winning TIER, never which replica won the race.

On an oracle violation the CLI dumps a **repro file** (see
``repro.sim.__main__._fail_dump``): the full simulation config plus the
violation list and the trace tail carried on the report. The file is
self-contained — ``python -m repro.sim --replay FILE`` reruns the exact
configuration and asserts the trace hash matches the recorded one, so a
red CI seed replays to the identical interleaving on a laptop.
"""

from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, List


def _canon(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, default=repr, separators=(",", ":"))


class TraceRecorder:
    """Order-sensitive event log with a running hash and a bounded tail."""

    def __init__(self, keep_last: int = 400):
        self.keep_last = keep_last
        self.n_events = 0
        self.tail: List[Dict[str, Any]] = []
        self._h = hashlib.blake2b(digest_size=16)

    def record(self, step: int, actor: str, kind: str,
               args: Any = None, result: Any = None) -> None:
        ev = {"step": step, "actor": actor, "kind": kind,
              "args": args, "result": result}
        self._h.update(_canon(ev).encode())
        self.n_events += 1
        self.tail.append(ev)
        if len(self.tail) > self.keep_last:
            del self.tail[: len(self.tail) - self.keep_last]

    @property
    def trace_hash(self) -> str:
        return self._h.hexdigest()

    @staticmethod
    def load_repro(path: str) -> Dict[str, Any]:
        with open(path) as f:
            return json.load(f)


__all__ = ["TraceRecorder"]

"""Sequential model-store oracle + invariant checks.

The model is the FoundationDB-style "obviously correct" twin: plain dicts
and lists, single-threaded, no locks, no batching. The step scheduler
serializes every operation, so the linearization order is known; the
optimized store must agree with the model applied in that order.

With ``fuzzy=True`` the model is *similarity-aware*: each node carries a
twin ``repro.index.SimilarityIndex`` over its local keys (the shared
embedding fixture — the same hashed-ngram ``embed`` the real shards use),
mirrored call-for-call, so the model predicts exactly which stored key a
paraphrase lookup resolves to. Paraphrase scenarios are therefore STRICT:
a fuzzy miss the model would have resolved is a durability violation and a
fuzzy hit the model says cannot happen is a phantom, where the pre-churn
model could only integrity-check them.

Membership is mirrored too: ``join`` replays ``add_node`` + ``_rebalance``
(ring change, per-shard scan skipping unreachable nodes, stale-owner
removal, re-home with per-node eviction) and ``drain`` replays the
graceful ``remove_node`` re-home — so elastic churn keeps the model exact.

Checked invariants (consumed by ``repro.sim.harness``):

* **durability / linearizability** — a key the model says is resolvable
  (inserted, acked, replicated, not evicted/removed) must come back, at
  the acked version;
* **resolution / phantom** — a lookup must resolve to exactly the key the
  model resolves it to (exact or fuzzy); a key the model says is absent
  must miss;
* **no torn entries** — every returned value's embedded checksum must
  verify (a torn/partially-applied write cannot masquerade as a hit);
* **stats conservation** — ``hits + misses == lookups`` and
  ``inserts == items offered`` on the facade's own counters;
* **capacity / eviction order** — no shard exceeds capacity, and the
  model replays the eviction policy (LRU / cost) so a wrong victim shows
  up as durability (evicted survivor) or phantom (surviving victim);
* **control plane** — ``keys()``/``len()`` must equal the union of the
  model's reachable nodes.

Intra-wave recency is mirrored faithfully: ``lookup_wave`` replays the
facade's tier-major grouped fan-out (tier 0 groups queries by primary
owner; each later tier re-groups the still-missing ones; shard groups
visit in sorted-node order) and, within one shard call, the match
pipeline's stage order — the whole group's exact stage first, then ONE
batched similarity call for the leftovers, then per-key cold promotion —
so per-shard per-tier LRU touch order inside a single wave matches the
store bit-for-bit and the eviction-order oracle runs on fuzzy cells too.
Router-driven cells stay outside that oracle's gate for a different
reason: route lookups touch store recency through traffic the admission
mirror never sees — see the harness's gating and ``docs/simulation.md``.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.distributed_cache import HashRing


@dataclass
class Violation:
    step: int
    oracle: str  # "durability" | "linearizability" | "phantom" | ...
    detail: str


def checksum(kw: str, version: int) -> str:
    return hashlib.blake2b(f"{kw}#{version}".encode(), digest_size=8).hexdigest()


def make_value(kw: str, version: int) -> Dict[str, Any]:
    """A sim cache value carrying its own integrity proof."""
    return {"k": kw, "v": version, "ck": checksum(kw, version)}


def value_torn(value: Any) -> bool:
    """True when a returned value fails its integrity check."""
    if not isinstance(value, dict) or "ck" not in value:
        return True
    return value.get("ck") != checksum(value.get("k", ""), value.get("v", -1))


class ModelStore:
    """Sequential mirror of DistributedPlanCache's documented semantics."""

    def __init__(
        self,
        *,
        replication: int = 2,
        capacity_per_node: int = 256,
        eviction: str = "lru",
        vnodes: int = 64,
        exact_only: bool = True,
        fuzzy: bool = False,
        fuzzy_threshold: float = 0.8,
        index_backend: str = "auto",
        clock: Optional[Any] = None,
        ttl_s: Optional[float] = None,
        cold_enabled: bool = False,
    ):
        if eviction not in ("lru", "cost"):
            raise ValueError("model replays eviction for 'lru' and 'cost' only")
        self.replication = replication
        self.capacity = capacity_per_node
        self.eviction = eviction
        self.exact_only = exact_only
        self.fuzzy = fuzzy
        self.fuzzy_threshold = fuzzy_threshold
        self.index_backend = index_backend
        # TTL twin: entries expire strictly after ttl_s, judged against the
        # SAME virtual clock the store reads. The scheduler serializes ops
        # and nothing advances the clock between a store op and its mirror
        # call here, so write stamps and expiry decisions agree bit-for-bit
        # (the single-node pinning in SimConfig.normalized keeps one seam
        # charge per op — see docs/simulation.md).
        self.clock = clock
        self.ttl_s = ttl_s
        # cold-tier twin (repro.memory.tiered): per-node manifest mirror;
        # eviction spills, exact-miss promotes (a MOVE back to hot with a
        # cascading evict), expiry/remove never resurrect from cold
        self.cold_enabled = cold_enabled
        self.ring = HashRing(vnodes)
        self.nodes: Dict[str, Dict[str, Any]] = {}
        self.hits: Dict[str, Dict[str, int]] = {}
        self.order: Dict[str, List[str]] = {}  # LRU recency, oldest first
        self.seq: Dict[str, Dict[str, int]] = {}  # stable dict-order mirror
        self.sim: Dict[str, Any] = {}  # per-node SimilarityIndex twins
        self.wtime: Dict[str, Dict[str, float]] = {}  # write stamps (TTL/CAS)
        self.cold: Dict[str, Dict[str, Any]] = {}  # cold-manifest mirrors
        self._next_seq = 0
        self._cold_crash = 0  # armed spill-wave crashes (segment w/o manifest)
        self.crashed: set = set()
        self.evictions = 0

    def _now(self) -> float:
        return float(self.clock()) if self.clock is not None else 0.0

    def _expired(self, node: str, kw: str) -> bool:
        if self.ttl_s is None:
            return False
        return self._now() - self.wtime[node][kw] > self.ttl_s

    def arm_cold_crash(self, waves: int) -> None:
        """Mirror of ``DistributedPlanCache.arm_cold_crash``: the next
        ``waves`` spill waves lose their entries (segment written, manifest
        never committed)."""
        self._cold_crash = waves

    # -- membership ----------------------------------------------------------

    def add_node(self, name: str) -> None:
        if name in self.nodes:
            return
        self.nodes[name] = {}
        self.hits[name] = {}
        self.order[name] = []
        self.seq[name] = {}
        self.wtime[name] = {}
        self.cold[name] = {}
        if self.fuzzy:
            from repro.index import SimilarityIndex

            # the twin index: same backend, mirrored call-for-call, so
            # scores/slots/tie-breaks are bit-identical to the shard's
            self.sim[name] = SimilarityIndex(backend=self.index_backend)
        self.ring.add(name)

    def join(self, name: str) -> None:
        """Mirror of ``add_node`` on a live cluster: ring change + the
        ``_rebalance`` re-home (the churn-rehoming guard's CORRECT
        semantics — an ablated store diverges from this and the durability
        oracle catches it)."""
        if name in self.nodes:
            return
        self.add_node(name)
        self._rebalance()

    def drain(self, name: str) -> None:
        """Mirror of graceful ``remove_node``: the drain scan re-homes the
        node's keys to their new owners — unless the node is unreachable,
        in which case its copies are lost with it (crash-style removal)."""
        if name not in self.nodes:
            return
        pairs = (
            [] if name in self.crashed else list(self.nodes[name].items())
        )
        self._drop_node(name)
        for kw, v in pairs:
            self._insert_single(kw, v)

    def _drop_node(self, name: str) -> None:
        del self.nodes[name]
        del self.hits[name]
        del self.order[name]
        del self.seq[name]
        self.wtime.pop(name, None)
        # a dropped node takes its cold directory with it — nothing re-homes
        self.cold.pop(name, None)
        self.sim.pop(name, None)
        self.ring.remove(name)
        self.crashed.discard(name)

    def _rebalance(self) -> None:
        """Mirror of ``DistributedPlanCache._rebalance``: scan shards in
        membership order (an unreachable shard keeps its keys), collect
        keys whose owner set no longer includes their holder, then remove
        from the stale owner and re-home with per-node eviction."""
        moves: List[Tuple[str, str, Any]] = []
        for node in list(self.nodes):
            if node in self.crashed:
                continue  # scan RPC fails: its keys stay put
            for kw, v in list(self.nodes[node].items()):
                if node not in self.ring.nodes_for(kw, self.replication):
                    moves.append((node, kw, v))
        for node, kw, v in moves:
            self._remove_from(node, kw)
            # the facade re-homes via ``shard.remove`` which purges the
            # stale owner's cold manifest too
            if self.cold_enabled:
                self.cold[node].pop(kw, None)
            self._insert_single(kw, v)

    def crash(self, name: str) -> None:
        self.crashed.add(name)

    def restore(self, name: str) -> None:
        self.crashed.discard(name)

    def restart(self, name: str, *, recover: bool = True) -> None:
        """Mirror of ``restart_node``: data gone; read-repair from peers."""
        self.crashed.discard(name)
        self.nodes[name] = {}
        self.hits[name] = {}
        self.order[name] = []
        self.seq[name] = {}
        # restart_node calls shard.clear(), which wipes the cold manifest
        # and gc's its segments — cold entries do NOT survive a restart
        self.wtime[name] = {}
        self.cold[name] = {}
        if self.fuzzy:
            self.sim[name].clear()
        if not recover:
            return
        for peer in sorted(self.nodes):
            # an unreachable peer cannot donate repair data (the facade's
            # repair scan goes through the interceptor seam and fails)
            if peer == name or peer in self.crashed:
                continue
            for kw, v in self.nodes[peer].items():
                if kw in self.nodes[name]:
                    continue
                if name in self.ring.nodes_for(kw, self.replication):
                    self._apply(name, kw, v)
        if self.fuzzy and self.nodes[name]:
            # the repaired entries land as ONE insert_batch on the real
            # restarted shard, so the twin ingests them as one batch too
            self.sim[name].add_batch(list(self.nodes[name]))
        self._evict(name)

    # -- write path ----------------------------------------------------------

    def _apply(self, node: str, kw: str, value: Any) -> None:
        store = self.nodes[node]
        if kw not in self.seq[node]:
            self._next_seq += 1
            self.seq[node][kw] = self._next_seq
        store[kw] = value
        self.wtime[node][kw] = self._now()
        self.hits[node][kw] = 0  # re-insert resets live-hit accounting
        if kw in self.order[node]:
            self.order[node].remove(kw)
        self.order[node].append(kw)

    def _remove_from(self, node: str, kw: str) -> None:
        del self.nodes[node][kw]
        del self.hits[node][kw]
        self.wtime[node].pop(kw, None)
        self.order[node].remove(kw)
        # dict-order fidelity: a removed key re-inserts at the END of the
        # real shard's store dict, so its order stamp must not survive
        self.seq[node].pop(kw, None)
        if self.fuzzy:
            self.sim[node].remove(kw)

    def _victim(self, node: str) -> str:
        if self.eviction == "lru":
            return self.order[node][0]
        # cost: min (1 + hits) * tokens_saved(=1 for dict values), ties by
        # dict order (mirrors CacheEntry.inserted_at ties within a wave)
        return min(
            self.nodes[node],
            key=lambda k: (1 + self.hits[node][k], self.seq[node][k]),
        )

    def _evict(self, node: str) -> None:
        victims: List[Tuple[str, Any]] = []
        while len(self.nodes[node]) > self.capacity:
            victim = self._victim(node)
            victims.append((victim, self.nodes[node][victim]))
            self._remove_from(node, victim)
            self.evictions += 1
        if victims and self.cold_enabled:
            # capacity victims SPILL (expiry/remove never do); one spill
            # wave per eviction round, lost whole if a crash is armed
            # between segment write and manifest commit
            if self._cold_crash > 0:
                self._cold_crash -= 1
            else:
                for kw, v in victims:
                    self.cold[node][kw] = v

    def _live_owners(self, kw: str) -> List[str]:
        return [
            n for n in self.ring.nodes_for(kw, self.replication)
            if n in self.nodes
        ]

    def _insert_single(self, kw: str, value: Any) -> None:
        """Mirror of ``_insert_unlocked`` (the membership re-home path):
        one key to every reachable owner, evicting after each owner's
        single-item wave."""
        for n in self._live_owners(kw):
            if n in self.crashed:
                continue  # write RPC failed; remaining owners hold it
            self._apply(n, kw, value)
            if self.fuzzy:
                self.sim[n].add(kw)
            self._evict(n)

    def insert_wave(
        self,
        items: Sequence[Tuple[str, Any]],
        *,
        unless_written_since: Optional[float] = None,
    ) -> None:
        """Spec semantics: the wave lands on every live owner (crashed
        owners drop their copy — the RPC fails), grouped per node with
        eviction AFTER each node's sub-wave (primary groups first, then
        replica groups, mirroring the facade's ack structure).

        ``unless_written_since`` mirrors conditional admission: a key whose
        live entry on that node was (re)written at or after the token is
        skipped — the stale background wave loses to the newer client
        insert, per node, exactly as each shard decides it."""
        for rank0 in (True, False):
            groups: Dict[str, List[Tuple[str, Any]]] = {}
            for kw, v in items:
                owners = self._live_owners(kw)
                for rank, n in enumerate(owners):
                    if (rank == 0) == rank0:
                        groups.setdefault(n, []).append((kw, v))
            for n, sub in groups.items():
                if n in self.crashed:
                    continue  # write RPC failed; remaining owners hold it
                applied: List[str] = []
                for kw, v in sub:
                    if (
                        unless_written_since is not None
                        and kw in self.nodes[n]
                        and self.wtime[n][kw] >= unless_written_since
                    ):
                        continue  # stale write skipped; index untouched
                    self._apply(n, kw, v)
                    applied.append(kw)
                if self.fuzzy and applied:
                    self.sim[n].add_batch(applied)
                self._evict(n)

    def remove(self, kw: str) -> None:
        for n in sorted(self.nodes):
            if n in self.crashed:
                continue  # unreachable; its stale copy dies at restart
            if kw in self.nodes[n]:
                self._remove_from(n, kw)
            if self.cold_enabled:
                # shard.remove purges the cold manifest entry too — a
                # removed key must not resurrect through a later promote
                self.cold[n].pop(kw, None)

    # -- read path -----------------------------------------------------------

    def _probe_order(self, kw: str) -> List[str]:
        owners = [n for n in self._live_owners(kw)]
        if self.fuzzy:
            owners += [n for n in sorted(self.nodes) if n not in owners]
        return owners

    def _touch(self, node: str, kw: str) -> Any:
        """Serve one live key on one node: hit counter + LRU move-to-end
        (the accounting half of ``_get_live`` after its expiry check)."""
        self.hits[node][kw] += 1
        if kw in self.order[node]:
            self.order[node].remove(kw)
            self.order[node].append(kw)
        return self.nodes[node][kw]

    def _get_live(self, node: str, kw: str) -> Optional[Any]:
        """Mirror of ``PlanCache._get_live``: TTL expire-on-touch is a
        hard delete (the entry does NOT spill), a survivor is touched. A
        key an earlier serve of the SAME stage already expired misses
        here — the pipeline resolves the whole group before serving."""
        if kw not in self.nodes[node]:
            return None
        if self._expired(node, kw):
            self._remove_from(node, kw)
            return None
        return self._touch(node, kw)

    def _promote_cold(self, node: str, kw: str) -> Optional[Any]:
        """Mirror of ``PlanCache._promote``: a cold manifest hit is a
        MOVE back through the admission path, cascading evict after the
        insert, then served through the normal touch path."""
        v = self.cold[node].pop(kw)
        self._apply(node, kw, v)
        if self.fuzzy:
            self.sim[node].add_batch([kw])
        self._evict(node)
        # under the cost policy a promote into a fully-reused hot set
        # picks ITSELF as the cascade victim (hits=0, youngest stamp)
        # — the store then misses, so the model must too
        if kw not in self.nodes[node]:
            return None
        return self._touch(node, kw)

    def _serve_group(
        self,
        node: str,
        group: List[Tuple[int, str]],
        out: List[Optional[Any]],
    ) -> None:
        """Mirror ONE shard ``lookup_batch`` call for its tier group.

        Stage-major, exactly like the shard's match pipeline: the exact
        stage resolves the WHOLE group (membership snapshot first, then
        serves in group order — so a twin query whose key expired under
        an earlier serve of the same stage stays pending); the fuzzy
        stage answers the leftovers with ONE batched similarity call
        against the twin index; the cold stage promotes per still-
        pending key in group order. This is what makes per-shard LRU
        touch order inside a single wave bit-identical to the store."""
        # exact stage: resolve all, then serve in group order
        alts = [kw if kw in self.nodes[node] else None for _, kw in group]
        pending: List[Tuple[int, str]] = []
        for (i, kw), alt in zip(group, alts):
            v = None if alt is None else self._get_live(node, alt)
            if v is None:
                pending.append((i, kw))
            else:
                out[i] = v
        # fuzzy stage: one batched index call for the still-unresolved
        if pending and self.fuzzy:
            alts = self.sim[node].best_match_batch(
                [kw for _, kw in pending], self.fuzzy_threshold
            )
            still: List[Tuple[int, str]] = []
            for (i, kw), alt in zip(pending, alts):
                # an expired fuzzy twin dies inside _get_live and the
                # wave does NOT re-run the stage — the query falls
                # through to the cold stage / next tier
                v = None if alt is None else self._get_live(node, alt)
                if v is None:
                    still.append((i, kw))
                else:
                    out[i] = v
            pending = still
        # cold stage: shard-local manifest, exact keys, group order
        if pending and self.cold_enabled:
            for i, kw in pending:
                if kw in self.cold.get(node, {}):
                    out[i] = self._promote_cold(node, kw)

    def lookup_wave(
        self, kws: Sequence[str]
    ) -> List[Tuple[Optional[Any], bool]]:
        """Tier-major grouped replay of one batched facade lookup.

        Mirrors ``DistributedPlanCache.lookup_batch`` shape-for-shape:
        tier 0 groups queries by primary owner, every later tier
        re-groups the still-missing ones, shard groups are visited in
        sorted-node order, and each (node, group) runs the full match
        pipeline via ``_serve_group``. A crashed node's seam call fails,
        so its group stays pending and retries on the next replica tier
        — the crash-fallthrough guard's correct semantics."""
        strict = True if self.fuzzy else self.exact_only
        out: List[Optional[Any]] = [None] * len(kws)
        owners_of = [self._probe_order(kw) for kw in kws]
        pending = list(range(len(kws)))
        tier = 0
        while pending:
            by_node: Dict[str, List[int]] = {}
            for i in pending:
                if tier < len(owners_of[i]):
                    by_node.setdefault(owners_of[i][tier], []).append(i)
            if not by_node:
                break
            for node, idxs in sorted(by_node.items()):
                if node in self.crashed:
                    continue  # seam call fails; queries retry next tier
                self._serve_group(node, [(i, kws[i]) for i in idxs], out)
            pending = [
                i for i in pending
                if out[i] is None and tier + 1 < len(owners_of[i])
            ]
            tier += 1
        return [
            (v, True) if v is not None else (None, strict) for v in out
        ]

    def lookup(self, kw: str) -> Tuple[Optional[Any], bool]:
        """(expected value or None, strict).

        Walks the same tiered probe order as the facade — ring owners,
        then (fuzzy) the remaining shards — resolving per node exactly as
        the shard's match pipeline does: exact dict membership first, then
        the twin similarity index at the shard's threshold, then the cold
        manifest. With the twin index mirrored call-for-call the
        prediction is exact, so fuzzy cells are STRICT; ``strict=False``
        survives only for the legacy ``exact_only=False`` mode (no
        similarity model installed)."""
        return self.lookup_wave([kw])[0]

    def keys(self) -> List[str]:
        seen: set = set()
        for store in self.nodes.values():
            seen.update(store)
        return sorted(seen)

    def visible_keys(self) -> List[str]:
        """What a control-plane ``keys()`` scan can observe right now:
        the union of every *reachable* node's keys (a crashed node's seam
        call fails, so its keys are invisible until it restarts)."""
        seen: set = set()
        for n, store in self.nodes.items():
            if n not in self.crashed:
                seen.update(store)
        return sorted(seen)


__all__ = ["ModelStore", "Violation", "checksum", "make_value", "value_torn"]

"""Sequential model-store oracle + invariant checks.

The model is the FoundationDB-style "obviously correct" twin: plain dicts
and lists, single-threaded, no locks, no batching, no indexes. The step
scheduler serializes every operation, so the linearization order is known;
the optimized store must agree with the model applied in that order, up to
the documented divergences (a fuzzy pipeline may resolve keys the model
treats as misses — those results are checked for integrity, not equality).

Checked invariants:

* **durability / linearizability** — a key the model says is resolvable
  (inserted, acked, replicated, not evicted/removed) must come back, at
  the acked version;
* **phantom** — in exact mode, a key the model says is absent must miss;
* **no torn entries** — every returned value's embedded checksum must
  verify (a torn/partially-applied write cannot masquerade as a hit);
* **stats conservation** — ``hits + misses == lookups`` and
  ``inserts == items offered`` on the facade's own counters;
* **capacity / eviction order** — no shard exceeds capacity, and the
  model replays the eviction policy (LRU / cost) so a wrong victim shows
  up as durability (evicted survivor) or phantom (surviving victim).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.distributed_cache import HashRing


@dataclass
class Violation:
    step: int
    oracle: str  # "durability" | "linearizability" | "phantom" | ...
    detail: str


def checksum(kw: str, version: int) -> str:
    return hashlib.blake2b(f"{kw}#{version}".encode(), digest_size=8).hexdigest()


def make_value(kw: str, version: int) -> Dict[str, Any]:
    """A sim cache value carrying its own integrity proof."""
    return {"k": kw, "v": version, "ck": checksum(kw, version)}


def value_torn(value: Any) -> bool:
    """True when a returned value fails its integrity check."""
    if not isinstance(value, dict) or "ck" not in value:
        return True
    return value.get("ck") != checksum(value.get("k", ""), value.get("v", -1))


class ModelStore:
    """Sequential mirror of DistributedPlanCache's documented semantics."""

    def __init__(
        self,
        *,
        replication: int = 2,
        capacity_per_node: int = 256,
        eviction: str = "lru",
        vnodes: int = 64,
        exact_only: bool = True,
    ):
        if eviction not in ("lru", "cost"):
            raise ValueError("model replays eviction for 'lru' and 'cost' only")
        self.replication = replication
        self.capacity = capacity_per_node
        self.eviction = eviction
        self.exact_only = exact_only
        self.ring = HashRing(vnodes)
        self.nodes: Dict[str, Dict[str, Any]] = {}
        self.hits: Dict[str, Dict[str, int]] = {}
        self.order: Dict[str, List[str]] = {}  # LRU recency, oldest first
        self.seq: Dict[str, Dict[str, int]] = {}  # stable dict-order mirror
        self._next_seq = 0
        self.crashed: set = set()
        self.evictions = 0

    # -- membership ----------------------------------------------------------

    def add_node(self, name: str) -> None:
        if name in self.nodes:
            return
        self.nodes[name] = {}
        self.hits[name] = {}
        self.order[name] = []
        self.seq[name] = {}
        self.ring.add(name)

    def crash(self, name: str) -> None:
        self.crashed.add(name)

    def restore(self, name: str) -> None:
        self.crashed.discard(name)

    def restart(self, name: str, *, recover: bool = True) -> None:
        """Mirror of ``restart_node``: data gone; read-repair from peers."""
        self.crashed.discard(name)
        self.nodes[name] = {}
        self.hits[name] = {}
        self.order[name] = []
        self.seq[name] = {}
        if not recover:
            return
        for peer in sorted(self.nodes):
            # an unreachable peer cannot donate repair data (the facade's
            # repair scan goes through the interceptor seam and fails)
            if peer == name or peer in self.crashed:
                continue
            for kw, v in self.nodes[peer].items():
                if kw in self.nodes[name]:
                    continue
                if name in self.ring.nodes_for(kw, self.replication):
                    self._apply(name, kw, v)
        self._evict(name)

    # -- write path ----------------------------------------------------------

    def _apply(self, node: str, kw: str, value: Any) -> None:
        store = self.nodes[node]
        if kw not in self.seq[node]:
            self._next_seq += 1
            self.seq[node][kw] = self._next_seq
        store[kw] = value
        self.hits[node][kw] = 0  # re-insert resets live-hit accounting
        if kw in self.order[node]:
            self.order[node].remove(kw)
        self.order[node].append(kw)

    def _victim(self, node: str) -> str:
        if self.eviction == "lru":
            return self.order[node][0]
        # cost: min (1 + hits) * tokens_saved(=1 for dict values), ties by
        # dict order (mirrors CacheEntry.inserted_at ties within a wave)
        return min(
            self.nodes[node],
            key=lambda k: (1 + self.hits[node][k], self.seq[node][k]),
        )

    def _evict(self, node: str) -> None:
        while len(self.nodes[node]) > self.capacity:
            victim = self._victim(node)
            del self.nodes[node][victim]
            del self.hits[node][victim]
            self.order[node].remove(victim)
            self.evictions += 1

    def _live_owners(self, kw: str) -> List[str]:
        # NOTE: the sim injects failures at the RPC layer (crashed), never
        # via mark_down — a membership-churn fault plan would add that
        # mirror here (see ROADMAP)
        return [
            n for n in self.ring.nodes_for(kw, self.replication)
            if n in self.nodes
        ]

    def insert_wave(self, items: Sequence[Tuple[str, Any]]) -> None:
        """Spec semantics: the wave lands on every live owner (crashed
        owners drop their copy — the RPC fails), grouped per node with
        eviction AFTER each node's sub-wave (primary groups first, then
        replica groups, mirroring the facade's ack structure)."""
        for rank0 in (True, False):
            groups: Dict[str, List[Tuple[str, Any]]] = {}
            for kw, v in items:
                owners = self._live_owners(kw)
                for rank, n in enumerate(owners):
                    if (rank == 0) == rank0:
                        groups.setdefault(n, []).append((kw, v))
            for n, sub in groups.items():
                if n in self.crashed:
                    continue  # write RPC failed; remaining owners hold it
                for kw, v in sub:
                    self._apply(n, kw, v)
                self._evict(n)

    def remove(self, kw: str) -> None:
        for n in self.nodes:
            if n in self.crashed:
                continue  # unreachable; its stale copy dies at restart
            if kw in self.nodes[n]:
                del self.nodes[n][kw]
                del self.hits[n][kw]
                self.order[n].remove(kw)

    # -- read path -----------------------------------------------------------

    def lookup(self, kw: str) -> Tuple[Optional[Any], bool]:
        """(expected value or None, strict) — strict=False means the real
        store may legitimately answer differently (fuzzy resolution of a
        key the model cannot predict); the result is then only
        integrity-checked."""
        for n in self._live_owners(kw):
            if n in self.crashed:
                continue  # guard spec: reader falls through to next tier
            v = self.nodes[n].get(kw)
            if v is not None:
                self.hits[n][kw] += 1
                if kw in self.order[n]:
                    self.order[n].remove(kw)
                    self.order[n].append(kw)
                return v, True
        return None, self.exact_only

    def keys(self) -> List[str]:
        seen: set = set()
        for store in self.nodes.values():
            seen.update(store)
        return sorted(seen)


__all__ = ["ModelStore", "Violation", "checksum", "make_value", "value_torn"]

"""CLI for the deterministic simulation harness.

Modes:

* single run — ``python -m repro.sim --seed 7 --fault crash_restart``:
  runs once, reruns to verify determinism, prints the trace hash and any
  oracle violations (exit 1 on violation or hash mismatch);
* matrix — ``python -m repro.sim --check --seeds 5``: the CI gate. Runs
  every (seed × scenario × fault-plan) cell with guards ON (must be
  clean + deterministic) and, with ``--ablation-audit`` (default on for
  ``--check``), re-runs each fault plan with its guard ablated and
  requires the matching oracle to FIRE — proving the oracles have teeth;
* replay — ``python -m repro.sim --replay FILE``: re-executes a dumped
  failure seed and verifies the trace hash reproduces bit-for-bit.

On any red cell a replayable repro JSON is dumped under ``--dump-dir``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import List

from repro.envs.workloads import SIM_SCENARIOS
from repro.sim.faults import (
    ABLATION_OF,
    ALL_ABLATIONS,
    EXTRA_PLAN_ABLATIONS,
    FAULT_PLANS,
    SCENARIO_ABLATION_OF,
)
from repro.sim.harness import SimConfig, run_sim
from repro.sim.trace import TraceRecorder


def _fail_dump(report, dump_dir: str, tag: str) -> str:
    """Write a self-contained, replayable failure seed (CI artifact)."""
    path = os.path.join(dump_dir, f"sim-repro-{tag}.json")
    payload = {
        "config": dataclasses.asdict(report.config),
        "trace_hash": report.trace_hash,
        "span_digest": report.span_digest,
        "violations": [dataclasses.asdict(v) for v in report.violations],
        "store_stats": report.store_stats,
        "router_metrics": report.router_metrics,
        "trace_tail": report.trace_tail,  # event log for post-mortems
        "how_to_replay": "PYTHONPATH=src python -m repro.sim --replay <this file>",
    }
    os.makedirs(dump_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=repr)
        f.write("\n")
    return path


def _run_once(cfg: SimConfig, *, verify_determinism: bool = True):
    """Run once; on verify, rerun and return (trace_hash, span_digest) —
    both must match for the cell to count as deterministic."""
    report = run_sim(cfg)
    rerun = None
    if verify_determinism:
        r2 = run_sim(cfg)
        rerun = (r2.trace_hash, r2.span_digest)
    return report, rerun


def cmd_single(args) -> int:
    cfg = SimConfig(
        seed=args.seed, scenario=args.scenario, fault=args.fault,
        n_ops=args.ops, ablate=tuple(args.ablate.split(",")) if args.ablate else (),
    )
    report, rerun = _run_once(cfg)
    print(f"seed={args.seed} scenario={report.config.scenario} "
          f"fault={report.config.fault} ablate={report.config.ablate or '-'}")
    print(f"steps={report.steps} ops={report.ops_applied} "
          f"lookups={report.lookups} inserts={report.inserts}")
    print(f"trace_hash={report.trace_hash}")
    print(f"span_digest={report.span_digest} spans={report.n_spans}")
    print(f"store_stats={json.dumps(report.store_stats, sort_keys=True)}")
    if report.router_metrics:
        print(f"router={json.dumps(report.router_metrics, sort_keys=True)}")
    ok = True
    if rerun is not None and rerun != (report.trace_hash, report.span_digest):
        print(f"NONDETERMINISTIC: rerun {rerun} != "
              f"{(report.trace_hash, report.span_digest)}")
        ok = False
    for v in report.violations:
        print(f"VIOLATION step={v.step} oracle={v.oracle}: {v.detail}")
    if report.violations:
        ok = False
    if not ok:
        path = _fail_dump(report, args.dump_dir,
                          f"s{args.seed}-{report.config.scenario}-"
                          f"{report.config.fault}")
        print(f"repro dumped: {path}")
    print("OK" if ok else "RED")
    return 0 if ok else 1


def cmd_check(args) -> int:
    """CI matrix: seeds x scenarios x fault plans, guards on + ablation audit."""
    red: List[str] = []
    cells = 0
    # plans that pin their own scenario (SimConfig.normalized) run once
    # per seed under it; other scenario pairings would be duplicate cells
    pinned = {"mid_wave_evict": "evict_then_hit",
              "cold_tier": "evict_then_hit",
              "ttl_churn": "skewed_reuse",
              "speculative_exec": "paraphrase_burst"}
    for seed in range(args.seeds):
        for scenario in SIM_SCENARIOS:
            for fault in FAULT_PLANS:
                if fault in pinned and scenario != pinned[fault]:
                    continue  # plan pins its scenario; skip duplicate cells
                cfg = SimConfig(seed=seed, scenario=scenario, fault=fault,
                                n_ops=args.ops)
                cells += 1
                report, rerun = _run_once(cfg)
                tag = f"s{seed}-{scenario}-{fault}"
                if report.violations:
                    red.append(f"{tag}: {report.violations[0].oracle}: "
                               f"{report.violations[0].detail}")
                    _fail_dump(report, args.dump_dir, tag)
                elif rerun != (report.trace_hash, report.span_digest):
                    red.append(f"{tag}: nondeterministic trace/span stream")
                    _fail_dump(report, args.dump_dir, tag)
        if args.ablation_audit:
            # fault-plan guards, plus the scenario-tied guards (e.g. the
            # fuzzy scatter, audited under paraphrase traffic with no
            # fault plan): every ablated guard must trip its oracle
            audit_cells = [
                SimConfig(seed=seed, fault=fault, n_ops=args.ops,
                          ablate=(guard,))
                for fault, guard in sorted(ABLATION_OF.items())
            ] + [
                # replication=1: scenario guards (fuzzy scatter) are
                # load-bearing exactly when a key has no replica tier to
                # hide behind, so that is where their loss must show
                SimConfig(seed=seed, scenario=scenario, n_ops=args.ops,
                          replication=1, ablate=(guard,))
                for scenario, guard in sorted(SCENARIO_ABLATION_OF.items())
            ] + [
                # plans guarding MORE than one invariant audit each extra
                # guard in its own cell (e.g. speculative_exec's
                # verify-timeout fallback, whose loss must trip the
                # spec_liveness oracle rather than spec_rollback's
                # spec_leak)
                SimConfig(seed=seed, fault=fault, n_ops=args.ops,
                          ablate=(guard,))
                for fault, guard in sorted(EXTRA_PLAN_ABLATIONS.items())
            ]
            for cfg in audit_cells:
                cells += 1
                report = run_sim(cfg)
                tag = f"s{seed}-ablate-{cfg.ablate[0]}"
                if not report.violations:
                    red.append(f"{tag}: guard ablated but NO oracle fired "
                               "(the sim lost its teeth)")
                    _fail_dump(report, args.dump_dir, tag)
    print(f"sim-check: {cells} cells, {len(red)} red")
    for r in red:
        print(f"RED {r}")
    return 1 if red else 0


def cmd_replay(args) -> int:
    payload = TraceRecorder.load_repro(args.replay)
    cfg_d = dict(payload["config"])
    cfg_d["ablate"] = tuple(cfg_d.get("ablate", ()))
    cfg = SimConfig(**cfg_d)
    report = run_sim(cfg)
    want = payload["trace_hash"]
    print(f"replayed {args.replay}: trace_hash={report.trace_hash} "
          f"(recorded {want})")
    for v in report.violations:
        print(f"VIOLATION step={v.step} oracle={v.oracle}: {v.detail}")
    if report.trace_hash != want:
        print("REPLAY DIVERGED")
        return 1
    print("replay reproduced the recorded interleaving exactly")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="Deterministic fault-injection simulation of the "
                    "distributed plan cache (see repro.sim docs).",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default="skewed_reuse",
                    choices=list(SIM_SCENARIOS))
    ap.add_argument("--fault", default="none", choices=list(FAULT_PLANS))
    ap.add_argument("--ops", type=int, default=60,
                    help="ops per simulated client (4 clients)")
    ap.add_argument("--ablate", default="",
                    help="comma-joined guard ablations "
                         f"({list(ALL_ABLATIONS)})")
    ap.add_argument("--check", action="store_true",
                    help="run the seeds x scenarios x faults CI matrix")
    ap.add_argument("--seeds", type=int, default=5,
                    help="seed count for --check")
    ap.add_argument("--no-ablation-audit", dest="ablation_audit",
                    action="store_false",
                    help="skip the guard-ablation oracle audit in --check")
    ap.add_argument("--replay", default="",
                    help="replay a dumped sim-repro JSON file")
    ap.add_argument("--dump-dir", default="sim-repro",
                    help="where failure repro seeds are written")
    args = ap.parse_args(argv)
    if args.replay:
        return cmd_replay(args)
    if args.check:
        return cmd_check(args)
    return cmd_single(args)


if __name__ == "__main__":
    sys.exit(main())

"""Fault plans + the shard-call fault interceptor.

Faults are scheduled through :class:`repro.distributed.fault.FaultSchedule`
— the same ``inject(step, kind, **details)`` path the training-side
``FaultTolerantRunner`` uses — and fire at their step inside the scheduler
loop. The four built-in plans each target one guard in the serving /
distributed layers; ablating that guard (``SimConfig.ablate``) must make
an oracle fire, which is how the sim proves its oracles have teeth:

========================  ==========================================  ===========================
plan                      guard under test                            ablation key
========================  ==========================================  ===========================
``crash_restart``         lookup fallthrough past an unreachable      ``crash_fallthrough``
                          shard + ``restart_node`` read-repair
``replica_lag``           synchronous replica acks                    ``replica_ack``
                          (``ack_policy="all"``)
``hedge_timeout``         hedged-dispatch failover in ``TierPool``    ``hedge_failover``
``mid_wave_evict``        evict-AFTER-admission-wave in ``PlanCache``  ``evict_after_wave``
========================  ==========================================  ===========================
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

from repro.core.distributed_cache import ShardUnavailable
from repro.distributed.fault import FaultSchedule
from repro.sim.clock import VirtualClock
from repro.sim.scheduler import StepScheduler

FAULT_PLANS = ("none", "crash_restart", "replica_lag", "hedge_timeout",
               "mid_wave_evict")

# guard-ablation keys, by the plan whose oracle they trip
ABLATION_OF = {
    "crash_restart": "crash_fallthrough",
    "replica_lag": "replica_ack",
    "hedge_timeout": "hedge_failover",
    "mid_wave_evict": "evict_after_wave",
}


class SimInterceptor:
    """Installed as ``DistributedPlanCache.interceptor``: the RPC layer of
    the simulated cluster. Crashed nodes raise :class:`ShardUnavailable`
    at call time (the facade has NOT been told via ``mark_down`` — crash
    discovery happens exactly where it would in production, at dispatch).
    ``defer`` models replica lag: the write applies ``lag_steps`` scheduler
    steps later, unless the node crashes first."""

    def __init__(
        self,
        scheduler: StepScheduler,
        clock: VirtualClock,
        *,
        call_latency_s: float = 2e-4,
        on_deferred: Optional[Callable[[str], None]] = None,
    ):
        self.scheduler = scheduler
        self.clock = clock
        self.call_latency_s = call_latency_s
        self.on_deferred = on_deferred
        self.crashed: Set[str] = set()
        self.lag_steps = 0
        self.calls = 0
        self.failed_calls = 0
        self.deferred_writes = 0

    # -- DistributedPlanCache seam ------------------------------------------

    def call(self, node: str, op: str, fn: Callable[[], object]) -> object:
        self.calls += 1
        self.clock.advance(self.call_latency_s)
        if node in self.crashed:
            self.failed_calls += 1
            raise ShardUnavailable(f"{node} unreachable ({op})")
        return fn()

    def defer(self, node: str, fn: Callable[[], None]) -> None:
        """Replica-lag channel (used only under the ``replica_ack``
        ablation): apply the write after ``lag_steps`` steps."""
        self.deferred_writes += 1

        def apply() -> None:
            if node in self.crashed:
                return  # the lagged write dies with the crashed node
            fn()
            if self.on_deferred is not None:
                self.on_deferred(node)

        self.scheduler.defer(max(1, self.lag_steps), apply)

    # -- fault-plan state ----------------------------------------------------

    def crash(self, node: str) -> None:
        self.crashed.add(node)

    def restore(self, node: str) -> None:
        self.crashed.discard(node)


class EngineFaultState:
    """Hedge-timeout fault state shared with the sim's fake tier engines:
    while ``budget > 0``, the named engine raises ``TimeoutError`` (one
    budget unit per raised call)."""

    def __init__(self) -> None:
        self.timeout_engine: Optional[str] = None
        self.budget = 0

    def arm(self, engine: str, calls: int) -> None:
        self.timeout_engine = engine
        self.budget = calls

    def should_timeout(self, engine: str) -> bool:
        if self.budget > 0 and engine == self.timeout_engine:
            self.budget -= 1
            return True
        return False


def build_fault_schedule(plan: str, n_steps: int, *, node: str = "cache-1",
                         lag_steps: int = 6) -> FaultSchedule:
    """Materialize a named plan into step-indexed fault events.

    Events (consumed by the harness's ``on_fault``):
      * ``crash``/``restart``  — node lifecycle (two cycles per run);
      * ``lag``                — set the interceptor's replica lag;
      * ``hedge_timeout``      — arm the large-tier engine timeout;
      * ``evict_pressure``     — marker only: the mid-wave plan does its
        damage through config (tiny capacity + flood waves), not events.
    """
    if plan not in FAULT_PLANS:
        raise ValueError(f"unknown fault plan {plan!r}; one of {FAULT_PLANS}")
    sched = FaultSchedule()
    if plan == "none":
        return sched
    q = max(8, n_steps // 4)
    if plan == "crash_restart":
        sched.inject(q, "crash", node=node)
        sched.inject(2 * q, "restart", node=node, recover=True)
        sched.inject(2 * q + q // 2, "crash", node=node)
        sched.inject(3 * q + q // 2, "restart", node=node, recover=True)
    elif plan == "replica_lag":
        sched.inject(2, "lag", steps=lag_steps)
        # crash a node mid-lag: readers must fall through to replicas that
        # (under the sync-ack guard) already hold the acked versions
        sched.inject(q, "crash", node=node)
        sched.inject(3 * q, "restart", node=node, recover=True)
    elif plan == "hedge_timeout":
        sched.inject(q, "hedge_timeout", engine="large-0", calls=8)
        sched.inject(3 * q, "hedge_timeout", engine="large-0", calls=8)
    elif plan == "mid_wave_evict":
        sched.inject(q, "evict_pressure")
    return sched


__all__ = [
    "ABLATION_OF",
    "EngineFaultState",
    "FAULT_PLANS",
    "SimInterceptor",
    "build_fault_schedule",
]

"""Fault plans + the shard-call fault interceptor + the sim cachegen pool.

Faults are scheduled through :class:`repro.distributed.fault.FaultSchedule`
— the same ``inject(step, kind, **details)`` path the training-side
``FaultTolerantRunner`` uses — and fire at their step inside the scheduler
loop. The built-in plans each target one guard in the serving /
distributed layers; ablating that guard (``SimConfig.ablate``) must make
an oracle fire, which is how the sim proves its oracles have teeth:

========================  ==========================================  ===========================
plan                      guard under test                            ablation key
========================  ==========================================  ===========================
``crash_restart``         lookup fallthrough past an unreachable      ``crash_fallthrough``
                          shard + ``restart_node`` read-repair
``replica_lag``           synchronous replica acks                    ``replica_ack``
                          (``ack_policy="all"``)
``hedge_timeout``         hedged-dispatch failover in ``TierPool``    ``hedge_failover``
``mid_wave_evict``        evict-AFTER-admission-wave in ``PlanCache``  ``evict_after_wave``
``membership_churn``      ring changes re-home data (``add_node``     ``churn_rehome``
                          rebalances, ``remove_node`` drains)
``async_cachegen``        rejected-submission sync fallback in        ``cachegen_fallback``
                          ``TwoTierRouter`` (no dropped waves)
``cold_tier``             manifest-refcounted cold-segment gc in      ``cold_gc_refcount``
                          ``ColdTier`` (age rotation must never
                          delete a segment with live entries)
``ttl_churn``             expire-on-touch in ``PlanCache._get_live``  ``ttl_expiry``
                          (an expired entry must never be served)
``speculative_exec``      journal rollback on a failed speculation    ``spec_rollback``
                          (``PlanSpeculator.resolve`` must undo every
                          journaled effect when the verifier
                          disagrees); the plan ALSO audits the
                          verify-timeout fallback under
                          ``spec_verify_timeout`` (see
                          ``EXTRA_PLAN_ABLATIONS``)
========================  ==========================================  ===========================

One guard is tied to a *scenario* rather than a fault plan: the fuzzy
scatter in ``DistributedPlanCache._probe_order`` (a similar key hashes to
its own owners, so fuzzy reads must reach every shard). Its ablation key
is ``fuzzy_scatter`` and the ``paraphrase_burst`` scenario's
similarity-aware oracle catches it (``SCENARIO_ABLATION_OF``).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Set

from repro.core.distributed_cache import ShardUnavailable
from repro.distributed.fault import FaultSchedule
from repro.sim.clock import VirtualClock
from repro.sim.scheduler import StepScheduler

FAULT_PLANS = ("none", "crash_restart", "replica_lag", "hedge_timeout",
               "mid_wave_evict", "membership_churn", "async_cachegen",
               "cold_tier", "ttl_churn", "speculative_exec")

# guard-ablation keys, by the plan whose oracle they trip
ABLATION_OF = {
    "crash_restart": "crash_fallthrough",
    "replica_lag": "replica_ack",
    "hedge_timeout": "hedge_failover",
    "mid_wave_evict": "evict_after_wave",
    "membership_churn": "churn_rehome",
    "async_cachegen": "cachegen_fallback",
    "cold_tier": "cold_gc_refcount",
    "ttl_churn": "ttl_expiry",
    "speculative_exec": "spec_rollback",
}

# guard-ablation keys tripped by a traffic scenario instead of a fault plan
SCENARIO_ABLATION_OF = {
    "paraphrase_burst": "fuzzy_scatter",
}

# second-guard audits: plans that protect MORE than one guard get extra
# audit cells beyond ABLATION_OF (one fault plan, a different ablation
# key, a different oracle expected to fire). Pure literal — check_docs
# reads it via the AST.
EXTRA_PLAN_ABLATIONS = {
    "speculative_exec": "spec_verify_timeout",
}

ALL_ABLATIONS = tuple(sorted(
    set(ABLATION_OF.values()) | set(SCENARIO_ABLATION_OF.values())
    | set(EXTRA_PLAN_ABLATIONS.values())
))


class SimInterceptor:
    """Installed as ``DistributedPlanCache.interceptor``: the RPC layer of
    the simulated cluster. Crashed nodes raise :class:`ShardUnavailable`
    at call time (the facade has NOT been told via ``mark_down`` — crash
    discovery happens exactly where it would in production, at dispatch).
    ``defer`` models replica lag: the write applies ``lag_steps`` scheduler
    steps later, unless the node crashes first."""

    def __init__(
        self,
        scheduler: StepScheduler,
        clock: VirtualClock,
        *,
        call_latency_s: float = 2e-4,
        on_deferred: Optional[Callable[[str], None]] = None,
    ):
        self.scheduler = scheduler
        self.clock = clock
        self.call_latency_s = call_latency_s
        self.on_deferred = on_deferred
        self.crashed: Set[str] = set()
        self.lag_steps = 0
        self.calls = 0
        self.failed_calls = 0
        self.deferred_writes = 0

    # -- DistributedPlanCache seam ------------------------------------------

    def call(self, node: str, op: str, fn: Callable[[], object]) -> object:
        self.calls += 1
        self.clock.advance(self.call_latency_s)
        if node in self.crashed:
            self.failed_calls += 1
            raise ShardUnavailable(f"{node} unreachable ({op})")
        return fn()

    def defer(self, node: str, fn: Callable[[], None]) -> None:
        """Replica-lag channel (used only under the ``replica_ack``
        ablation): apply the write after ``lag_steps`` steps."""
        self.deferred_writes += 1

        def apply() -> None:
            if node in self.crashed:
                return  # the lagged write dies with the crashed node
            fn()
            if self.on_deferred is not None:
                self.on_deferred(node)

        self.scheduler.defer(max(1, self.lag_steps), apply)

    # -- fault-plan state ----------------------------------------------------

    def crash(self, node: str) -> None:
        self.crashed.add(node)

    def restore(self, node: str) -> None:
        self.crashed.discard(node)


class SimCachegenFuture:
    """Future-compatible handle for a scheduler-driven cachegen task."""

    def __init__(self) -> None:
        self._done = False
        self._result: Any = None

    def set_result(self, value: Any) -> None:
        self._done = True
        self._result = value

    def done(self) -> bool:
        return self._done

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done:
            # the scheduler runs every queued worker op before quiescence,
            # so an unresolved future at drain() time is a harness bug
            raise RuntimeError("sim cachegen task never ran")
        return self._result

    def cancel(self) -> bool:
        return False


class SimCachegenPool:
    """The router's async cache-generation worker pool, as sim clients.

    Injected as ``TwoTierRouter(cachegen_pool=...)``: instead of a
    ThreadPoolExecutor, ``submit`` appends a ``{"op": "cachegen"}`` task to
    one of N pre-registered worker clients (round-robin) on the step
    scheduler — so the seeded scheduler, not a thread race, decides when a
    distilled admission wave lands relative to concurrent lookups, inserts
    and removals. That is exactly the §4.3 admission race the paper defers.

    ``arm_saturation(calls)`` makes the next ``calls`` submissions raise
    (an injected "pool saturated" rejection): the router's guarded response
    is the synchronous fallback; with ``cachegen_fallback`` ablated the
    wave is dropped, which the harness's ``cachegen_loss`` oracle catches.
    """

    def __init__(
        self,
        scheduler: StepScheduler,
        clock: VirtualClock,
        *,
        workers: int = 2,
        submit_latency_s: float = 1e-4,
    ):
        self.scheduler = scheduler
        self.clock = clock
        self.submit_latency_s = submit_latency_s
        self.worker_names = [f"cachegen-{i}" for i in range(workers)]
        for name in self.worker_names:
            scheduler.add_client(name, [])
        self._rr = 0
        self.saturate_budget = 0
        self.submitted = 0
        self.rejected = 0

    def arm_saturation(self, calls: int) -> None:
        self.saturate_budget = calls

    def submit(self, fn: Callable[[], Any]) -> SimCachegenFuture:
        self.clock.advance(self.submit_latency_s)
        if self.saturate_budget > 0:
            self.saturate_budget -= 1
            self.rejected += 1
            raise RuntimeError("cachegen pool saturated (injected fault)")
        fut = SimCachegenFuture()
        worker = self.worker_names[self._rr % len(self.worker_names)]
        self._rr += 1
        self.scheduler.extend_client(
            worker, [{"op": "cachegen", "fn": fn, "future": fut}]
        )
        self.submitted += 1
        return fut


class EngineFaultState:
    """Hedge-timeout fault state shared with the sim's fake tier engines:
    while ``budget > 0``, the named engine raises ``TimeoutError`` (one
    budget unit per raised call)."""

    def __init__(self) -> None:
        self.timeout_engine: Optional[str] = None
        self.budget = 0

    def arm(self, engine: str, calls: int) -> None:
        self.timeout_engine = engine
        self.budget = calls

    def should_timeout(self, engine: str) -> bool:
        if self.budget > 0 and engine == self.timeout_engine:
            self.budget -= 1
            return True
        return False


def build_fault_schedule(plan: str, n_steps: int, *, node: str = "cache-1",
                         lag_steps: int = 6) -> FaultSchedule:
    """Materialize a named plan into step-indexed fault events.

    Events (consumed by the harness's ``on_fault``):
      * ``crash``/``restart``  — node lifecycle (two cycles per run);
      * ``lag``                — set the interceptor's replica lag;
      * ``hedge_timeout``      — arm the large-tier engine timeout;
      * ``evict_pressure``     — marker only: the mid-wave plan does its
        damage through config (tiny capacity + flood waves), not events;
      * ``join``/``drain``     — elastic membership: ``add_node`` a fresh
        node mid-wave / gracefully ``remove_node`` one, racing the client
        traffic (``membership_churn``);
      * ``pool_saturate``      — arm N rejected cachegen submissions on
        the sim worker pool (``async_cachegen``);
      * ``cold_crash``         — arm N spill-wave crashes between segment
        write and manifest commit on store AND model (``cold_tier``): the
        entries are lost on both sides, deterministically, proving the
        two-phase spill ordering is mirrored;
      * ``ttl_pressure``       — marker only: the ttl plan does its damage
        through config (short ``ttl_s`` against skewed reuse gaps).
    """
    if plan not in FAULT_PLANS:
        raise ValueError(f"unknown fault plan {plan!r}; one of {FAULT_PLANS}")
    sched = FaultSchedule()
    if plan == "none":
        return sched
    q = max(8, n_steps // 4)
    if plan == "crash_restart":
        sched.inject(q, "crash", node=node)
        sched.inject(2 * q, "restart", node=node, recover=True)
        sched.inject(2 * q + q // 2, "crash", node=node)
        sched.inject(3 * q + q // 2, "restart", node=node, recover=True)
    elif plan == "replica_lag":
        sched.inject(2, "lag", steps=lag_steps)
        # crash a node mid-lag: readers must fall through to replicas that
        # (under the sync-ack guard) already hold the acked versions
        sched.inject(q, "crash", node=node)
        sched.inject(3 * q, "restart", node=node, recover=True)
    elif plan == "hedge_timeout":
        sched.inject(q, "hedge_timeout", engine="large-0", calls=8)
        sched.inject(3 * q, "hedge_timeout", engine="large-0", calls=8)
    elif plan == "mid_wave_evict":
        sched.inject(q, "evict_pressure")
    elif plan == "membership_churn":
        # join mid-wave, graceful drain racing lookups, a crash held open
        # across a join (rebalance with an unreachable shard), a restart
        # whose read-repair runs against the post-churn ring, and a drain
        # of the earlier joiner — every ring change mirrored by the model
        sched.inject(q // 2, "join", node="cache-join-0")
        sched.inject(q, "drain", node=node)
        sched.inject(2 * q, "crash", node="cache-2")
        sched.inject(2 * q + 2, "join", node="cache-join-1")
        sched.inject(3 * q, "restart", node="cache-2", recover=True)
        sched.inject(3 * q + q // 2, "drain", node="cache-join-0")
    elif plan == "async_cachegen":
        # two bursts of rejected cachegen submissions: the guarded router
        # falls back to synchronous generation; the ablated router drops
        # the distilled waves (cachegen_loss oracle)
        sched.inject(q, "pool_saturate", calls=6)
        sched.inject(3 * q, "pool_saturate", calls=6)
    elif plan == "cold_tier":
        # lose one spill wave mid-run and one late: a crash between the
        # segment write and the manifest commit must lose the wave WHOLE
        # (no template both lost and unevicted) on store and model alike
        sched.inject(q, "cold_crash", calls=1)
        sched.inject(3 * q, "cold_crash", calls=1)
    elif plan == "ttl_churn":
        sched.inject(q, "ttl_pressure")
    elif plan == "speculative_exec":
        # three bursts of rejected pool submissions: near-hit verify tasks
        # share the cachegen pool, so some rejections hit verifies — the
        # guarded router verifies synchronously (spec_sync_verifies); the
        # spec_verify_timeout ablation drops them, leaving speculations
        # pending forever (spec_liveness oracle). Bursts are wide enough
        # that every seed rejects at least one verify submission.
        sched.inject(q // 2, "pool_saturate", calls=10)
        sched.inject(2 * q, "pool_saturate", calls=10)
        sched.inject(3 * q, "pool_saturate", calls=10)
    return sched


__all__ = [
    "ABLATION_OF",
    "ALL_ABLATIONS",
    "EXTRA_PLAN_ABLATIONS",
    "EngineFaultState",
    "FAULT_PLANS",
    "SCENARIO_ABLATION_OF",
    "SimCachegenFuture",
    "SimCachegenPool",
    "SimInterceptor",
    "build_fault_schedule",
]

"""``repro.sim`` — deterministic simulation of the distributed plan cache.

FoundationDB-style verification for the serving/distributed layers: a
seeded virtual clock + step scheduler drive concurrent
``lookup_batch``/``insert_batch``/``remove``/``autotune`` (and router
``route_batch``) traffic against ``DistributedPlanCache`` /
``TwoTierRouter`` under injected faults — shard crash/restart, replica
lag, hedged-dispatch timeouts, mid-wave eviction — and every run is
checked against a sequential model-store oracle. A failing run dumps a
replayable seed file.

Entry points::

    python -m repro.sim --seed 7 --scenario skewed_reuse --fault crash_restart
    python -m repro.sim --check --seeds 5          # CI matrix (make sim-check)
    python -m repro.sim --replay sim-repro/failure.json

Library use::

    from repro.sim import SimConfig, run_sim
    report = run_sim(SimConfig(seed=7, fault="replica_lag"))
    assert report.ok and report.trace_hash == run_sim(...).trace_hash
"""

from repro.sim.clock import VirtualClock
from repro.sim.faults import ABLATION_OF, FAULT_PLANS, SimInterceptor
from repro.sim.harness import SimConfig, SimReport, run_sim
from repro.sim.oracle import ModelStore, Violation, make_value, value_torn
from repro.sim.scheduler import StepScheduler
from repro.sim.trace import TraceRecorder

__all__ = [
    "ABLATION_OF",
    "FAULT_PLANS",
    "ModelStore",
    "SimConfig",
    "SimInterceptor",
    "SimReport",
    "StepScheduler",
    "TraceRecorder",
    "VirtualClock",
    "Violation",
    "make_value",
    "run_sim",
    "value_torn",
]

"""``repro.sim`` — deterministic simulation of the distributed plan cache.

FoundationDB-style verification for the serving/distributed layers: a
seeded virtual clock + step scheduler drive concurrent
``lookup_batch``/``insert_batch``/``remove``/``autotune`` and
control-plane ``keys``/``len`` traffic (and router ``route_batch``, with
async cache-generation workers modeled as scheduler clients) against
``DistributedPlanCache`` / ``TwoTierRouter`` under injected faults —
shard crash/restart, elastic membership churn (join/drain), replica lag,
hedged-dispatch timeouts, rejected cachegen submissions, mid-wave
eviction — and every run is checked against a sequential model-store
oracle (similarity-aware in fuzzy mode, so paraphrase resolution is
verified strictly). A failing run dumps a replayable seed file.

Entry points::

    python -m repro.sim --seed 7 --scenario skewed_reuse --fault crash_restart
    python -m repro.sim --check --seeds 5          # CI matrix (make sim-check)
    python -m repro.sim --replay sim-repro/failure.json

Library use::

    from repro.sim import SimConfig, run_sim
    report = run_sim(SimConfig(seed=7, fault="membership_churn"))
    assert report.ok and report.trace_hash == run_sim(...).trace_hash

The operator's handbook (seed/replay workflow, fault-plan catalog, oracle
guarantees, reading a red run) lives in ``docs/simulation.md``.
"""

from repro.sim.clock import VirtualClock
from repro.sim.faults import (
    ABLATION_OF,
    ALL_ABLATIONS,
    EXTRA_PLAN_ABLATIONS,
    FAULT_PLANS,
    SCENARIO_ABLATION_OF,
    SimCachegenPool,
    SimInterceptor,
)
from repro.sim.harness import SimConfig, SimReport, run_sim
from repro.sim.oracle import ModelStore, Violation, make_value, value_torn
from repro.sim.scheduler import StepScheduler
from repro.sim.trace import TraceRecorder

__all__ = [
    "ABLATION_OF",
    "ALL_ABLATIONS",
    "EXTRA_PLAN_ABLATIONS",
    "FAULT_PLANS",
    "ModelStore",
    "SCENARIO_ABLATION_OF",
    "SimCachegenPool",
    "SimConfig",
    "SimInterceptor",
    "SimReport",
    "StepScheduler",
    "TraceRecorder",
    "VirtualClock",
    "Violation",
    "make_value",
    "run_sim",
    "value_torn",
]

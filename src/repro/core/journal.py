"""Step-level undo/commit journal for speculative plan execution.

Speculation (ISSUE §4.3 latency hiding / AgenticCache reconciliation)
executes an adapted cached plan *before* the planner has confirmed it.
Every tool/env effect of a speculative step must therefore be either

* **applied eagerly with a compensation** — env writes go through the
  :class:`repro.envs.base.Workspace` compensating-write protocol, whose
  ``write()``/``delete()`` return the undo closure the journal keeps; or
* **deferred until commit** — cache admissions and metric increments run
  only when the verifier agrees, so a rolled-back step can never leak a
  template into the store or a count into the metrics registry. Deferred
  admissions capture their ``unless_written_since`` token at *record*
  time, so a commit that lands late can never clobber a newer write.

The journal is strictly step-ordered: ``commit(n)`` finalizes the prefix
(deferred actions run in record order), ``rollback(from_step)`` unwinds
the suffix (compensations run in reverse record order), and
``patch(keep)`` is the splice the speculative agent loop uses — keep the
executed prefix that matches the verified plan, unwind the divergent
tail, then continue recording the re-executed suffix in the same
journal. Single-owner by design: one journal per speculation, driven
from one logical thread (under the sim, one scheduler client), so it
takes no lock.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List


@dataclass
class JournalStep:
    """One speculative step: eager undos + deferred commit actions."""

    index: int
    undos: List[Callable[[], None]] = field(default_factory=list)
    deferred: List[Callable[[], None]] = field(default_factory=list)
    label: str = ""

    def applied(self, undo: Callable[[], None]) -> None:
        """Record an eagerly-applied effect via its compensation closure."""
        self.undos.append(undo)

    def on_commit(self, action: Callable[[], None]) -> None:
        """Defer an effect (cache admission, metric bump) until commit."""
        self.deferred.append(action)


class StepJournal:
    """Ordered journal of reversible steps with prefix-commit semantics.

    State machine per step: *open* -> committed (prefix-only) or rolled
    back (suffix-only). ``open_steps()`` is the liveness surface the sim
    oracle checks at quiescence: a speculation whose verify never
    resolved leaves its steps open.
    """

    def __init__(self) -> None:
        self._steps: List[JournalStep] = []
        self._committed = 0  # steps [0, _committed) are final
        self.steps_recorded = 0
        self.steps_committed = 0
        self.steps_rolled_back = 0

    # -- recording ----------------------------------------------------

    def begin_step(self, label: str = "") -> JournalStep:
        step = JournalStep(index=self._committed + len(self._open()), label=label)
        self._steps.append(step)
        self.steps_recorded += 1
        return step

    def _open(self) -> List[JournalStep]:
        return self._steps[self._committed:]

    def open_steps(self) -> int:
        """Steps recorded but neither committed nor rolled back."""
        return len(self._steps) - self._committed

    # -- resolution ---------------------------------------------------

    def commit(self, upto: int | None = None) -> int:
        """Commit the first ``upto`` open steps (all open steps when None).

        Deferred actions run in record order. Returns #steps committed.
        """
        pending = self.open_steps()
        n = pending if upto is None else min(upto, pending)
        if n < 0:
            raise ValueError("commit count must be >= 0")
        for step in self._steps[self._committed:self._committed + n]:
            for action in step.deferred:
                action()
        self._committed += n
        self.steps_committed += n
        return n

    def rollback(self, from_step: int = 0) -> int:
        """Unwind open steps from relative index ``from_step`` to the end.

        Compensations run in reverse record order (newest effect first),
        so nested workspace writes restore correctly. Returns #steps
        rolled back.
        """
        pending = self.open_steps()
        if from_step < 0 or from_step > pending:
            raise ValueError(f"rollback from_step {from_step} out of range "
                             f"(0..{pending})")
        doomed = self._steps[self._committed + from_step:]
        for step in reversed(doomed):
            for undo in reversed(step.undos):
                undo()
        del self._steps[self._committed + from_step:]
        self.steps_rolled_back += len(doomed)
        return len(doomed)

    def patch(self, keep: int) -> tuple:
        """Splice: commit the matching prefix of ``keep`` open steps, then
        roll back the divergent suffix. Returns (committed, rolled_back).
        The journal stays usable — the re-executed suffix records into it.
        """
        rolled = self.rollback(from_step=min(keep, self.open_steps()))
        committed = self.commit()
        return committed, rolled

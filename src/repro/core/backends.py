"""LM backends for the APC control plane.

``SimulatedBackend`` is a deterministic behavioral model of each LM role:
it produces *real structured plans* against the executable envs (so accuracy
is measured end-to-end by the env judge), with per-role quality knobs
calibrated to the paper's sensitivity tables (Tables 9-11) — e.g. the large
planner plans correctly ~95% of the time, the small planner ~57%, template
adaptation ~93%. Failures are real failure modes (wrong field retrieved,
wrong scope, unfilled placeholder), not coin-flip labels.

``JaxBackend`` (serving/jax_backend.py) runs actual JAX models from the zoo
for the data-plane path; content-level behavior still comes from the
simulated layer (random weights produce no usable text), which is the
standard synthetic-workload methodology for serving systems.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.cost_model import estimate_tokens
from repro.core.template import PlanTemplate, instantiate
from repro.envs.base import Task, det_rng, execute_compute, execute_retrieve


@dataclass(frozen=True)
class QualityProfile:
    """Per-CALL success probabilities. A query samples ~2.4 calls (retrieval
    rounds + answer round), so per-query accuracies compose: e.g.
    0.975^2.4 * 0.985^1.4 ~ 0.91 (paper's accuracy-optimal FinanceBench)."""

    p_plan_large: float = 0.975  # correct from-scratch plan, large planner
    p_plan_small: float = 0.66  # correct from-scratch plan, small planner
    p_adapt: float = 0.945  # correct template adaptation, small planner
    p_adapt_fullhist: float = 0.81  # adaptation from unfiltered history
    p_actor: float = 0.985  # actor retrieves values faithfully
    p_keyword: float = 0.96  # canonical keyword extracted
    p_generalize: float = 0.93  # cache-gen filter abstracts every slot


@dataclass(frozen=True)
class TokenProfile:
    """Per-call token counts (see EXPERIMENTS.md §Calibration)."""

    planner_sys: int = 1500
    planner_out_large: int = 800  # chain-of-thought + retrieval message
    planner_out_small: int = 680
    answer_out_large: int = 260  # terminal answer call is shorter
    answer_out_small: int = 220
    adapt_out: int = 130
    adapt_answer_out: int = 90
    adapt_fullhist_out: int = 180
    actor_excerpt: int = 1200  # actor reads a retrieved excerpt, not the full doc
    actor_out: int = 90
    keyword_in_extra: int = 60
    keyword_out: int = 8
    cachegen_in: int = 500
    cachegen_out: int = 200


DEFAULT_QUALITY = QualityProfile()
DEFAULT_TOKENS = TokenProfile()


@dataclass
class PlanMsg:
    """A planner->actor message (or terminal answer)."""

    kind: str  # "message" | "answer"
    text: str
    op: Dict[str, Any]


class SimulatedBackend:
    """All five LM roles, deterministic given (seed, task id, call site)."""

    def __init__(
        self,
        quality: QualityProfile = DEFAULT_QUALITY,
        tokens: TokenProfile = DEFAULT_TOKENS,
        seed: int = 0,
    ):
        self.q = quality
        self.t = tokens
        self.seed = seed

    # ------------------------------------------------------------------
    # keyword extraction (paper B.4.3)
    # ------------------------------------------------------------------

    def extract_keyword(self, task: Task) -> Tuple[str, int, int]:
        """Returns (keyword, in_tokens, out_tokens)."""
        rng = det_rng(self.seed, task.id, "keyword")
        intent = task.intent
        if rng.random() < self.q.p_keyword or not intent.paraphrase_keywords:
            kw = intent.keyword
        else:
            kw = rng.choice(list(intent.paraphrase_keywords))
        inp = estimate_tokens(task.query) + self.t.keyword_in_extra
        return kw, inp, self.t.keyword_out

    # ------------------------------------------------------------------
    # planning from scratch (large or small planner)
    # ------------------------------------------------------------------

    def plan(
        self,
        task: Task,
        responses: List[Dict[str, Any]],
        *,
        large: bool,
        round_idx: int,
    ) -> Tuple[PlanMsg, int, int]:
        """Next plan message, or the final answer once retrievals suffice."""
        intent = task.intent
        p_ok = self.q.p_plan_large if large else self.q.p_plan_small
        rng = det_rng(self.seed, task.id, "plan", large, round_idx)
        correct = rng.random() < p_ok

        if round_idx < intent.n_rounds:
            fields = list(intent.rounds[round_idx])
            if not correct:
                fields = self._corrupt_fields(fields, task, rng)
            msg = PlanMsg(
                kind="message",
                text=(
                    f"Please provide {', '.join(fields)} for "
                    f"{task.slots.get('company', task.slots.get('student', ''))} "
                    f"from the provided context."
                ),
                op={"retrieve": fields, "scope": dict(task.slots)},
            )
        else:
            msg = self._answer_from(task, responses, correct)
        inp = (
            self.t.planner_sys
            + estimate_tokens(task.query)
            + sum(estimate_tokens(str(r)) for r in responses)
        )
        if msg.kind == "answer":
            out = self.t.answer_out_large if large else self.t.answer_out_small
        else:
            out = self.t.planner_out_large if large else self.t.planner_out_small
        return msg, inp, out

    def _corrupt_fields(self, fields, task: Task, rng) -> List[str]:
        bad = list(fields)
        i = rng.randrange(len(bad))
        pool = task.distractors or ["unknown_metric"]
        bad[i] = rng.choice(pool)
        return bad

    def _answer_from(self, task: Task, responses, correct: bool) -> PlanMsg:
        names = "abcdefghij"
        bindings: Dict[str, float] = {}
        idx = 0
        for r in responses:
            for f in task.intent.all_fields:
                if f in r.get("values", {}) and names[idx : idx + 1]:
                    bindings[names[idx]] = r["values"][f]
                    idx += 1
        expr = task.intent.expr
        if not correct:
            rng = det_rng(self.seed, task.id, "expr")
            expr = rng.choice(["a", "a * b" if "b" in bindings else "a * 2", "a + 1"])
        val = execute_compute(expr, bindings)
        return PlanMsg(
            kind="answer",
            text=f"The answer is {val}.",
            op={"compute": expr, "value": val},
        )

    # ------------------------------------------------------------------
    # template adaptation (small planner on cache hit; paper B.4.5)
    # ------------------------------------------------------------------

    def adapt(
        self,
        task: Task,
        template: PlanTemplate,
        responses: List[Dict[str, Any]],
        *,
        round_idx: int,
        full_history: bool = False,
    ) -> Tuple[PlanMsg, int, int]:
        p_ok = self.q.p_adapt_fullhist if full_history else self.q.p_adapt
        rng = det_rng(self.seed, task.id, "adapt", round_idx, full_history)
        correct = rng.random() < p_ok

        msgs = template.message_steps()
        if round_idx < len(msgs):
            step = msgs[round_idx]
            op = instantiate(step.op, task.slots) or {}
            fields = [f for f in op.get("retrieve", []) if "{" not in f]
            if not correct and fields:
                fields = self._corrupt_fields(fields, task, rng)
            msg = PlanMsg(
                kind="message",
                text=instantiate(step.content, task.slots),
                op={"retrieve": fields, "scope": dict(task.slots)},
            )
        else:
            ans = template.answer_step()
            expr = (ans.op or {}).get("compute", task.intent.expr) if ans else task.intent.expr
            if "{" in str(expr):  # un-generalized garbage leaked into template
                correct = False
                expr = "a"
            if not correct:
                expr = rng.choice(["a", "a + 1"])
            names = "abcdefghij"
            bindings, idx = {}, 0
            for r in responses:
                for f in task.intent.all_fields:
                    if f in r.get("values", {}):
                        bindings[names[idx]] = r["values"][f]
                        idx += 1
            val = execute_compute(str(expr), bindings)
            msg = PlanMsg("answer", f"The answer is {val}.", {"compute": expr, "value": val})
        inp = (
            estimate_tokens(task.query)
            + (template.size_tokens() if not full_history else 0)
            + sum(estimate_tokens(str(r)) for r in responses)
            + 120
        )
        if full_history:
            out = self.t.adapt_fullhist_out
        else:
            out = self.t.adapt_answer_out if msg.kind == "answer" else self.t.adapt_out
        return msg, inp, out

    # ------------------------------------------------------------------
    # actor (executes retrieval plans against the context)
    # ------------------------------------------------------------------

    def act(self, task: Task, plan: PlanMsg) -> Tuple[Dict[str, Any], int, int]:
        rng = det_rng(self.seed, task.id, "act", plan.text[:40])
        values = execute_retrieve(plan.op, task.context)
        if values and rng.random() > self.q.p_actor:
            k = rng.choice(list(values))
            values[k] = values[k] * rng.choice([10.0, 0.1, -1.0])  # mis-read
        resp = {"values": values}
        inp = min(task.context_tokens, self.t.actor_excerpt) + estimate_tokens(plan.text)
        return resp, inp, self.t.actor_out

    # ------------------------------------------------------------------
    # cache generation filter (lightweight LM; slot-abstraction errors)
    # ------------------------------------------------------------------

    def generalization_misses(self, task: Task) -> List[str]:
        rng = det_rng(self.seed, task.id, "gen")
        if rng.random() < self.q.p_generalize:
            return []
        slots = list(task.slots)
        return [rng.choice(slots)] if slots else []

    def cachegen_tokens(self, raw_tokens: int) -> Tuple[int, int]:
        return min(raw_tokens, self.t.cachegen_in) + 150, self.t.cachegen_out

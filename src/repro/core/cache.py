"""The plan cache: a batch-native ``PlanStore`` with composable eviction
policies and a pluggable match pipeline (paper §3.2, §4.4).

``PlanCache`` implements the :class:`repro.memory.protocol.PlanStore`
protocol: ``lookup_batch``/``insert_batch`` are the primitive operations
(one lock acquisition, one batched fuzzy/semantic resolution, one device
scatter per admission wave on the ``device`` index backend); the singular
``lookup``/``insert`` are thin wrappers inherited from ``PlanStoreBase``.

Matching is a :class:`~repro.memory.pipeline.MatchPipeline` — exact dict
membership by default, exact -> fuzzy with ``fuzzy=True`` (the paper's
Tables 5-6 configuration, backed by the ``repro.index`` subsystem with
``index_backend`` selecting ``brute`` | ``pallas`` | ``bucketed`` |
``device`` | ``auto``), and arbitrary cascades via ``pipeline=("exact",
"fuzzy", "semantic")``. Stage indexes are maintained *incrementally* under
the cache lock on insert/evict/TTL-expire — no per-lookup key-list copy or
matrix rebuild.

Eviction is an :class:`~repro.memory.policies.EvictionPolicy`
(``eviction="lru" | "lfu" | "cost"`` or an instance); the historical
``ttl_s`` kwarg wraps the chosen policy in TTL expiry, so pre-protocol
constructor calls behave exactly as before.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Generic, List, Optional, Sequence, Tuple, Union

from repro.memory.pipeline import MatchPipeline, build_pipeline
from repro.memory.policies import CacheEntry, EvictionPolicy, make_policy
from repro.memory.protocol import CacheStats, PlanStoreBase, V
from repro.memory.tiered import ColdTier
from repro.obs import MetricsRegistry, deposit, trace_span
from repro.obs.names import (
    SPAN_CACHE_INSERT,
    SPAN_CACHE_LOOKUP,
    SPAN_CACHE_PROMOTE,
    SPAN_CACHE_SPILL,
    SPAN_MATCH_STAGE,
)


class PlanCache(PlanStoreBase, Generic[V]):
    """keyword -> plan-template store with pluggable eviction + matching.

    Thread-safe: the serving router calls lookup/insert from request threads
    while async cache generation (speculative.py) inserts from workers.
    """

    def __init__(
        self,
        capacity: int = 100,
        *,
        fuzzy: bool = False,
        fuzzy_threshold: float = 0.8,
        semantic_threshold: float = 0.85,
        index_backend: str = "auto",
        ttl_s: Optional[float] = None,
        eviction: Union[str, EvictionPolicy] = "lru",
        pipeline: Optional[Union[MatchPipeline, Sequence[Any]]] = None,
        clock: Optional[Callable[[], float]] = None,
        evict_during_wave: bool = False,
        serve_expired: bool = False,
        cold_dir: Optional[str] = None,
        cold_budget_tokens: int = 160,
        cold_keep_last: int = 8,
        cold_refcount_gc: bool = True,
        obs: Optional[MetricsRegistry] = None,
        obs_labels: Optional[Dict[str, str]] = None,
    ):
        self.capacity = capacity
        # injectable time source: TTL expiry and entry timestamps read THIS,
        # never the wall clock directly, so the deterministic simulation
        # harness (repro.sim) and TTL tests can drive time explicitly
        self._clock = clock if clock is not None else time.time
        # ABLATION SEAM (repro.sim only): the documented contract is that
        # eviction runs AFTER an admission wave lands, so a wave larger than
        # capacity keeps its newest entries. Setting evict_during_wave=True
        # restores the pre-protocol per-insert eviction so the sim's
        # eviction oracle can demonstrate it catches the regression.
        self._evict_during_wave = evict_during_wave
        # ABLATION SEAM (repro.sim only): serve_expired=True skips the TTL
        # check on the lookup path, serving entries past their expiry — the
        # ttl_churn phantom oracle must catch exactly this.
        self._serve_expired = serve_expired
        self.fuzzy_threshold = fuzzy_threshold
        self.semantic_threshold = semantic_threshold
        self.index_backend = index_backend
        self.ttl_s = ttl_s
        self.policy = make_policy(eviction, ttl_s=ttl_s)
        # obs: the shared metrics registry this store's accounting lands
        # in (shards of a DistributedPlanCache share the facade's registry
        # with a ``shard=<name>`` label); a private registry otherwise
        self.obs = obs if obs is not None else MetricsRegistry()
        self.obs_labels = dict(obs_labels or {})
        if pipeline is None:
            pipeline = ("exact", "fuzzy") if fuzzy else ("exact",)
        self.pipeline = (
            pipeline
            if isinstance(pipeline, MatchPipeline)
            else build_pipeline(
                pipeline,
                fuzzy_threshold=fuzzy_threshold,
                semantic_threshold=semantic_threshold,
                index_backend=index_backend,
                obs=self.obs,
                obs_labels=self.obs_labels,
            )
        )
        self.fuzzy = self.pipeline.stage("fuzzy") is not None
        self._store: Dict[str, CacheEntry] = {}
        # hot-tier delete hooks: called with the keyword for EVERY removal
        # from the hot store (eviction, TTL expiry, remove(), clear()) —
        # the seam that ties derived per-template state (the paged KV
        # prefix pool) to this cache's lifecycle. Listeners run under the
        # cache lock and must not call back into this cache.
        self._evict_listeners: List[Callable[[str], None]] = []
        self._lock = threading.RLock()
        self.stats = CacheStats(self.obs, **self.obs_labels)
        # the cold persistent tier (repro.memory.tiered): eviction victims
        # spill to CheckpointStore segments and hot misses promote back
        # through insert_batch; None keeps the historical two-tier shape
        self.cold: Optional[ColdTier] = (
            None if cold_dir is None else ColdTier(
                cold_dir,
                budget_tokens=cold_budget_tokens,
                keep_last=cold_keep_last,
                refcount_gc=cold_refcount_gc,
            )
        )

    def now(self) -> float:
        """The store's clock — capture this before a read whose derived
        wave will be inserted with ``unless_written_since``."""
        return self._clock()

    @property
    def _matcher(self):
        """Back-compat alias: the fuzzy stage's matcher (None when exact-only)."""
        stage = self.pipeline.stage("fuzzy")
        return None if stage is None else stage.matcher

    # -- core ops ----------------------------------------------------------

    def lookup_batch(
        self,
        keywords: Sequence[str],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
    ) -> List[Optional[V]]:
        """Answer a whole batch of lookups in one pipeline walk.

        Each stage resolves the still-unresolved queries in one batched
        call (a single top-k device call for the fuzzy/semantic stages on
        the ``pallas``/``device`` backends); resolved keys are served
        through the one exact path that accounts TTL expiry, hit counters,
        and policy touches — so batched and singular lookups can't drift.
        """
        t0 = time.perf_counter()
        if contexts is None:
            contexts = [None] * len(keywords)
        try:
            with trace_span(SPAN_CACHE_LOOKUP, n=len(keywords),
                            **self.obs_labels) as lsp, self._lock:
                now = self._clock()
                out: List[Optional[V]] = [None] * len(keywords)
                pending = list(range(len(keywords)))
                hits = 0
                for stage in self.pipeline.stages:
                    if not pending:
                        break
                    with trace_span(SPAN_MATCH_STAGE, stage=stage.name,
                                    pending=len(pending)) as ssp:
                        alts = stage.resolve(
                            [keywords[i] for i in pending],
                            [contexts[i] for i in pending],
                            self._store.__contains__,
                        )
                        still: List[int] = []
                        for i, alt in zip(pending, alts):
                            v = None if alt is None else self._get_live(alt, now)
                            if v is None:
                                still.append(i)
                            else:
                                out[i] = v
                                # attribution: which stage resolved batch
                                # index i, and to which stored key
                                deposit(i, stage=stage.name, matched_key=alt)
                        ssp.set(resolved=len(pending) - len(still))
                        pending = still
                if pending and self.cold is not None:
                    # the cold tier resolves exact keys only, via the
                    # in-RAM manifest; a manifest hit PROMOTES the entry
                    # back through the normal insert path (per-key waves,
                    # in batch order) and serves it from the hot tier
                    with trace_span(SPAN_MATCH_STAGE, stage="cold",
                                    pending=len(pending)) as ssp:
                        still = []
                        for i in pending:
                            kw = keywords[i]
                            v = (self._promote(kw, now)
                                 if kw in self.cold else None)
                            if v is None:
                                still.append(i)
                            else:
                                out[i] = v
                                deposit(i, stage="cold", matched_key=kw,
                                        cache_tier="cold")
                                self.stats.add("cold_hits")
                        ssp.set(resolved=len(pending) - len(still))
                        pending = still
                for v in out:
                    if v is None:
                        self.stats.misses += 1
                    else:
                        self.stats.hits += 1
                        hits += 1
                lsp.set(hits=hits)
                return out
        finally:
            # lock-safe inc: runs outside self._lock, and a traced router
            # may overlap concurrent lookup waves on one shared registry
            self.stats.add("lookup_time_s", time.perf_counter() - t0)

    def _get_live(self, keyword: str, now: float) -> Optional[V]:
        """Serve one exact key: TTL-expire, count the hit, touch the policy."""
        entry = self._store.get(keyword)
        if entry is None:
            return None
        if not self._serve_expired and self.policy.expired(keyword, entry, now):
            # expiry is a hard delete, never a spill: a TTL'd entry is
            # stale by contract and must not resurrect from the cold tier
            self._delete(keyword)
            return None
        entry.hits += 1
        self.policy.on_access(keyword, entry)
        return entry.value

    def _promote(self, keyword: str, now: float) -> Optional[V]:
        """Move one cold entry back to the hot tier and serve it.

        Promotion is a MOVE (the manifest entry is consumed) through the
        normal ``insert_batch`` path — policy bookkeeping, pipeline index
        maintenance, and any cascading eviction (which may spill a colder
        victim, or even re-spill this key if the policy scores it lowest)
        all behave exactly as a fresh insert. Returns None when the
        manifest was stale (segment rotated/torn) or the promoted entry
        did not survive its own admission wave."""
        got = self.cold.take([keyword])[0]
        if got is None:
            return None
        with trace_span(SPAN_CACHE_PROMOTE, key=keyword, **self.obs_labels):
            self.insert_batch(
                [(keyword, got.value)],
                contexts=[got.context],
                vectors=None if got.vector is None else [got.vector],
            )
            self.stats.add("promotes")
        return self._get_live(keyword, now)

    def add_evict_listener(self, fn: Callable[[str], None]) -> None:
        """Register a hot-tier delete hook (see ``_evict_listeners``)."""
        with self._lock:
            self._evict_listeners.append(fn)

    def _delete(self, keyword: str) -> None:
        del self._store[keyword]
        self.policy.on_remove(keyword)
        self.pipeline.on_remove(keyword)
        for fn in self._evict_listeners:
            fn(keyword)

    def insert_batch(
        self,
        items: Sequence[Tuple[str, V]],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
        vectors: Optional[Any] = None,
        unless_written_since: Optional[float] = None,
    ) -> None:
        """Insert a whole admission wave under one lock acquisition.

        Pipeline stages ingest the wave batched — one embedding batch and,
        on the ``device`` backend, one donated multi-slot device scatter —
        instead of one index write per key. ``vectors`` lets a caller that
        already embedded the keys (a replicating distributed cache) skip
        re-embedding. Eviction runs after the wave lands, so a wave larger
        than ``capacity`` keeps its newest entries; with a cold tier wired,
        the wave's victims spill as ONE cold segment at wave end.

        ``unless_written_since`` is conditional admission (see the
        protocol docs): keys whose live entry was (re)written at or after
        the token are skipped — the guard against async cache generation
        clobbering a newer client insert with a stale template.
        """
        items = list(items)
        if contexts is None:
            contexts = [None] * len(items)
        with trace_span(SPAN_CACHE_INSERT, n=len(items),
                        **self.obs_labels), self._lock:
            now = self._clock()
            kept: List[int] = []
            victims: List[Tuple[str, CacheEntry]] = []

            def _evict_one() -> None:
                vk = self.policy.victim(self._store)
                ventry = self._store[vk]
                self._delete(vk)
                self.stats.evictions += 1
                if self.cold is not None:
                    victims.append((vk, ventry))

            for idx, (kw, v) in enumerate(items):
                if unless_written_since is not None:
                    existing = self._store.get(kw)
                    if (existing is not None
                            and existing.inserted_at >= unless_written_since):
                        self.stats.add("stale_insert_skips")
                        continue
                kept.append(idx)
                if kw in self._store:
                    # overwrite of a live key is delete + insert, not a
                    # silent swap: eviction listeners must see the OLD
                    # entry go (the paged KV prefix pool keys derived
                    # state by keyword; a surviving stale registration
                    # would serve the old template's prefix KV under the
                    # regenerated template's id)
                    self._delete(kw)
                entry = CacheEntry(
                    v, now,
                    context=contexts[idx],
                    vector=None if vectors is None else vectors[idx],
                )
                self._store[kw] = entry
                self.policy.on_insert(kw, entry)
                self.stats.inserts += 1
                if self._evict_during_wave:
                    while len(self._store) > self.capacity:
                        _evict_one()
            if kept:
                self.pipeline.on_insert_batch(
                    [items[i] for i in kept],
                    [contexts[i] for i in kept],
                    None if vectors is None else [vectors[i] for i in kept],
                )
            while len(self._store) > self.capacity:
                _evict_one()
            if victims:
                self._spill(victims)

    def _spill(self, victims: List[Tuple[str, CacheEntry]]) -> None:
        """Write one spill wave (this insert wave's eviction victims) to
        the cold tier: compaction + segment write + manifest commit."""
        with trace_span(SPAN_CACHE_SPILL, n=len(victims),
                        **self.obs_labels) as sp:
            saved = self.cold.spill([
                (kw, e.value, e.context, e.vector,
                 float(e.hits + getattr(e.value, "uses", 0)))
                for kw, e in victims
            ])
            self.stats.add("spills", len(victims))
            if saved:
                self.stats.add("compaction_saved_tokens", saved)
            sp.set(saved_tokens=saved)

    def peek(self, keyword: str) -> Optional[V]:
        """Value for an exact key WITHOUT hit accounting or policy touches
        (expired entries still return None). Used by crash-recovery
        read-repair in the distributed cache, where a repair scan must not
        perturb recency/frequency bookkeeping."""
        with self._lock:
            entry = self._store.get(keyword)
            if entry is None or self.policy.expired(keyword, entry, self._clock()):
                return None
            return entry.value

    def snapshot_items(self) -> List[Tuple[str, V]]:
        """Every live (keyword, value) pair under ONE lock acquisition, with
        ``peek`` semantics (no hit/recency perturbation, expired entries
        skipped). The repair-scan primitive: a per-key ``peek`` loop would
        take the lock O(keys) times."""
        with self._lock:
            now = self._clock()
            return [
                (k, e.value) for k, e in self._store.items()
                if not self.policy.expired(k, e, now)
            ]

    def remove(self, keyword: str) -> bool:
        """Delete one entry, keeping stage indexes in sync. True if present
        in EITHER tier — a removed key must not resurrect from the cold
        manifest on a later miss."""
        with self._lock:
            purged = self.cold.purge(keyword) if self.cold is not None else False
            if keyword not in self._store:
                return purged
            self._delete(keyword)
            return True

    def autotune(self, **thresholds) -> List[str]:
        """One auto-tuning step for every stage index that supports it
        (LSH ``n_bits``/``probe_hamming`` adjustment from live telemetry);
        returns the actions taken, e.g. ``["fuzzy:n_bits->14"]``."""
        with self._lock:
            actions: List[str] = []
            for stage in self.pipeline.stages:
                tune = getattr(stage, "autotune", None)
                if tune is not None:
                    act = tune(**thresholds)
                    if act:
                        actions.append(f"{stage.name}:{act}")
            return actions

    def __contains__(self, keyword: str) -> bool:
        with self._lock:
            return keyword in self._store

    def __len__(self) -> int:
        with self._lock:  # consistent reads while writers mutate _store
            return len(self._store)

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._store)

    def clear(self) -> None:
        with self._lock:
            dropped = list(self._store)
            self._store.clear()
            for kw in dropped:
                for fn in self._evict_listeners:
                    fn(kw)
            # reset, don't rebuild: the stats object is a view over a
            # possibly-shared registry, and replacing it would strand the
            # registered series at their old values
            self.stats.reset()
            self.policy.reset()
            self.pipeline.clear()
            if self.cold is not None:
                self.cold.clear()

    # -- serialization (checkpoint/restore of the test-time memory) --------

    def to_state(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "entries": [(k, e.value) for k, e in self._store.items()],
            }

    @classmethod
    def from_state(cls, state: Dict[str, Any], **kw) -> "PlanCache":
        c = cls(capacity=state["capacity"], **kw)
        c.insert_batch(state["entries"])
        return c


__all__ = ["CacheStats", "PlanCache"]

"""The plan cache: exact-match dict with LRU eviction (paper §3.2, §4.4).

Exact matching is the paper's default — O(1) lookups via a hash map,
validated to scale to 1e6 entries (Table 5). Fuzzy matching is available
behind the same interface (``fuzzy=True``), backed by the ``repro.index``
similarity subsystem: the matcher's embedding bank is maintained
*incrementally* under the cache lock on insert/evict/TTL-expire (no
per-lookup key-list copy or matrix rebuild), and ``index_backend`` selects
the search strategy (``brute`` | ``pallas`` | ``bucketed`` | ``device`` |
``auto``). The paper's threshold/latency trade-offs (Tables 5-6) reproduce
against the ``brute`` backend; ``bucketed`` removes the Table 5 scaling
cliff, and ``device`` keeps the embedding bank resident on the accelerator
so batched lookups move zero bank bytes per call.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Generic, List, Optional, Tuple, TypeVar

V = TypeVar("V")


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    lookup_time_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "inserts": self.inserts,
            "evictions": self.evictions,
            "lookup_time_s": round(self.lookup_time_s, 6),
        }


class PlanCache(Generic[V]):
    """keyword -> plan-template store with LRU eviction.

    Thread-safe: the serving router calls lookup/insert from request threads
    while async cache generation (speculative.py) inserts from workers.
    """

    def __init__(
        self,
        capacity: int = 100,
        *,
        fuzzy: bool = False,
        fuzzy_threshold: float = 0.8,
        index_backend: str = "auto",
        ttl_s: Optional[float] = None,
    ):
        self.capacity = capacity
        self.fuzzy = fuzzy
        self.fuzzy_threshold = fuzzy_threshold
        self.index_backend = index_backend
        self.ttl_s = ttl_s
        self._store: "OrderedDict[str, Tuple[V, float]]" = OrderedDict()
        self._lock = threading.RLock()
        self.stats = CacheStats()
        self._matcher = None
        if fuzzy:
            from repro.core.fuzzy import FuzzyMatcher

            self._matcher = FuzzyMatcher(backend=index_backend)

    # -- core ops ----------------------------------------------------------

    def lookup(self, keyword: str) -> Optional[V]:
        t0 = time.perf_counter()
        try:
            with self._lock:
                hit = self._lookup_exact(keyword)
                if hit is None and self._matcher is not None:
                    # the matcher's index is maintained incrementally on
                    # insert/evict/TTL-expire — no key-list copy per lookup
                    alt = self._matcher.best_match(
                        keyword, threshold=self.fuzzy_threshold
                    )
                    if alt is not None:
                        hit = self._lookup_exact(alt)
                if hit is None:
                    self.stats.misses += 1
                else:
                    self.stats.hits += 1
                return hit
        finally:
            self.stats.lookup_time_s += time.perf_counter() - t0

    def _lookup_exact(self, keyword: str) -> Optional[V]:
        item = self._store.get(keyword)
        if item is None:
            return None
        value, ts = item
        if self.ttl_s is not None and time.time() - ts > self.ttl_s:
            del self._store[keyword]
            if self._matcher is not None:
                self._matcher.remove(keyword)
            return None
        self._store.move_to_end(keyword)  # LRU touch
        return value

    def insert(self, keyword: str, value: V) -> None:
        with self._lock:
            if keyword in self._store:
                self._store.move_to_end(keyword)
            self._store[keyword] = (value, time.time())
            self.stats.inserts += 1
            if self._matcher is not None:
                self._matcher.add(keyword)
            while len(self._store) > self.capacity:
                old, _ = self._store.popitem(last=False)
                self.stats.evictions += 1
                if self._matcher is not None:
                    self._matcher.remove(old)

    def insert_batch(self, items: List[Tuple[str, V]]) -> None:
        """Insert a whole admission wave under one lock acquisition.

        The fuzzy index ingests the wave via ``add_batch`` — one embedding
        batch and, on the ``device`` backend, one donated multi-slot device
        scatter — instead of one index write per key. Eviction runs after
        the wave lands, so a wave larger than ``capacity`` keeps its newest
        entries (same LRU order as sequential inserts).
        """
        with self._lock:
            now = time.time()
            for kw, v in items:
                if kw in self._store:
                    self._store.move_to_end(kw)
                self._store[kw] = (v, now)
                self.stats.inserts += 1
            if self._matcher is not None and items:
                self._matcher.add_batch([kw for kw, _ in items])
            while len(self._store) > self.capacity:
                old, _ = self._store.popitem(last=False)
                self.stats.evictions += 1
                if self._matcher is not None:
                    self._matcher.remove(old)

    def lookup_batch(self, keywords: List[str]) -> List[Optional[V]]:
        """Answer a whole batch of lookups in one pass.

        Exact hits resolve per-key; the fuzzy fallback for all remaining
        misses is answered by a single batched top-k (one device call on
        the ``pallas`` backend) instead of one scan per request.
        """
        t0 = time.perf_counter()
        try:
            with self._lock:
                out: List[Optional[V]] = [self._lookup_exact(k) for k in keywords]
                if self._matcher is not None:
                    miss_pos = [i for i, v in enumerate(out) if v is None]
                    if miss_pos:
                        alts = self._matcher.best_match_batch(
                            [keywords[i] for i in miss_pos], self.fuzzy_threshold
                        )
                        for i, alt in zip(miss_pos, alts):
                            if alt is not None:
                                out[i] = self._lookup_exact(alt)
                for v in out:
                    if v is None:
                        self.stats.misses += 1
                    else:
                        self.stats.hits += 1
                return out
        finally:
            self.stats.lookup_time_s += time.perf_counter() - t0

    def remove(self, keyword: str) -> bool:
        """Delete one entry, keeping the fuzzy index in sync. True if present."""
        with self._lock:
            if self._store.pop(keyword, None) is None:
                return False
            if self._matcher is not None:
                self._matcher.remove(keyword)
            return True

    def __contains__(self, keyword: str) -> bool:
        with self._lock:
            return keyword in self._store

    def __len__(self) -> int:
        with self._lock:  # consistent reads while writers mutate _store
            return len(self._store)

    def keys(self):
        with self._lock:
            return list(self._store.keys())

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.stats = CacheStats()
            if self._matcher is not None:
                self._matcher.clear()

    # -- serialization (checkpoint/restore of the test-time memory) --------

    def to_state(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "entries": [(k, v) for k, (v, _) in self._store.items()],
            }

    @classmethod
    def from_state(cls, state: Dict[str, Any], **kw) -> "PlanCache":
        c = cls(capacity=state["capacity"], **kw)
        for k, v in state["entries"]:
            c.insert(k, v)
        return c

"""Agent run-strategies: every evaluation method as a registered class.

Method map (paper §4.1, plus the beyond-paper ``cascade`` hybrid):

  apc               Alg.1: keyword -> cache -> Alg.2 (hit, small planner
                    adapts template) / Alg.3 (miss, large planner plans from
                    scratch; successful log distilled into the cache)
  accuracy_optimal  always the large planner, no cache
  cost_optimal      always the small planner, no cache
  semantic          GPTCache-style query-similarity cache of final responses
  full_history      keyword cache of raw execution logs used as in-context
                    examples for the small planner
  cascade           exact -> fuzzy -> semantic MatchPipeline over ONE plan
                    store: keyword matching first (APC semantics), then
                    query-text similarity against each template's source
                    task — reusing *templates* (adapted by the small
                    planner) across paraphrases whose keywords don't match,
                    instead of replaying final answers verbatim like the
                    semantic baseline.

Importing this module populates the :mod:`repro.memory.registry`; the
harness's ``METHODS`` and the t1 benchmark enumerate it instead of keeping
a hand-written list. All strategies account their results through the one
:func:`record` helper, so RunRecord fields can't drift between methods.
"""

from __future__ import annotations

import time
from typing import Optional

from repro.core.cache import PlanCache
from repro.core.template import ExecutionLog, PlanTemplate, make_template, rule_filter
from repro.envs.base import Task, judge
from repro.memory.registry import (
    METHOD_REGISTRY,
    AgentMethod,
    get_method_class,
    make_method,
    method_names,
    register_method,
)


def record(
    agent,
    task: Task,
    method: str,
    *,
    correct: bool,
    hit: bool,
    keyword: str,
    iterations: int,
    answer: Optional[float],
    latency_s: float,
    lookup_s: float = 0.0,
    gen_s: float = 0.0,
    speculated: bool = False,
    spec_outcome: str = "",
):
    """The single RunRecord accounting path shared by every method."""
    from repro.core.agent_loop import RunRecord

    return RunRecord(
        task.id, method, correct, hit, keyword, iterations, answer,
        agent.ledger.total_cost(), latency_s, lookup_s, gen_s,
        speculated, spec_outcome,
    )


class _ScratchMethod(AgentMethod):
    """No cache: every task planned from scratch on one fixed tier."""

    large = True

    def run(self, task: Task):
        agent = self.agent
        answer, iters, _, lat = agent._loop_scratch(task, large=self.large)
        return record(
            agent, task, self.name,
            correct=judge(answer, task.gt_answer), hit=False, keyword="",
            iterations=iters, answer=answer, latency_s=lat,
        )


@register_method("accuracy_optimal")
class AccuracyOptimalMethod(_ScratchMethod):
    large = True


@register_method("cost_optimal")
class CostOptimalMethod(_ScratchMethod):
    large = False


@register_method("semantic")
class SemanticMethod(AgentMethod):
    """GPTCache semantics: cache final responses keyed by the query, served
    on query-text similarity. The matcher is a plain PlanCache with an
    ``exact -> semantic`` MatchPipeline (the baseline's hand-rolled
    SimilarityIndex is gone)."""

    def setup(self) -> None:
        cfg = self.agent.cfg
        self.store: PlanCache = PlanCache(
            capacity=1_000_000,  # the baseline never evicts
            pipeline=("exact", "semantic"),
            semantic_threshold=cfg.semantic_threshold,
            index_backend=cfg.index_backend,
        )

    def run(self, task: Task):
        agent = self.agent
        t0 = time.perf_counter()
        hit_val = self.store.lookup(task.query)
        lookup_s = time.perf_counter() - t0
        if hit_val is not None:
            # cached final response returned verbatim (GPTCache semantics) —
            # correct only if the numeric answer transfers to THIS task.
            answer = hit_val[1]
            return record(
                agent, task, self.name,
                correct=judge(answer, task.gt_answer), hit=True, keyword="",
                iterations=0, answer=answer, latency_s=lookup_s,
                lookup_s=lookup_s,
            )
        answer, iters, _, lat = agent._loop_scratch(task, large=True)
        self.store.insert(task.query, (task.query, answer))
        return record(
            agent, task, self.name,
            correct=judge(answer, task.gt_answer), hit=False, keyword="",
            iterations=iters, answer=answer, latency_s=lat + lookup_s,
            lookup_s=lookup_s,
        )


@register_method("full_history")
class FullHistoryMethod(AgentMethod):
    """Cache raw execution logs by keyword; replay them unfiltered as
    in-context examples for the small planner."""

    def run(self, task: Task):
        agent = self.agent
        lat = 0.0
        kw, ki, ko = agent.be.extract_keyword(task)
        lat += agent.ledger.record("keyword_extractor", ki, ko)
        t0 = time.perf_counter()
        log: Optional[ExecutionLog] = agent.cache.lookup(kw)
        lookup_s = time.perf_counter() - t0
        lat += lookup_s
        if log is not None:
            # raw log as in-context example: build an UNfiltered pseudo-template
            steps = rule_filter(log)
            tpl = PlanTemplate(keyword=kw, steps=steps, source_task=log.task_query)
            # charge the long history into the small planner's context
            agent.ledger.record("small_planner", log.raw_tokens(), 0)
            answer, iters, l2 = agent._loop_adapt(task, tpl, full_history=True)
            lat += l2
            return record(
                agent, task, self.name,
                correct=judge(answer, task.gt_answer), hit=True, keyword=kw,
                iterations=iters, answer=answer, latency_s=lat,
                lookup_s=lookup_s,
            )
        answer, iters, log, l3 = agent._loop_scratch(task, large=True)
        lat += l3
        if answer is not None:
            agent.cache.insert(kw, log)
        return record(
            agent, task, self.name,
            correct=judge(answer, task.gt_answer), hit=False, keyword=kw,
            iterations=iters, answer=answer, latency_s=lat, lookup_s=lookup_s,
        )


@register_method("apc")
class ApcMethod(AgentMethod):
    """Algorithms 1-3. Subclasses override the store hooks to change how
    templates are matched/admitted without touching the accounting."""

    def _lookup(self, kw: str, task: Task) -> Optional[PlanTemplate]:
        return self.agent.cache.lookup(kw)

    def _admit(self, kw: str, task: Task, tpl: PlanTemplate) -> None:
        self.agent.cache.insert(kw, tpl)

    def run(self, task: Task):
        agent = self.agent
        lat = 0.0
        kw, ki, ko = agent.be.extract_keyword(task)
        lat += agent.ledger.record("keyword_extractor", ki, ko)

        t0 = time.perf_counter()
        template = self._lookup(kw, task)
        lookup_s = time.perf_counter() - t0
        lat += lookup_s

        if template is not None:  # ---- Algorithm 2: cache hit
            template.uses += 1
            answer, iters, l2 = agent._loop_adapt(task, template, full_history=False)
            lat += l2
            return record(
                agent, task, self.name,
                correct=judge(answer, task.gt_answer), hit=True, keyword=kw,
                iterations=iters, answer=answer, latency_s=lat,
                lookup_s=lookup_s,
            )

        # ---- Algorithm 3: cache miss
        return self._run_miss(task, kw, lat, lookup_s)

    def _run_miss(self, task: Task, kw: str, lat: float, lookup_s: float,
                  **extra):
        """Algorithm 3 (shared with the speculative rollback path)."""
        agent = self.agent
        answer, iters, log, l3 = agent._loop_scratch(task, large=True)
        lat += l3
        gen_s = 0.0
        if answer is not None and log.final_answer is not None:
            gi, go = agent.be.cachegen_tokens(log.raw_tokens())
            gen_s = agent.ledger.record("cache_generator", gi, go)
            miss_slots = agent.be.generalization_misses(task)
            tpl = make_template(log, kw, task.slots, miss_slots=miss_slots)
            self._admit(kw, task, tpl)
            if not agent.cfg.async_cachegen:
                lat += gen_s  # synchronous generation blocks the response
        return record(
            agent, task, self.name,
            correct=judge(answer, task.gt_answer), hit=False, keyword=kw,
            iterations=iters, answer=answer, latency_s=lat,
            lookup_s=lookup_s, gen_s=gen_s, **extra,
        )


@register_method("cascade")
class CascadeMethod(ApcMethod):
    """Exact -> fuzzy -> semantic over one plan store.

    The store's MatchPipeline resolves a keyword exactly, then by keyword
    similarity, then — using the raw task query as the lookup *context* —
    by similarity against the query each template was distilled from. A
    semantic-stage hit still goes through template adaptation (small
    planner), so unlike the ``semantic`` baseline a similar-but-different
    task reuses the PLAN, not the stale final answer.
    """

    def setup(self) -> None:
        agent = self.agent
        if not agent.cache_external:
            cfg = agent.cfg
            agent.cache = PlanCache(
                capacity=cfg.cache_capacity,
                pipeline=("exact", "fuzzy", "semantic"),
                fuzzy_threshold=cfg.fuzzy_threshold,
                semantic_threshold=cfg.semantic_threshold,
                index_backend=cfg.index_backend,
                eviction=cfg.eviction,
            )

    def _lookup(self, kw, task):
        return self.agent.cache.lookup(kw, context=task.query)

    def _admit(self, kw, task, tpl):
        self.agent.cache.insert(kw, tpl, context=task.query)


@register_method("speculative")
class SpeculativeMethod(ApcMethod):
    """Speculative plan execution on fuzzy near-hits (§4.3 latency hiding,
    AgenticCache-style reconciliation).

    An exact hit runs Algorithm 2 unchanged. A *near* hit (resolved by the
    fuzzy stage) starts executing the adapted template immediately — every
    actor round journaled as a reversible step — while the large planner
    re-derives the plan round-by-round in the background. When the plans
    agree the journal **commits** (env writes finalized, the adapted
    template promoted under the exact keyword with the
    ``unless_written_since`` token captured at lookup); when they diverge
    at round ``d > 0`` the journal **patches** (the matching executed
    prefix commits, the divergent suffix rolls back and is re-executed by
    the verified planner); divergence at round 0 **rolls back** every
    step and falls back to Algorithm 3. Serving latency on agreement is
    ``max(execute, verify)`` instead of ``verify + execute``.
    """

    def setup(self) -> None:
        agent = self.agent
        if not agent.cache_external:
            cfg = agent.cfg
            agent.cache = PlanCache(
                capacity=cfg.cache_capacity,
                fuzzy=True,
                fuzzy_threshold=cfg.fuzzy_threshold,
                index_backend=cfg.index_backend,
                eviction=cfg.eviction,
            )

    def run(self, task: Task):
        from repro.obs.attribution import collect

        agent = self.agent
        lat = 0.0
        kw, ki, ko = agent.be.extract_keyword(task)
        lat += agent.ledger.record("keyword_extractor", ki, ko)

        t0 = time.perf_counter()
        with collect() as attrib:
            template = self._lookup(kw, task)
        lookup_s = time.perf_counter() - t0
        lat += lookup_s
        if template is None:
            return self._run_miss(task, kw, lat, lookup_s)

        template.uses += 1
        stage = (attrib.get(0) or {}).get("stage", "exact")
        if stage == "exact":  # nothing to verify: plain Algorithm 2
            answer, iters, l2 = agent._loop_adapt(task, template,
                                                  full_history=False)
            lat += l2
            return record(
                agent, task, self.name,
                correct=judge(answer, task.gt_answer), hit=True, keyword=kw,
                iterations=iters, answer=answer, latency_s=lat,
                lookup_s=lookup_s,
            )
        return self._run_speculative(task, kw, template, lat, lookup_s)

    # -- the race ------------------------------------------------------

    def _round_responses(self, task: Task, n_rounds: int):
        """Reconstruct per-round actor responses from the journaled
        workspace writes (the speculative execution's real effects)."""
        ws = task.workspace
        out = []
        for r in range(n_rounds):
            prefix = f"r{r}/"
            vals = {k[len(prefix):]: ws.read(k)
                    for k in ws.keys() if k.startswith(prefix)}
            out.append({"values": vals})
        return out

    def _run_speculative(self, task: Task, kw: str, template, lat: float,
                         lookup_s: float):
        from repro.core.journal import StepJournal

        agent = self.agent
        journal = StepJournal()
        token = agent.cache.now()

        # 1) execute the adapted plan now; steps stay open in the journal
        answer, iters, exec_lat = agent._loop_adapt(
            task, template, full_history=False, journal=journal)
        executed = journal.open_steps()  # actor rounds speculatively run
        responses = self._round_responses(task, executed)

        # promotion of the near-hit under the exact keyword is deferred:
        # it lands only if the verifier agrees end-to-end, and the token
        # captured at lookup keeps a late commit from clobbering a newer
        # template (insert-if-newer, §4.3 admission race)
        admit = journal.begin_step("spec-admit")
        admit.on_commit(lambda: agent.cache.insert_batch(
            [(kw, template)], unless_written_since=token))

        # 2) verify in the background: the large planner re-derives the
        #    plan round-by-round against the speculative retrievals
        verify_lat = 0.0
        divergence = executed  # rounds 0..divergence-1 match
        for r in range(executed):
            msg, pi, po = agent.be.plan(task, responses[:r], large=True,
                                        round_idx=r)
            verify_lat += agent.ledger.record("large_planner", pi, po)
            planned = sorted(f for f in msg.op.get("retrieve", [])
                             if f in task.context)
            ran = sorted(responses[r]["values"])
            if msg.kind != "message" or planned != ran:
                divergence = r
                break

        if divergence >= executed:  # ---- plans agree: COMMIT
            journal.commit()
            lat += max(exec_lat, verify_lat)
            return record(
                agent, task, self.name,
                correct=judge(answer, task.gt_answer), hit=True, keyword=kw,
                iterations=iters, answer=answer, latency_s=lat,
                lookup_s=lookup_s, speculated=True, spec_outcome="commit",
            )

        if divergence > 0:  # ---- PATCH: keep the matching prefix
            journal.patch(keep=divergence)
            prefix_lat = exec_lat * divergence / max(1, iters)
            answer, suffix_iters, _log, suffix_lat = agent._loop_scratch(
                task, large=True, journal=journal,
                responses=responses[:divergence], start_round=divergence)
            journal.commit()  # the re-executed suffix is verified work
            lat += max(prefix_lat, verify_lat) + suffix_lat
            return record(
                agent, task, self.name,
                correct=judge(answer, task.gt_answer), hit=True, keyword=kw,
                iterations=divergence + suffix_iters, answer=answer,
                latency_s=lat, lookup_s=lookup_s,
                speculated=True, spec_outcome="patch",
            )

        # ---- ROLLBACK: divergence at round 0, nothing reusable
        journal.rollback()
        lat += verify_lat  # the loss: verification time was spent
        return self._run_miss(task, kw, lat, lookup_s,
                              speculated=True, spec_outcome="rollback")


__all__ = [
    "METHOD_REGISTRY",
    "AgentMethod",
    "ApcMethod",
    "CascadeMethod",
    "FullHistoryMethod",
    "SemanticMethod",
    "SpeculativeMethod",
    "get_method_class",
    "make_method",
    "method_names",
    "record",
    "register_method",
]

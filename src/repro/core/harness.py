"""Workload harness: run (env x method) and aggregate the paper's metrics."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.configs.apc_minion import APCDeployment, DEFAULT
from repro.core.agent_loop import AgentConfig, PlanActAgent, RunRecord
from repro.core.backends import (
    DEFAULT_QUALITY,
    DEFAULT_TOKENS,
    QualityProfile,
    SimulatedBackend,
    TokenProfile,
)
from repro.core.cache import PlanCache
from repro.core.cost_model import CostLedger
from repro.envs.workloads import get_env


@dataclass
class WorkloadResult:
    env: str
    method: str
    n: int
    accuracy: float
    cost: float
    latency_s: float
    hit_rate: float
    hit_accuracy: Optional[float]
    miss_accuracy: Optional[float]
    breakdown: Dict[str, Dict[str, float]]
    records: List[RunRecord] = field(default_factory=list)
    cache_entries: int = 0

    def row(self) -> Dict[str, Any]:
        return {
            "env": self.env,
            "method": self.method,
            "n": self.n,
            "accuracy": round(self.accuracy, 4),
            "cost": round(self.cost, 4),
            "latency_s": round(self.latency_s, 1),
            "hit_rate": round(self.hit_rate, 4),
            "hit_acc": None if self.hit_accuracy is None else round(self.hit_accuracy, 4),
            "miss_acc": None if self.miss_accuracy is None else round(self.miss_accuracy, 4),
            "cache_entries": self.cache_entries,
        }


def run_workload(
    env_name: str,
    method: str,
    n: int = 200,
    *,
    seed: int = 0,
    deployment: APCDeployment = DEFAULT,
    agent_cfg: Optional[AgentConfig] = None,
    quality: QualityProfile = DEFAULT_QUALITY,
    tokens: TokenProfile = DEFAULT_TOKENS,
    cache: Optional[PlanCache] = None,
    keep_records: bool = False,
) -> WorkloadResult:
    env = get_env(env_name)
    tasks = env.generate(n, seed=seed)
    cfg = agent_cfg or AgentConfig(method=method)
    cfg.method = method
    backend = SimulatedBackend(quality=quality, tokens=tokens, seed=seed)
    ledger = CostLedger(pricing_map=dict(deployment.pricing))
    agent = PlanActAgent(backend, ledger, cfg, cache=cache)

    records: List[RunRecord] = []
    prev_cost = 0.0
    for t in tasks:
        rec = agent.run_task(t)
        rec.cost, prev_cost = rec.cost - prev_cost, rec.cost  # per-task delta
        records.append(rec)

    hits = [r for r in records if r.hit]
    misses = [r for r in records if not r.hit]
    acc = sum(r.correct for r in records) / max(1, len(records))
    res = WorkloadResult(
        env=env_name,
        method=method,
        n=n,
        accuracy=acc,
        cost=ledger.total_cost(),
        latency_s=sum(r.latency_s for r in records),
        hit_rate=len(hits) / max(1, len(records)),
        hit_accuracy=(sum(r.correct for r in hits) / len(hits)) if hits else None,
        miss_accuracy=(sum(r.correct for r in misses) / len(misses)) if misses else None,
        breakdown=ledger.breakdown(),
        records=records if keep_records else [],
        cache_entries=len(agent.cache),
    )
    return res


# METHODS is derived from the repro.memory method registry (importing
# repro.core.methods registers the built-ins, including the beyond-paper
# `cascade` hybrid). It is resolved LIVE via module __getattr__ so a
# method registered after this module was imported still shows up in
# `harness.METHODS` — note that `from repro.core.harness import METHODS`
# snapshots at the importing module's import time, so enumerators that
# must see late registrations should call method_names() instead.
from repro.core.methods import method_names


def __getattr__(name: str):
    if name == "METHODS":
        return method_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Open-Deep-Research-style agent (the paper's SECOND architecture, §4.2).

Unlike the Minion loop (planner <-> actor), a deep-research agent runs a
multi-step research trajectory: an initial plan decomposes the task into
search/extract steps, each step may trigger RE-PLANNING, and APC caches the
*re-planning* structures — the paper's GAIA finding: initial plans rarely
recur (heterogeneous tasks) but re-planning skeletons do, so APC still cuts
cost 76% there.

Implementation: the research trajectory for intent I is [survey ->
retrieve(fields) -> verify -> synthesize]; the re-plan template caches the
retrieve/verify skeleton keyed by the intent keyword, while the survey step
(task-specific) always runs on the large planner.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.agent_loop import RunRecord
from repro.core.backends import PlanMsg, SimulatedBackend
from repro.core.cache import PlanCache
from repro.core.cost_model import CostLedger, estimate_tokens
from repro.core.template import ExecutionLog, PlanTemplate, make_template
from repro.envs.base import Task, judge

SURVEY_OUT = 700  # initial open-domain survey is long (web browsing notes)
REPLAN_OUT = 450
VERIFY_OUT = 120


@dataclass
class DeepResearchConfig:
    max_steps: int = 12
    cache_capacity: int = 100
    async_cachegen: bool = False
    seed: int = 0


class DeepResearchAgent:
    """APC wired into a survey -> (re-plan -> act)* -> synthesize loop."""

    def __init__(
        self,
        backend: SimulatedBackend,
        ledger: CostLedger,
        cfg: DeepResearchConfig = DeepResearchConfig(),
        cache: Optional[PlanCache] = None,
    ):
        self.be = backend
        self.ledger = ledger
        self.cfg = cfg
        self.cache = cache if cache is not None else PlanCache(cfg.cache_capacity)

    def run_task(self, task: Task) -> RunRecord:
        lat = 0.0
        # 1) survey: always the large planner (task-specific, uncacheable)
        lat += self.ledger.record(
            "large_planner",
            1200 + estimate_tokens(task.query),
            SURVEY_OUT,
        )
        # 2) keyword for the RE-PLANNING skeleton
        kw, ki, ko = self.be.extract_keyword(task)
        lat += self.ledger.record("keyword_extractor", ki, ko)
        t0 = time.perf_counter()
        tpl = self.cache.lookup(kw)
        lookup_s = time.perf_counter() - t0
        lat += lookup_s

        responses: List[Dict[str, Any]] = []
        log = ExecutionLog(task_query=task.query)
        answer = None
        hit = tpl is not None
        steps = 0
        for it in range(self.cfg.max_steps):
            steps += 1
            if hit:
                msg, pi, po = self.be.adapt(task, tpl, responses, round_idx=it)
                lat += self.ledger.record("small_planner", pi, po)
            else:
                msg, pi, po = self.be.plan(task, responses, large=True, round_idx=it)
                lat += self.ledger.record("large_planner", pi, REPLAN_OUT)
            if msg.kind == "answer":
                # verification pass (deep-research agents double-check)
                lat += self.ledger.record("small_planner", 400, VERIFY_OUT)
                log.final_answer = {"answer_text": msg.text, "op": msg.op}
                answer = msg.op.get("value")
                break
            resp, ai, ao = self.be.act(task, msg)
            lat += self.ledger.record("actor", ai, ao)
            responses.append(resp)
            log.append({"message": msg.text, "op": msg.op}, resp)

        gen_s = 0.0
        if not hit and answer is not None:
            gi, go = self.be.cachegen_tokens(log.raw_tokens())
            gen_s = self.ledger.record("cache_generator", gi, go)
            miss_slots = self.be.generalization_misses(task)
            self.cache.insert(kw, make_template(log, kw, task.slots,
                                                miss_slots=miss_slots))
            if not self.cfg.async_cachegen:
                lat += gen_s
        return RunRecord(
            task.id, "deep_research_apc", judge(answer, task.gt_answer), hit,
            kw, steps, answer, self.ledger.total_cost(), lat, lookup_s, gen_s,
        )


def run_deep_research(
    env_name: str = "gaia",
    n: int = 165,
    *,
    use_apc: bool = True,
    seed: int = 0,
) -> Dict[str, Any]:
    """Paper Table 1 GAIA row: Open Deep Research with/without APC."""
    from repro.configs.apc_minion import DEFAULT
    from repro.envs.workloads import get_env

    env = get_env(env_name)
    tasks = env.generate(n, seed=seed)
    be = SimulatedBackend(seed=seed)
    ledger = CostLedger(pricing_map=dict(DEFAULT.pricing))
    cache = PlanCache(100) if use_apc else PlanCache(0)  # capacity-0 = no reuse
    agent = DeepResearchAgent(be, ledger, DeepResearchConfig(seed=seed), cache)
    recs = [agent.run_task(t) for t in tasks]
    return {
        "n": n,
        "accuracy": sum(r.correct for r in recs) / n,
        "cost": ledger.total_cost(),
        "hit_rate": sum(r.hit for r in recs) / n,
        "latency_s": sum(r.latency_s for r in recs),
    }

"""Beyond-paper latency optimizations the paper names as future work (§4.3):

  * async cache generation — template distillation runs on a worker pool so
    the response path never blocks on it (TwoTierRouter wires this);
  * speculative next-query prefetch — predict the next likely keyword from
    the observed keyword bigram stream and pre-warm templates: validate the
    template for the predicted keyword is resident (or promote it in LRU
    order) before the query arrives.
"""

from __future__ import annotations

import threading
from collections import Counter, defaultdict
from typing import Callable, Dict, List, Optional

from repro.core.cache import PlanCache


class KeywordPredictor:
    """First-order Markov model over the keyword stream."""

    def __init__(self):
        self._bigram: Dict[str, Counter] = defaultdict(Counter)
        self._prev: Optional[str] = None
        self._lock = threading.Lock()

    def observe(self, keyword: str) -> None:
        with self._lock:
            if self._prev is not None:
                self._bigram[self._prev][keyword] += 1
            self._prev = keyword

    def predict(self, k: int = 3) -> List[str]:
        with self._lock:
            if self._prev is None or self._prev not in self._bigram:
                return []
            return [kw for kw, _ in self._bigram[self._prev].most_common(k)]


class SpeculativePrefetcher:
    """Pre-warms the plan cache for predicted next keywords.

    ``generate_fn(keyword)`` produces a template offline (e.g. replaying a
    stored exemplar task through the large planner during idle cycles);
    when it's None the prefetcher only performs an LRU *touch* so hot
    templates survive eviction pressure.
    """

    def __init__(
        self,
        cache: PlanCache,
        predictor: KeywordPredictor,
        generate_fn: Optional[Callable[[str], object]] = None,
    ):
        self.cache = cache
        self.predictor = predictor
        self.generate_fn = generate_fn
        self.prefetches = 0
        self.generated = 0

    def on_request(self, keyword: str) -> None:
        self.predictor.observe(keyword)
        for kw in self.predictor.predict():
            if kw in self.cache:
                self.cache.lookup(kw)  # LRU touch keeps it resident
                self.prefetches += 1
            elif self.generate_fn is not None:
                tpl = self.generate_fn(kw)
                if tpl is not None:
                    self.cache.insert(kw, tpl)
                    self.generated += 1
                    self.prefetches += 1

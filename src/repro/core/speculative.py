"""Beyond-paper latency optimizations the paper names as future work (§4.3):

  * async cache generation — template distillation runs on a worker pool so
    the response path never blocks on it (TwoTierRouter wires this);
  * speculative next-query prefetch — predict the next likely keyword from
    the observed keyword bigram stream and pre-warm templates: validate the
    template for the predicted keyword is resident (or promote it in LRU
    order) before the query arrives;
  * speculative near-hit execution — on a fuzzy/semantic near-hit the
    router serves the adapted template immediately while the large planner
    verifies in the background; :class:`PlanSpeculator` owns the
    commit/rollback protocol (one :class:`~repro.core.journal.StepJournal`
    per speculation, so out-of-order verify completions are safe), and the
    verify task rides the router's cachegen pool — under ``repro.sim`` that
    pool is a set of scheduler clients, so the seeded scheduler owns the
    verify-vs-execute race.
"""

from __future__ import annotations

import threading
from collections import Counter, defaultdict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cache import PlanCache
from repro.core.journal import StepJournal


class KeywordPredictor:
    """First-order Markov model over the keyword stream."""

    def __init__(self):
        self._bigram: Dict[str, Counter] = defaultdict(Counter)
        self._prev: Optional[str] = None
        self._lock = threading.Lock()

    def observe(self, keyword: str) -> None:
        with self._lock:
            if self._prev is not None:
                self._bigram[self._prev][keyword] += 1
            self._prev = keyword

    def predict(self, k: int = 3) -> List[str]:
        with self._lock:
            if self._prev is None or self._prev not in self._bigram:
                return []
            return [kw for kw, _ in self._bigram[self._prev].most_common(k)]


class SpeculativePrefetcher:
    """Pre-warms the plan cache for predicted next keywords.

    ``generate_fn(keyword)`` produces a template offline (e.g. replaying a
    stored exemplar task through the large planner during idle cycles);
    when it's None the prefetcher only performs an LRU *touch* so hot
    templates survive eviction pressure.
    """

    def __init__(
        self,
        cache: PlanCache,
        predictor: KeywordPredictor,
        generate_fn: Optional[Callable[[str], object]] = None,
    ):
        self.cache = cache
        self.predictor = predictor
        self.generate_fn = generate_fn
        self.prefetches = 0
        self.generated = 0

    def on_request(self, keyword: str) -> None:
        self.predictor.observe(keyword)
        for kw in self.predictor.predict():
            if kw in self.cache:
                self.cache.lookup(kw)  # LRU touch keeps it resident
                self.prefetches += 1
            elif self.generate_fn is not None:
                tpl = self.generate_fn(kw)
                if tpl is not None:
                    self.cache.insert(kw, tpl)
                    self.generated += 1
                    self.prefetches += 1


class PlanSpeculator:
    """Commit/rollback controller for near-hit speculation.

    Each speculation gets its own :class:`StepJournal` (verify tasks
    complete in scheduler order, not begin order, so a shared
    prefix-commit journal would deadlock the race): ``begin`` applies the
    eager env effect through the journal and defers the cache admission
    and commit-side metric bumps; ``resolve`` commits (verifier agreed)
    or rolls back (verifier disagreed) — unless the rollback guard is
    ablated (``rollback_enabled=False``), in which case a disagreeing
    speculation *commits anyway*, the leak the sim's ``spec_leak`` oracle
    exists to catch.

    ``pending()`` is the liveness surface: every speculation begun must
    be resolved by quiescence (the ``spec_liveness`` oracle), which the
    router guarantees by falling back to a synchronous verify when the
    pool rejects the task — unless *that* guard is ablated.

    Single-owner per the journal contract: begin/resolve run on one
    logical thread (the sim scheduler linearizes ops; the threaded router
    resolves under its submit lock).
    """

    def __init__(self, *, rollback_enabled: bool = True):
        self.rollback_enabled = rollback_enabled
        self._next_id = 0
        self._pending: Dict[int, Tuple[str, StepJournal]] = {}
        self.begun = 0
        self.commits = 0
        self.rollbacks = 0
        self.forced_commits = 0  # ablation only: disagreed but committed

    def begin(
        self,
        kw: str,
        *,
        effect: Optional[Callable[[], Callable[[], None]]] = None,
        on_commit: Sequence[Callable[[], None]] = (),
    ) -> int:
        """Open a speculation on ``kw``. ``effect`` applies the eager env
        write and returns its compensation; ``on_commit`` actions (cache
        admission with its pre-captured ``unless_written_since`` token,
        metric increments) run only if the verifier agrees."""
        journal = StepJournal()
        step = journal.begin_step(f"spec:{kw}")
        if effect is not None:
            step.applied(effect())
        for action in on_commit:
            step.on_commit(action)
        spec_id = self._next_id
        self._next_id += 1
        self._pending[spec_id] = (kw, journal)
        self.begun += 1
        return spec_id

    def resolve(self, spec_id: int, agree: bool) -> str:
        """Complete a speculation: returns "commit" or "rollback"."""
        kw, journal = self._pending.pop(spec_id)
        if agree or not self.rollback_enabled:
            journal.commit()
            if agree:
                self.commits += 1
            else:
                self.forced_commits += 1  # the ablated leak
            return "commit"
        journal.rollback()
        self.rollbacks += 1
        return "rollback"

    def pending(self) -> int:
        return len(self._pending)

    def pending_keys(self) -> List[str]:
        return sorted(kw for kw, _ in self._pending.values())

    def stats(self) -> Dict[str, Any]:
        return {
            "begun": self.begun,
            "commits": self.commits,
            "rollbacks": self.rollbacks,
            "forced_commits": self.forced_commits,
            "pending": self.pending(),
        }

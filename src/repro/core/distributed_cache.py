"""Distributed plan cache: consistent-hash sharding + replication.

At 1000+ nodes the plan cache outgrows a single frontend: this shards
keywords across cache nodes with a consistent-hash ring (virtual nodes), so
elastic add/remove of cache servers moves only ~K/N keys. Each key is
replicated onto R successive ring nodes; reads fall through replicas on
node failure (fault tolerance), writes go to all live replicas.

In-process shards stand in for network nodes (the container has one host);
the interface (lookup/insert/add_node/remove_node/mark_down) is what a
networked implementation would expose.
"""

from __future__ import annotations

import bisect
import hashlib
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.core.cache import CacheStats, PlanCache


def _hash(s: str) -> int:
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(), "big")


class HashRing:
    def __init__(self, vnodes: int = 64):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, str]] = []
        self._nodes: set = set()

    def add(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for v in range(self.vnodes):
            self._ring.append((_hash(f"{node}#{v}"), node))
        self._ring.sort()

    def remove(self, node: str) -> None:
        self._nodes.discard(node)
        self._ring = [(h, n) for h, n in self._ring if n != node]

    def nodes_for(self, key: str, r: int = 1) -> List[str]:
        """r distinct nodes clockwise from the key's hash."""
        if not self._ring:
            return []
        h = _hash(key)
        i = bisect.bisect_right(self._ring, (h, "￿")) % len(self._ring)
        out: List[str] = []
        j = i
        while len(out) < min(r, len(self._nodes)):
            node = self._ring[j % len(self._ring)][1]
            if node not in out:
                out.append(node)
            j += 1
        return out

    @property
    def nodes(self) -> List[str]:
        return sorted(self._nodes)


class DistributedPlanCache:
    """PlanCache-compatible facade over sharded, replicated cache nodes."""

    def __init__(
        self, n_nodes: int = 4, *, replication: int = 2, capacity_per_node: int = 64
    ):
        self.ring = HashRing()
        self.replication = replication
        self.capacity_per_node = capacity_per_node
        self.shards: Dict[str, PlanCache] = {}
        self.down: set = set()
        self.stats = CacheStats()
        self._lock = threading.RLock()
        for i in range(n_nodes):
            self.add_node(f"cache-{i}")

    # -- membership (elastic scaling) -----------------------------------

    def add_node(self, name: str) -> None:
        with self._lock:
            if name in self.shards:
                self.down.discard(name)
                return
            self.shards[name] = PlanCache(capacity=self.capacity_per_node)
            self.ring.add(name)
            self._rebalance()

    def remove_node(self, name: str) -> None:
        """Graceful removal: re-home this node's keys before dropping it."""
        with self._lock:
            if name not in self.shards:
                return
            old = self.shards.pop(name)
            self.ring.remove(name)
            self.down.discard(name)
            for k in old.keys():
                v = old.lookup(k)
                if v is not None:
                    self._insert_unlocked(k, v)

    def mark_down(self, name: str) -> None:
        """Crash-failure: node unreachable, data NOT migrated (replicas serve)."""
        with self._lock:
            self.down.add(name)

    def mark_up(self, name: str) -> None:
        with self._lock:
            self.down.discard(name)

    def _rebalance(self) -> None:
        """After adding a node, re-home keys whose primary moved."""
        moves = []
        for node, shard in self.shards.items():
            for k in shard.keys():
                owners = self.ring.nodes_for(k, self.replication)
                if node not in owners:
                    v = shard.lookup(k)
                    moves.append((node, k, v))
        for node, k, v in moves:
            # remove from stale owner, reinsert at the right owners
            self.shards[node]._store.pop(k, None)
            self._insert_unlocked(k, v)

    # -- cache ops --------------------------------------------------------

    def _live(self, names: List[str]) -> List[str]:
        return [n for n in names if n not in self.down and n in self.shards]

    def lookup(self, keyword: str) -> Optional[Any]:
        with self._lock:
            owners = self._live(self.ring.nodes_for(keyword, self.replication))
            for n in owners:  # fall through replicas on miss/failure
                v = self.shards[n].lookup(keyword)
                if v is not None:
                    self.stats.hits += 1
                    return v
            self.stats.misses += 1
            return None

    def _insert_unlocked(self, keyword: str, value: Any) -> None:
        owners = self._live(self.ring.nodes_for(keyword, self.replication))
        for n in owners:
            self.shards[n].insert(keyword, value)

    def insert(self, keyword: str, value: Any) -> None:
        with self._lock:
            self._insert_unlocked(keyword, value)
            self.stats.inserts += 1

    def __contains__(self, keyword: str) -> bool:
        return self.lookup(keyword) is not None

    def __len__(self) -> int:
        with self._lock:
            seen = set()
            for n, s in self.shards.items():
                if n not in self.down:
                    seen.update(s.keys())
            return len(seen)

    def keys(self) -> List[str]:
        with self._lock:
            seen = set()
            for n, s in self.shards.items():
                if n not in self.down:
                    seen.update(s.keys())
            return sorted(seen)

    def load_by_node(self) -> Dict[str, int]:
        return {n: len(s) for n, s in sorted(self.shards.items())}

"""Distributed plan cache: consistent-hash sharding + replication.

At 1000+ nodes the plan cache outgrows a single frontend: this shards
keywords across cache nodes with a consistent-hash ring (virtual nodes), so
elastic add/remove of cache servers moves only ~K/N keys. Each key is
replicated onto R successive ring nodes; reads fall through replicas on
node failure (fault tolerance), writes go to all live replicas.

``DistributedPlanCache`` implements the same batch-native
:class:`repro.memory.protocol.PlanStore` protocol as ``PlanCache`` — the
router and harness program against the protocol and never probe for
capabilities. Each shard is a full PlanCache, so with ``fuzzy=True`` every
shard owns a private ``repro.index`` similarity index scoped to its local
keys; ``index_backend="device"`` gives each shard its own device-resident
embedding bank, making the grouped ``lookup_batch`` fan-out one
resident-bank device call per probed shard per tier. Eviction policy
(``eviction="lru" | "lfu" | "cost"``) and TTL are forwarded to every shard.

Replicated writes embed each key exactly ONCE: the facade embeds the wave
and ships ``(key, vector)`` pairs to every replica shard, instead of each
shard's index re-embedding the key privately.

In-process shards stand in for network nodes (the container has one host);
the interface (lookup/insert/add_node/remove_node/mark_down/restart_node)
is what a networked implementation would expose.

Failure semantics (exercised by the ``repro.sim`` deterministic-simulation
harness):

* Every per-shard batch call goes through an injectable ``interceptor``
  seam. A networked deployment would put the RPC client here; the sim
  installs a fault injector that can raise :class:`ShardUnavailable`
  (crash-failure discovered at call time, unlike ``mark_down`` which
  models a failure the membership layer already knows about) or defer
  replica writes (replica lag).
* GUARD — crash fallthrough: when a shard call fails mid-lookup, the
  affected keywords stay pending and fall through to the next replica
  tier instead of being dropped as misses. Ablatable via
  ``ablate={"crash_fallthrough"}`` so the sim's durability oracle can
  demonstrate it catches the regression.
* GUARD — synchronous replica acks (``ack_policy="all"``, the default):
  ``insert_batch`` returns only after every live owner applied the wave,
  so a read that falls through to any replica observes the acked version.
  ``ack_policy="primary"`` is the ablation: replica writes are handed to
  ``interceptor.defer`` (applied after an injected lag), opening the
  stale-read window the sim's linearizability oracle catches.
* GUARD — crash-recovery read-repair: ``restart_node`` brings a node back
  EMPTY (process restart loses in-memory state) and, with
  ``recover=True``, re-pulls the keys it owns from peer replicas before
  serving, restoring the replication factor.
* GUARD — churn re-homing: every ring change moves data with it.
  ``add_node`` re-homes keys whose owner set changed (``_rebalance``) and
  ``remove_node`` drains a leaving node's keys back to their new owners
  before dropping it. Ablatable via ``ablate={"churn_rehome"}`` (joins
  don't rebalance, drains drop their data) so the sim's
  ``membership_churn`` durability oracle can demonstrate it catches the
  regression.
* GUARD — fuzzy scatter: with fuzzy shards, a lookup probes the ring
  owners *and then every remaining live shard*, because a similar key
  hashes to its own owners, not the query's. Ablatable via
  ``ablate={"fuzzy_scatter"}`` (probe the query's owners only) so the
  sim's similarity-aware paraphrase oracle can catch the lost-resolution
  regression.

Control-plane ops (``keys``/``__len__``/``autotune``/``clear`` and the
membership scans behind ``_rebalance``/``remove_node``/``restart_node``)
go through the same per-shard interceptor seam as the data plane: in a
networked deployment they pay RPCs and can fail them, and the sim charges
and crashes them accordingly. An unreachable shard is skipped — its keys
are invisible to ``keys()``/``len()``, it keeps stale data across
``clear()`` until its next restart wipes it, and it can neither donate
nor receive re-homed keys during membership changes.
"""

from __future__ import annotations

import bisect
import hashlib
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cache import CacheStats, PlanCache
from repro.index.bank import embed, embed_batch
from repro.memory.protocol import PlanStoreBase
from repro.obs import MetricsRegistry, collect, deposit, trace_span
from repro.obs.names import (
    SPAN_DCACHE_INSERT,
    SPAN_DCACHE_LOOKUP,
    SPAN_DCACHE_TIER,
    SPAN_SHARD_CALL,
)


class ShardUnavailable(RuntimeError):
    """A shard call failed at dispatch time (crash discovered by the RPC
    layer, not yet by membership). Raised by interceptors, never by the
    in-process shards themselves."""


def _hash(s: str) -> int:
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(), "big")


class HashRing:
    def __init__(self, vnodes: int = 64):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, str]] = []
        self._nodes: set = set()

    def add(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for v in range(self.vnodes):
            self._ring.append((_hash(f"{node}#{v}"), node))
        self._ring.sort()

    def remove(self, node: str) -> None:
        self._nodes.discard(node)
        self._ring = [(h, n) for h, n in self._ring if n != node]

    def nodes_for(self, key: str, r: int = 1) -> List[str]:
        """r distinct nodes clockwise from the key's hash."""
        if not self._ring:
            return []
        h = _hash(key)
        i = bisect.bisect_right(self._ring, (h, "￿")) % len(self._ring)
        out: List[str] = []
        j = i
        while len(out) < min(r, len(self._nodes)):
            node = self._ring[j % len(self._ring)][1]
            if node not in out:
                out.append(node)
            j += 1
        return out

    @property
    def nodes(self) -> List[str]:
        return sorted(self._nodes)


class DistributedPlanCache(PlanStoreBase):
    """PlanStore-conformant facade over sharded, replicated cache nodes."""

    def __init__(
        self,
        n_nodes: int = 4,
        *,
        replication: int = 2,
        capacity_per_node: int = 64,
        fuzzy: bool = False,
        fuzzy_threshold: float = 0.8,
        index_backend: str = "auto",
        eviction: str = "lru",
        ttl_s: Optional[float] = None,
        clock: Optional[Callable[[], float]] = None,
        interceptor: Optional[Any] = None,
        ack_policy: str = "all",
        ablate: Sequence[str] = (),
        cold_dir: Optional[str] = None,
        cold_budget_tokens: int = 160,
        cold_keep_last: int = 8,
        obs: Optional[MetricsRegistry] = None,
    ):
        if not isinstance(eviction, str):
            # a policy INSTANCE would be shared bookkeeping across shards
            raise TypeError("DistributedPlanCache takes an eviction policy name")
        if ack_policy not in ("all", "primary"):
            raise ValueError(f"ack_policy must be 'all' or 'primary', got {ack_policy!r}")
        if ack_policy == "primary" and not callable(getattr(interceptor, "defer", None)):
            # without a defer channel the 'primary' ablation would silently
            # degrade to synchronous 'all' semantics — refuse instead
            raise ValueError(
                "ack_policy='primary' requires an interceptor with a "
                "defer(node, fn) channel to carry the lagged replica writes"
            )
        self.ring = HashRing()
        self.replication = replication
        self.capacity_per_node = capacity_per_node
        # each shard owns a private repro.index similarity index; lookups
        # fan out per-shard so the fuzzy scan never spans the global key set
        self.fuzzy = fuzzy
        self.fuzzy_threshold = fuzzy_threshold
        self.index_backend = index_backend
        self.eviction = eviction
        self.ttl_s = ttl_s
        # the injectable clock seam: store the function (wall clock only as
        # the default REFERENCE); every read goes through self.clock()
        self.clock = clock if clock is not None else time.time
        self.interceptor = interceptor
        self.ack_policy = ack_policy
        self.ablate = frozenset(ablate)
        # cold persistent tier (repro.memory.tiered): every shard gets its
        # own segment directory under cold_dir — spill/promote stay
        # shard-local, so they ride the same interceptor seam as the
        # lookup/insert calls that trigger them
        self.cold_dir = cold_dir
        self.cold_budget_tokens = cold_budget_tokens
        self.cold_keep_last = cold_keep_last
        self.shards: Dict[str, PlanCache] = {}
        self.down: set = set()
        # one registry spans the facade and every shard: shard series carry
        # a ``shard=<name>`` label, the facade's aggregate stats none
        self.obs = obs if obs is not None else MetricsRegistry()
        self.stats = CacheStats(self.obs)
        self._lock = threading.RLock()
        for i in range(n_nodes):
            self.add_node(f"cache-{i}")

    # -- membership (elastic scaling) -----------------------------------

    def add_node(self, name: str) -> None:
        with self._lock:
            if name in self.shards:
                self.down.discard(name)
                return
            self.shards[name] = PlanCache(
                capacity=self.capacity_per_node,
                fuzzy=self.fuzzy,
                fuzzy_threshold=self.fuzzy_threshold,
                index_backend=self.index_backend,
                eviction=self.eviction,
                ttl_s=self.ttl_s,
                clock=self.clock,
                # the evict-after-wave guard ablation reaches every shard,
                # including ones created by later add_node/restart_node
                evict_during_wave="evict_after_wave" in self.ablate,
                # ABLATION (ttl_expiry): shards serve expired entries
                serve_expired="ttl_expiry" in self.ablate,
                cold_dir=(None if self.cold_dir is None
                          else os.path.join(self.cold_dir, name)),
                cold_budget_tokens=self.cold_budget_tokens,
                cold_keep_last=self.cold_keep_last,
                # ABLATION (cold_gc_refcount): segments age-rotate even
                # while the manifest references them — the lost-template
                # regression the sim's cold_tier durability oracle catches
                cold_refcount_gc="cold_gc_refcount" not in self.ablate,
                obs=self.obs,
                obs_labels={"shard": name},
            )
            self.ring.add(name)
            if "churn_rehome" not in self.ablate:
                # GUARD (churn re-homing): a join immediately re-homes the
                # keys whose owner set the ring change moved
                self._rebalance()

    def remove_node(self, name: str) -> None:
        """Graceful removal: re-home this node's keys before dropping it.

        The drain scan goes through the ``_shard_call`` seam; a node that
        turns out to be unreachable cannot donate its keys, so it is
        dropped crash-style (its data is lost — replicas still hold the
        replicated copies). With ``"churn_rehome"`` in ``ablate`` the
        re-home is skipped entirely (the data-loss regression the sim's
        ``membership_churn`` durability oracle catches)."""
        with self._lock:
            if name not in self.shards:
                return
            shard = self.shards[name]
            pairs: Optional[List[Tuple[str, Any]]] = None
            if "churn_rehome" not in self.ablate:
                try:
                    pairs = self._shard_call(
                        name, "drain_scan", shard.snapshot_items
                    )
                except ShardUnavailable:
                    pairs = None  # unreachable: crash-style removal
            self.shards.pop(name)
            self.ring.remove(name)
            self.down.discard(name)
            for k, v in pairs or ():
                self._insert_unlocked(k, v)

    def mark_down(self, name: str) -> None:
        """Crash-failure: node unreachable, data NOT migrated (replicas serve)."""
        with self._lock:
            self.down.add(name)

    def mark_up(self, name: str) -> None:
        with self._lock:
            self.down.discard(name)

    def restart_node(self, name: str, *, recover: bool = True) -> int:
        """Crash-recovery hook: the node's process restarts EMPTY (a crash
        loses in-memory cache state) and rejoins. With ``recover=True`` it
        read-repairs the keys it owns from peer replicas before serving —
        the guard that restores the replication factor after a crash.
        Repair reads and the repair write go through the ``_shard_call``
        seam like any other shard traffic: an unreachable peer simply
        cannot donate repair data. Returns the number of repaired entries."""
        with self._lock:
            if name not in self.shards:
                self.add_node(name)
                return 0
            shard = self.shards[name]
            shard.clear()
            self.down.discard(name)
            if not recover:
                return 0
            repaired: List[Tuple[str, Any]] = []
            seen: set = set()
            for peer in sorted(self.shards):
                if peer == name or peer in self.down:
                    continue
                other = self.shards[peer]
                try:
                    # one-lock snapshot with peek semantics: the repair scan
                    # must not perturb the peer's recency/frequency state
                    pairs = self._shard_call(
                        peer, "repair_scan", other.snapshot_items
                    )
                except ShardUnavailable:
                    continue
                for k, v in pairs:
                    if k in seen:
                        continue
                    if name in self.ring.nodes_for(k, self.replication):
                        repaired.append((k, v))
                        seen.add(k)
            if repaired:
                try:
                    # fuzzy shards re-embed the repaired keys here: peers
                    # don't expose their index vectors, and crash recovery
                    # is rare enough that the embed-once invariant is only
                    # enforced on the hot (insert_batch) write path
                    self._shard_call(
                        name, "insert_batch",
                        lambda: shard.insert_batch(repaired),
                    )
                except ShardUnavailable:
                    return 0  # the restarted node died again mid-repair
            return len(repaired)

    def _rebalance(self) -> None:
        """After a ring change, re-home keys whose owner set moved.

        Scans every shard through the ``_shard_call`` seam with ``peek``
        semantics (``snapshot_items``: no hit/recency perturbation); an
        unreachable shard keeps its keys where they are — they stay
        invisible to the new owners until the node restarts and
        read-repairs, exactly like a networked rebalance that cannot
        reach a peer."""
        moves = []
        for node in list(self.shards):
            shard = self.shards[node]
            try:
                pairs = self._shard_call(
                    node, "rebalance_scan", shard.snapshot_items
                )
            except ShardUnavailable:
                continue
            for k, v in pairs:
                if node not in self.ring.nodes_for(k, self.replication):
                    moves.append((node, k, v))
        for node, k, v in moves:
            # remove from stale owner (keeps its fuzzy index in sync),
            # reinsert at the right owners. The re-home must happen even
            # when the retire RPC fails — the value is already in hand,
            # and skipping the insert would orphan the key on a node its
            # new owners never probe; the unretired stale copy dies at
            # that node's next restart (remove()'s tombstone-free
            # semantics)
            try:
                self._shard_call(
                    node, "remove",
                    lambda s=self.shards[node], k=k: s.remove(k),
                )
            except ShardUnavailable:
                pass
            self._insert_unlocked(k, v)

    # -- cache ops --------------------------------------------------------

    def _shard_call(self, node: str, op: str, fn: Callable[[], Any]) -> Any:
        """Every per-shard batch call funnels through here — the seam where
        a networked deployment dispatches an RPC, where the sim's fault
        injector raises :class:`ShardUnavailable` / charges latency, and
        where tracing wraps all data- and control-plane shard traffic in
        one ``dcache.shard_call`` span."""
        with trace_span(SPAN_SHARD_CALL, node=node, op=op):
            if self.interceptor is not None:
                return self.interceptor.call(node, op, fn)
            return fn()

    def _live(self, names: List[str]) -> List[str]:
        return [n for n in names if n not in self.down and n in self.shards]

    def _probe_order(self, keyword: str) -> List[str]:
        """Ring owners first; with fuzzy shards, scatter to the remaining
        live nodes — a similar key hashes to *its own* owners, not the
        query's, so fuzzy resolution must reach every shard's index (each
        shard still scans only its local keys; in a networked deployment
        this fan-out runs in parallel)."""
        owners = self._live(self.ring.nodes_for(keyword, self.replication))
        if self.fuzzy and "fuzzy_scatter" not in self.ablate:
            # GUARD (fuzzy scatter); the ablation probes the query's own
            # ring owners only — the lost-paraphrase-resolution regression
            # the sim's similarity-aware oracle catches
            owners += [
                n for n in sorted(self.shards)
                if n not in self.down and n not in owners
            ]
        return owners

    def lookup_batch(
        self,
        keywords: Sequence[str],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
    ) -> List[Optional[Any]]:
        """Batched lookups under one lock acquisition (router admission).

        Tier-by-tier grouped fan-out: tier 0 groups keywords by primary
        owner so each shard's fuzzy index answers its group in one batched
        call (on the ``device`` backend, one resident-bank device call per
        shard); every subsequent replica/fuzzy-scatter tier batches the
        *still-missing* keywords the same way, so the fallthrough path is
        also O(tiers) shard calls instead of one per keyword. Probe order
        per keyword is identical to the singular ``lookup`` (which IS this
        path with a batch of one), and ``contexts`` ride along to each
        shard's match pipeline.

        GUARD (crash fallthrough): a shard call that raises
        :class:`ShardUnavailable` leaves its keywords PENDING — they retry
        on the next replica tier exactly as if the shard had answered
        "miss", so a crashed-but-not-yet-marked-down node costs one wasted
        probe, never a durability hole. With ``"crash_fallthrough"`` in
        ``ablate`` the failed shard's keywords are dropped as misses (the
        regression the sim's durability oracle catches).
        """
        if contexts is None:
            contexts = [None] * len(keywords)
        with trace_span(SPAN_DCACHE_LOOKUP, n=len(keywords)) as lsp, \
                self._lock:
            out: List[Optional[Any]] = [None] * len(keywords)
            owners_of = [self._probe_order(k) for k in keywords]
            pending = list(range(len(keywords)))
            dropped: set = set()
            tier = 0
            while pending:
                by_node: Dict[str, List[int]] = {}
                for i in pending:
                    if tier < len(owners_of[i]):
                        by_node.setdefault(owners_of[i][tier], []).append(i)
                if not by_node:
                    break
                with trace_span(SPAN_DCACHE_TIER, tier=tier,
                                pending=len(pending),
                                shards=len(by_node)):
                    for node, idxs in sorted(by_node.items()):
                        shard = self.shards[node]
                        kws = [keywords[i] for i in idxs]
                        ctxs = [contexts[i] for i in idxs]
                        try:
                            # a nested collector shadows the router's for
                            # exactly this shard call; resolved indices are
                            # re-deposited at the facade's batch positions
                            # with the answering node and replica tier
                            with collect() as shard_attrib:
                                vals = self._shard_call(
                                    node, "lookup_batch",
                                    lambda s=shard, k=kws, c=ctxs:
                                        s.lookup_batch(k, contexts=c),
                                )
                        except ShardUnavailable:
                            if "crash_fallthrough" in self.ablate:
                                dropped.update(idxs)  # served as misses (BUG)
                            continue  # guard: keywords stay pending -> next tier
                        for j, (i, v) in enumerate(zip(idxs, vals)):
                            out[i] = v
                            if v is not None:
                                deposit(i, node=node, replica_tier=tier,
                                        **shard_attrib.get(j))
                pending = [
                    i for i in pending
                    if out[i] is None and i not in dropped
                    and tier + 1 < len(owners_of[i])
                ]
                tier += 1
            hits = sum(1 for v in out if v is not None)
            for v in out:
                if v is None:
                    self.stats.misses += 1
                else:
                    self.stats.hits += 1
            lsp.set(hits=hits, tiers=tier)
            return out

    def _insert_unlocked(
        self,
        keyword: str,
        value: Any,
        context: Optional[str] = None,
        vector: Optional[Any] = None,
    ) -> None:
        # NOTE: this path serves control-plane re-homing only (_rebalance /
        # remove_node) — membership moves are deliberately synchronous and
        # outside the ack_policy contract, which governs the client write
        # path (insert/insert_batch, where PlanStoreBase.insert delegates)
        owners = self._live(self.ring.nodes_for(keyword, self.replication))
        if self.fuzzy and vector is None and owners:
            vector = embed(keyword)  # embed once, ship to every replica
        for n in owners:
            shard = self.shards[n]
            try:
                self._shard_call(
                    n, "insert",
                    lambda s=shard: s.insert(
                        keyword, value, context=context, vector=vector
                    ),
                )
            except ShardUnavailable:
                continue  # write lands on the remaining owners

    def now(self) -> float:
        """The facade's clock (shared with every shard) — capture before a
        read whose derived wave inserts with ``unless_written_since``."""
        return self.clock()

    def arm_cold_crash(self, waves: int) -> None:
        """Sim fault seam: arm every shard's cold tier to crash between
        segment write and manifest commit on its next ``waves`` spill
        waves (no-op for shards without a cold tier)."""
        with self._lock:
            for shard in self.shards.values():
                if shard.cold is not None:
                    shard.cold.arm_crash_after_segment(waves)

    def insert_batch(
        self,
        items: Sequence[Tuple[str, Any]],
        *,
        contexts: Optional[Sequence[Optional[str]]] = None,
        vectors: Optional[Any] = None,
        unless_written_since: Optional[float] = None,
    ) -> None:
        """Admission-wave insert: group by owner shard so each shard takes
        the wave in one ``insert_batch`` call (one device scatter per shard
        on the ``device`` backend). With fuzzy shards the wave is embedded
        ONCE here and the (key, vector) pairs are replicated, so an R-way
        replicated key never embeds R times.

        GUARD (synchronous replica acks): with ``ack_policy="all"`` every
        live owner applies the wave before this call returns, so a reader
        falling through to any replica observes the acked version. The
        ``"primary"`` ablation acks after the per-key PRIMARY write only
        and defers replica application to the interceptor's lag queue —
        the stale-read window the sim's linearizability oracle catches. A
        replica that raises :class:`ShardUnavailable` is skipped (the wave
        lands on the remaining owners)."""
        items = list(items)
        if contexts is None:
            contexts = [None] * len(items)
        with trace_span(SPAN_DCACHE_INSERT, n=len(items)), self._lock:
            if self.fuzzy and vectors is None and items:
                vectors = embed_batch([kw for kw, _ in items])
            primary_by_node: Dict[str, List[int]] = {}
            replica_by_node: Dict[str, List[int]] = {}
            for j, (kw, _) in enumerate(items):
                owners = self._live(self.ring.nodes_for(kw, self.replication))
                for rank, n in enumerate(owners):
                    tgt = primary_by_node if rank == 0 else replica_by_node
                    tgt.setdefault(n, []).append(j)

            def apply(node: str, idxs: List[int]) -> None:
                shard = self.shards[node]
                shard.insert_batch(
                    [items[j] for j in idxs],
                    contexts=[contexts[j] for j in idxs],
                    vectors=None if vectors is None else [vectors[j] for j in idxs],
                    # conditional admission is enforced per shard: each
                    # shard compares the token against ITS entry timestamps
                    # (all shards share the facade's clock)
                    unless_written_since=unless_written_since,
                )

            for n, idxs in primary_by_node.items():
                try:
                    self._shard_call(n, "insert_batch",
                                     lambda n=n, idxs=idxs: apply(n, idxs))
                except ShardUnavailable:
                    continue  # replicas still take the wave below
            defer = getattr(self.interceptor, "defer", None)
            for n, idxs in replica_by_node.items():
                if self.ack_policy == "primary" and defer is not None:
                    # ABLATION: ack without the replica -> lag window
                    defer(n, lambda n=n, idxs=idxs: apply(n, idxs))
                    continue
                try:
                    self._shard_call(n, "insert_batch",
                                     lambda n=n, idxs=idxs: apply(n, idxs))
                except ShardUnavailable:
                    continue
            self.stats.inserts += len(items)

    def remove(self, keyword: str) -> bool:
        """Delete from every shard holding the key (owners may be stale
        after membership churn). True if any replica held it. A shard that
        is unreachable keeps its stale copy until its next restart wipes
        it — the same tombstone-free semantics a networked delete has."""
        with self._lock:
            removed = False
            for name in sorted(self.shards):
                shard = self.shards[name]
                try:
                    r = self._shard_call(
                        name, "remove", lambda s=shard: s.remove(keyword)
                    )
                except ShardUnavailable:
                    continue
                removed = r or removed
            return removed

    def clear(self) -> None:
        """Wipe every *reachable* shard. Clears go through the interceptor
        seam like any other shard call: an unreachable node keeps its stale
        data until its next restart wipes it (the same tombstone-free
        semantics ``remove`` has)."""
        with self._lock:
            for name in list(self.shards):
                shard = self.shards[name]
                try:
                    self._shard_call(name, "clear", shard.clear)
                except ShardUnavailable:
                    continue
            # reset the shared-registry view in place (see PlanCache.clear)
            self.stats.reset()

    def autotune(self, **thresholds) -> List[str]:
        """Run one index auto-tune step on every reachable shard (see
        PlanCache). Per-shard calls pay the interceptor seam; an
        unreachable shard simply skips this tuning round."""
        with self._lock:
            actions: List[str] = []
            for name, shard in sorted(self.shards.items()):
                try:
                    acts = self._shard_call(
                        name, "autotune",
                        lambda s=shard: s.autotune(**thresholds),
                    )
                except ShardUnavailable:
                    continue
                for act in acts:
                    actions.append(f"{name}/{act}")
            return actions

    def __contains__(self, keyword: str) -> bool:
        # exact membership, no fuzzy resolution and no stats mutation
        # (mirrors PlanCache.__contains__)
        with self._lock:
            owners = self._live(self.ring.nodes_for(keyword, self.replication))
            return any(keyword in self.shards[n] for n in owners)

    def __len__(self) -> int:
        """Distinct reachable keys; pays one seam call per live shard."""
        return len(self.keys())

    def keys(self) -> List[str]:
        """Union of every reachable shard's live keys. The per-shard
        enumeration goes through the interceptor seam — a crashed-but-not-
        marked-down shard contributes nothing (its keys are unreachable,
        exactly what a networked key scan would observe)."""
        with self._lock:
            seen = set()
            for n in list(self.shards):
                if n in self.down:
                    continue
                shard = self.shards[n]
                try:
                    ks = self._shard_call(n, "keys", shard.keys)
                except ShardUnavailable:
                    continue
                seen.update(ks)
            return sorted(seen)

    def load_by_node(self) -> Dict[str, int]:
        return {n: len(s) for n, s in sorted(self.shards.items())}

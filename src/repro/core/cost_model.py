"""Token-level cost & latency accounting.

Dollar costs use the paper's Table 8 API prices so benchmark figures stay
comparable with the paper. Latency uses a serving-rate model: when a JAX
data plane is attached, rates come from the roofline'd engine; otherwise
from the published-API throughput defaults below (tokens/s), matching the
paper's remote-API setting.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.apc_minion import PAPER_PRICES, TierPricing

# tokens/second + per-call RTT defaults (remote-API regime, calibrated to the
# paper's Table 3 wall-clock); overridable per role, and replaced by
# engine-derived rates when a JAX data plane is attached.
DEFAULT_RATES = {
    "large_planner": {"prefill": 5_000.0, "decode": 58.0, "rtt": 0.35},
    "small_planner": {"prefill": 12_000.0, "decode": 110.0, "rtt": 0.30},
    "actor": {"prefill": 12_000.0, "decode": 120.0, "rtt": 0.30},
    "keyword_extractor": {"prefill": 20_000.0, "decode": 60.0, "rtt": 0.30},
    "cache_generator": {"prefill": 20_000.0, "decode": 60.0, "rtt": 0.35},
}


@dataclass
class Usage:
    input_tokens: int = 0
    output_tokens: int = 0
    calls: int = 0
    latency_s: float = 0.0

    def add(self, inp: int, out: int, latency: float = 0.0):
        self.input_tokens += inp
        self.output_tokens += out
        self.calls += 1
        self.latency_s += latency


@dataclass
class CostLedger:
    """Accumulates per-role token usage; prices via a role->model mapping."""

    pricing_map: Dict[str, str]  # role -> Table 8 model name
    rates: Dict[str, Dict[str, float]] = field(default_factory=lambda: dict(DEFAULT_RATES))
    usage: Dict[str, Usage] = field(default_factory=lambda: defaultdict(Usage))

    def record(self, role: str, input_tokens: int, output_tokens: int) -> float:
        """Record a call; returns its modeled latency in seconds."""
        r = self.rates.get(role, DEFAULT_RATES["actor"])
        latency = (
            r.get("rtt", 0.0)
            + input_tokens / r["prefill"]
            + output_tokens / r["decode"]
        )
        self.usage[role].add(input_tokens, output_tokens, latency)
        return latency

    def price(self, role: str) -> TierPricing:
        return PAPER_PRICES[self.pricing_map.get(role, "llama-3.1-8b")]

    def cost_of(self, role: str) -> float:
        u = self.usage[role]
        p = self.price(role)
        return (u.input_tokens * p.input_per_m + u.output_tokens * p.output_per_m) / 1e6

    def total_cost(self) -> float:
        return sum(self.cost_of(r) for r in self.usage)

    def total_latency(self) -> float:
        return sum(u.latency_s for u in self.usage.values())

    def breakdown(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for role, u in sorted(self.usage.items()):
            out[role] = {
                "cost": round(self.cost_of(role), 6),
                "input_tokens": u.input_tokens,
                "output_tokens": u.output_tokens,
                "calls": u.calls,
                "latency_s": round(u.latency_s, 3),
            }
        return out

    def merge(self, other: "CostLedger") -> None:
        for role, u in other.usage.items():
            self.usage[role].input_tokens += u.input_tokens
            self.usage[role].output_tokens += u.output_tokens
            self.usage[role].calls += u.calls
            self.usage[role].latency_s += u.latency_s


def estimate_tokens(text: str) -> int:
    """chars/4 heuristic (matches OpenAI's rule of thumb)."""
    return max(1, len(text) // 4)

"""Fuzzy keyword matching via hashed character-ngram embeddings.

Stands in for SentenceTransformer('all-MiniLM-L6-v2') from the paper's
prototype (offline container). Same asymptotics: embedding once per insert,
O(N * dim) brute-force cosine scan per lookup — which is exactly the poor
scaling the paper measures in Table 5. Also used by the semantic-caching
baseline (query-level similarity).
"""

from __future__ import annotations

import hashlib
import re
from typing import Dict, List, Optional

import numpy as np

DIM = 384  # matches MiniLM-L6 dim


def _tokens(text: str) -> List[str]:
    text = text.lower()
    words = re.findall(r"[a-z0-9]+", text)
    grams = list(words)
    for w in words:
        for i in range(len(w) - 2):
            grams.append(w[i : i + 3])
    for a, b in zip(words, words[1:]):
        grams.append(a + "_" + b)
    return grams


def embed(text: str) -> np.ndarray:
    """Deterministic hashed bag-of-ngrams embedding, L2-normalized."""
    v = np.zeros(DIM, np.float32)
    for g in _tokens(text):
        h = int.from_bytes(hashlib.blake2b(g.encode(), digest_size=8).digest(), "little")
        idx = h % DIM
        sign = 1.0 if (h >> 62) & 1 else -1.0
        v[idx] += sign
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def similarity(a: str, b: str) -> float:
    return float(embed(a) @ embed(b))


class FuzzyMatcher:
    """Brute-force cosine index (matches the paper's prototype)."""

    def __init__(self):
        self._keys: List[str] = []
        self._embs: Optional[np.ndarray] = None
        self._cache: Dict[str, np.ndarray] = {}

    def add(self, key: str) -> None:
        if key in self._cache:
            return
        e = embed(key)
        self._cache[key] = e
        self._keys.append(key)
        self._embs = None  # invalidate matrix

    def remove(self, key: str) -> None:
        if key in self._cache:
            del self._cache[key]
            self._keys.remove(key)
            self._embs = None

    def clear(self) -> None:
        self._keys = []
        self._embs = None
        self._cache = {}

    def _matrix(self) -> np.ndarray:
        if self._embs is None:
            if not self._keys:
                self._embs = np.zeros((0, DIM), np.float32)
            else:
                self._embs = np.stack([self._cache[k] for k in self._keys])
        return self._embs

    def best_match(
        self, query: str, keys: Optional[List[str]] = None, threshold: float = 0.8
    ) -> Optional[str]:
        if keys is not None and set(keys) != set(self._keys):
            # caller supplied the live key set; rebuild lazily
            self._keys = list(keys)
            for k in self._keys:
                if k not in self._cache:
                    self._cache[k] = embed(k)
            self._embs = None
        M = self._matrix()
        if M.shape[0] == 0:
            return None
        q = embed(query)
        sims = M @ q
        i = int(np.argmax(sims))
        if sims[i] >= threshold:
            return self._keys[i]
        return None

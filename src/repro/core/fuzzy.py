"""Fuzzy keyword matching — thin adapter over the ``repro.index`` subsystem.

Stands in for SentenceTransformer('all-MiniLM-L6-v2') from the paper's
prototype (offline container). The hashed-ngram embedding itself lives in
``repro.index.bank`` (batched, memoized); this module keeps the historical
``embed``/``similarity``/``DIM`` surface plus :class:`FuzzyMatcher`, the
PlanCache-facing matcher.

The seed implementation reproduced Table 5's scaling cliff on purpose: a
brute-force numpy cosine scan with an ``np.stack`` matrix rebuild after any
mutation and an O(N) key-set comparison per lookup. FuzzyMatcher is now a
view over an :class:`~repro.index.SimilarityIndex` — O(1) add/remove on the
bank's freelist arena, no rebuilds, and a choice of search backend:

* ``brute``    exact numpy scan (the paper's prototype behavior)
* ``pallas``   ``ops.batch_topk`` blocked kernel (one device call/batch,
               bank re-uploaded per call)
* ``device``   ``ops.resident_topk`` against a device-resident DeviceBank
               mirror — one device call/batch, zero bank H2D per lookup
* ``bucketed`` multi-probe SRP-LSH, sublinear at 1e6 entries
* ``auto``     brute below ~4k live keys, bucketed above (default)
"""

from __future__ import annotations

from typing import List, Optional

from repro.index import SimilarityIndex
from repro.index.bank import DIM, embed, embed_batch  # noqa: F401  (re-export)


def similarity(a: str, b: str) -> float:
    e = embed_batch([a, b])
    return float(e[0] @ e[1])


class FuzzyMatcher:
    """PlanCache-facing matcher backed by a SimilarityIndex.

    API-compatible with the seed matcher; ``best_match``'s ``keys``
    parameter remains for external callers that manage their own key set,
    but costs an O(N) reconciliation — PlanCache no longer passes it and
    instead maintains the index incrementally on insert/evict/TTL-expire.
    """

    def __init__(self, backend: str = "auto", **index_kw):
        self.index = SimilarityIndex(backend=backend, **index_kw)

    def add(self, key: str, vector=None) -> None:
        self.index.add(key, vector)

    def add_batch(self, keys: List[str], vectors=None) -> None:
        """Admission-wave insert: one embedding batch, and on the ``device``
        backend one donated multi-slot device scatter for the whole wave.
        ``vectors`` skips embedding for callers that already embedded the
        keys (e.g. a replicating distributed cache)."""
        self.index.add_batch(keys, vectors)

    def remove(self, key: str) -> None:
        self.index.remove(key)

    def clear(self) -> None:
        self.index.clear()

    def _sync(self, keys: List[str]) -> None:
        """Compat path: reconcile the index with an externally-owned key
        set. O(N) — incremental add/remove is the fast path."""
        want = set(keys)
        have = set(self.index.bank.keys())
        for k in have - want:
            self.index.remove(k)
        for k in want - have:
            self.index.add(k)

    def best_match(
        self, query: str, keys: Optional[List[str]] = None, threshold: float = 0.8
    ) -> Optional[str]:
        if keys is not None:
            self._sync(keys)
        return self.index.best_match(query, threshold)

    def best_match_batch(
        self, queries: List[str], threshold: float = 0.8
    ) -> List[Optional[str]]:
        """Batched lookup: embeds all queries at once and answers them in a
        single top-k call (one device call on the pallas backend)."""
        return self.index.best_match_batch(queries, threshold)

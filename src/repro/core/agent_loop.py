"""The Plan-Act agent loop: Algorithms 1-3 from the paper, plus the four
evaluation baselines (accuracy-optimal, cost-optimal, semantic caching,
full-history caching).

Method map (paper §4.1):
  apc               Alg.1: keyword -> cache -> Alg.2 (hit, small planner
                    adapts template) / Alg.3 (miss, large planner plans from
                    scratch; successful log distilled into the cache)
  accuracy_optimal  always the large planner, no cache
  cost_optimal      always the small planner, no cache
  semantic          GPTCache-style query-similarity cache of final responses
  full_history      keyword cache of raw execution logs used as in-context
                    examples for the small planner
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.backends import PlanMsg, SimulatedBackend
from repro.core.cache import PlanCache
from repro.core.cost_model import CostLedger, estimate_tokens
from repro.core import fuzzy
from repro.core.template import (
    ExecutionLog,
    PlanTemplate,
    make_template,
    rule_filter,
)
from repro.envs.base import Task, judge


@dataclass
class RunRecord:
    task_id: str
    method: str
    correct: bool
    hit: bool
    keyword: str
    iterations: int
    answer: Optional[float]
    cost: float
    latency_s: float
    cache_lookup_s: float = 0.0
    cache_gen_s: float = 0.0


@dataclass
class AgentConfig:
    method: str = "apc"
    max_iterations: int = 10
    cache_capacity: int = 100
    fuzzy: bool = False
    fuzzy_threshold: float = 0.8
    semantic_threshold: float = 0.85
    index_backend: str = "auto"  # repro.index backend for fuzzy/semantic search
    async_cachegen: bool = False  # beyond-paper: don't block on cache writes
    seed: int = 0


class PlanActAgent:
    """One agent serving deployment: backends + cache + ledger."""

    def __init__(
        self,
        backend: SimulatedBackend,
        ledger: CostLedger,
        config: AgentConfig,
        cache: Optional[PlanCache] = None,
    ):
        self.be = backend
        self.ledger = ledger
        self.cfg = config
        # NB: `cache or ...` would be wrong — an empty PlanCache is falsy
        self.cache: PlanCache = (
            cache
            if cache is not None
            else PlanCache(
                capacity=config.cache_capacity,
                fuzzy=config.fuzzy,
                fuzzy_threshold=config.fuzzy_threshold,
                index_backend=config.index_backend,
            )
        )
        # semantic baseline: repro.index over query embeddings -> answers
        # (replaces the seed's list-of-arrays + per-lookup np.stack scan)
        from repro.index import SimilarityIndex

        self._sem_index = SimilarityIndex(backend=config.index_backend)
        self._sem_vals: List[Tuple[str, Optional[float]]] = []
        self._pending_cachegen: List[Tuple[str, PlanTemplate, float]] = []

    # ==================================================================
    # Cache pre-warming (paper §4.5: "pre-populating the cache with
    # offline samples before deployment" mitigates cold start)
    # ==================================================================

    def prewarm(self, tasks: List[Task]) -> int:
        """Run offline samples through the miss path to populate templates.
        Costs accrue to the ledger (offline budget); returns #inserted."""
        inserted = 0
        for task in tasks:
            kw, ki, ko = self.be.extract_keyword(task)
            self.ledger.record("keyword_extractor", ki, ko)
            if kw in self.cache:
                continue
            answer, _, log, _ = self._loop_scratch(task, large=True)
            if answer is not None and log.final_answer is not None:
                gi, go = self.be.cachegen_tokens(log.raw_tokens())
                self.ledger.record("cache_generator", gi, go)
                miss = self.be.generalization_misses(task)
                self.cache.insert(kw, make_template(log, kw, task.slots,
                                                    miss_slots=miss))
                inserted += 1
        return inserted

    # ==================================================================
    # Algorithm 1: end-to-end
    # ==================================================================

    def run_task(self, task: Task) -> RunRecord:
        m = self.cfg.method
        if m == "apc":
            return self._run_apc(task)
        if m == "accuracy_optimal":
            return self._run_scratch(task, large=True)
        if m == "cost_optimal":
            return self._run_scratch(task, large=False)
        if m == "semantic":
            return self._run_semantic(task)
        if m == "full_history":
            return self._run_full_history(task)
        raise ValueError(m)

    # ==================================================================
    # APC (Algorithms 1-3)
    # ==================================================================

    def _run_apc(self, task: Task) -> RunRecord:
        lat = 0.0
        kw, ki, ko = self.be.extract_keyword(task)
        lat += self.ledger.record("keyword_extractor", ki, ko)

        t0 = time.perf_counter()
        template = self.cache.lookup(kw)
        lookup_s = time.perf_counter() - t0
        lat += lookup_s

        if template is not None:  # ---- Algorithm 2: cache hit
            template.uses += 1
            answer, iters, l2 = self._loop_adapt(task, template, full_history=False)
            lat += l2
            correct = judge(answer, task.gt_answer)
            return RunRecord(
                task.id, "apc", correct, True, kw, iters, answer,
                self.ledger.total_cost(), lat, lookup_s,
            )

        # ---- Algorithm 3: cache miss
        answer, iters, log, l3 = self._loop_scratch(task, large=True)
        lat += l3
        correct = judge(answer, task.gt_answer)
        gen_s = 0.0
        if answer is not None and log.final_answer is not None:
            gi, go = self.be.cachegen_tokens(log.raw_tokens())
            gen_s = self.ledger.record("cache_generator", gi, go)
            miss_slots = self.be.generalization_misses(task)
            tpl = make_template(log, kw, task.slots, miss_slots=miss_slots)
            self.cache.insert(kw, tpl)
            if not self.cfg.async_cachegen:
                lat += gen_s  # synchronous generation blocks the response
        return RunRecord(
            task.id, "apc", correct, False, kw, iters, answer,
            self.ledger.total_cost(), lat, lookup_s, gen_s,
        )

    # ==================================================================
    # inner loops
    # ==================================================================

    def _loop_scratch(
        self, task: Task, *, large: bool
    ) -> Tuple[Optional[float], int, ExecutionLog, float]:
        role = "large_planner" if large else "small_planner"
        log = ExecutionLog(task_query=task.query)
        responses: List[Dict[str, Any]] = []
        lat = 0.0
        answer = None
        for it in range(self.cfg.max_iterations):
            msg, pi, po = self.be.plan(task, responses, large=large, round_idx=it)
            lat += self.ledger.record(role, pi, po)
            if msg.kind == "answer":
                log.final_answer = {"answer_text": msg.text, "op": msg.op}
                answer = msg.op.get("value")
                return answer, it + 1, log, lat
            resp, ai, ao = self.be.act(task, msg)
            lat += self.ledger.record("actor", ai, ao)
            responses.append(resp)
            log.append({"message": msg.text, "op": msg.op}, resp)
        return None, self.cfg.max_iterations, log, lat

    def _loop_adapt(
        self, task: Task, template: PlanTemplate, *, full_history: bool
    ) -> Tuple[Optional[float], int, float]:
        responses: List[Dict[str, Any]] = []
        lat = 0.0
        n_rounds = max(1, template.n_rounds())
        for it in range(self.cfg.max_iterations):
            msg, pi, po = self.be.adapt(
                task, template, responses, round_idx=it, full_history=full_history
            )
            lat += self.ledger.record("small_planner", pi, po)
            if msg.kind == "answer":
                return msg.op.get("value"), it + 1, lat
            resp, ai, ao = self.be.act(task, msg)
            lat += self.ledger.record("actor", ai, ao)
            responses.append(resp)
            if it + 1 >= n_rounds and it + 1 < self.cfg.max_iterations:
                continue  # next adapt() call emits the answer
        return None, self.cfg.max_iterations, lat

    # ==================================================================
    # baselines
    # ==================================================================

    def _run_scratch(self, task: Task, *, large: bool) -> RunRecord:
        answer, iters, _, lat = self._loop_scratch(task, large=large)
        return RunRecord(
            task.id,
            "accuracy_optimal" if large else "cost_optimal",
            judge(answer, task.gt_answer),
            False, "", iters, answer, self.ledger.total_cost(), lat,
        )

    def _run_semantic(self, task: Task) -> RunRecord:
        t0 = time.perf_counter()
        q_emb = fuzzy.embed(task.query)
        hit_val = None
        hit_key = self._sem_index.best_match(q_emb, self.cfg.semantic_threshold)
        if hit_key is not None:
            hit_val = self._sem_vals[int(hit_key[1:])]
        lookup_s = time.perf_counter() - t0
        if hit_val is not None:
            # cached final response returned verbatim (GPTCache semantics) —
            # correct only if the numeric answer transfers to THIS task.
            answer = hit_val[1]
            return RunRecord(
                task.id, "semantic", judge(answer, task.gt_answer), True,
                "", 0, answer, self.ledger.total_cost(), lookup_s, lookup_s,
            )
        answer, iters, _, lat = self._loop_scratch(task, large=True)
        self._sem_index.add(f"q{len(self._sem_vals)}", q_emb)
        self._sem_vals.append((task.query, answer))
        return RunRecord(
            task.id, "semantic", judge(answer, task.gt_answer), False,
            "", iters, answer, self.ledger.total_cost(), lat + lookup_s, lookup_s,
        )

    def _run_full_history(self, task: Task) -> RunRecord:
        lat = 0.0
        kw, ki, ko = self.be.extract_keyword(task)
        lat += self.ledger.record("keyword_extractor", ki, ko)
        t0 = time.perf_counter()
        log: Optional[ExecutionLog] = self.cache.lookup(kw)
        lookup_s = time.perf_counter() - t0
        lat += lookup_s
        if log is not None:
            # raw log as in-context example: build an UNfiltered pseudo-template
            steps = rule_filter(log)
            tpl = PlanTemplate(keyword=kw, steps=steps, source_task=log.task_query)
            # charge the long history into the small planner's context
            hist_tokens = log.raw_tokens()
            self.ledger.record("small_planner", hist_tokens, 0)
            answer, iters, l2 = self._loop_adapt(task, tpl, full_history=True)
            lat += l2
            return RunRecord(
                task.id, "full_history", judge(answer, task.gt_answer), True,
                kw, iters, answer, self.ledger.total_cost(), lat, lookup_s,
            )
        answer, iters, log, l3 = self._loop_scratch(task, large=True)
        lat += l3
        if answer is not None:
            self.cache.insert(kw, log)
        return RunRecord(
            task.id, "full_history", judge(answer, task.gt_answer), False,
            kw, iters, answer, self.ledger.total_cost(), lat, lookup_s,
        )

"""The Plan-Act agent loop: Algorithms 1-3 from the paper.

``PlanActAgent`` owns one serving deployment (backends + plan store +
ledger) and the two inner loops every method composes:

* ``_loop_scratch`` — plan from scratch on the large/small planner
  (Algorithm 3's replan path and both no-cache baselines);
* ``_loop_adapt``   — adapt a cached template with the small planner
  (Algorithm 2).

WHICH loop runs, and how the plan store is consulted, is a method
strategy: ``run_task`` dispatches to a class registered in
:mod:`repro.memory.registry` (``@register_method``) and implemented in
:mod:`repro.core.methods` — apc, the paper's baselines, and any
out-of-tree method a scenario registers. There is no per-method branching
here.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.backends import SimulatedBackend
from repro.core.cache import PlanCache
from repro.core.cost_model import CostLedger
from repro.core.journal import StepJournal
from repro.core.template import ExecutionLog, PlanTemplate, make_template
from repro.envs.base import Task


@dataclass
class RunRecord:
    task_id: str
    method: str
    correct: bool
    hit: bool
    keyword: str
    iterations: int
    answer: Optional[float]
    cost: float
    latency_s: float
    cache_lookup_s: float = 0.0
    cache_gen_s: float = 0.0
    speculated: bool = False
    spec_outcome: str = ""  # "" | commit | patch | rollback


@dataclass
class AgentConfig:
    method: str = "apc"
    max_iterations: int = 10
    cache_capacity: int = 100
    fuzzy: bool = False
    fuzzy_threshold: float = 0.8
    semantic_threshold: float = 0.85
    index_backend: str = "auto"  # repro.index backend for fuzzy/semantic search
    eviction: str = "lru"  # repro.memory eviction policy (lru | lfu | cost)
    async_cachegen: bool = False  # beyond-paper: don't block on cache writes
    seed: int = 0


class PlanActAgent:
    """One agent serving deployment: backends + cache + ledger."""

    def __init__(
        self,
        backend: SimulatedBackend,
        ledger: CostLedger,
        config: AgentConfig,
        cache: Optional[PlanCache] = None,
    ):
        self.be = backend
        self.ledger = ledger
        self.cfg = config
        # NB: `cache is not None` — an empty PlanCache is falsy
        self.cache_external = cache is not None
        self.cache: Optional[PlanCache] = cache
        # registry dispatch: the method strategy may SUPPLY self.cache in
        # its setup() (cascade builds an exact->fuzzy->semantic store), so
        # the default store is built only if neither the caller nor the
        # strategy provided one — no throwaway construction.
        from repro.core.methods import make_method

        self._method = make_method(config.method, self)
        if self.cache is None:
            self.cache = PlanCache(
                capacity=config.cache_capacity,
                fuzzy=config.fuzzy,
                fuzzy_threshold=config.fuzzy_threshold,
                index_backend=config.index_backend,
                eviction=config.eviction,
            )

    # ==================================================================
    # Cache pre-warming (paper §4.5: "pre-populating the cache with
    # offline samples before deployment" mitigates cold start)
    # ==================================================================

    def prewarm(self, tasks: List[Task]) -> int:
        """Run offline samples through the miss path to populate templates.
        Costs accrue to the ledger (offline budget); returns #inserted."""
        inserted = 0
        for task in tasks:
            kw, ki, ko = self.be.extract_keyword(task)
            self.ledger.record("keyword_extractor", ki, ko)
            if kw in self.cache:
                continue
            answer, _, log, _ = self._loop_scratch(task, large=True)
            if answer is not None and log.final_answer is not None:
                gi, go = self.be.cachegen_tokens(log.raw_tokens())
                self.ledger.record("cache_generator", gi, go)
                miss = self.be.generalization_misses(task)
                self.cache.insert(kw, make_template(log, kw, task.slots,
                                                    miss_slots=miss))
                inserted += 1
        return inserted

    # ==================================================================
    # Algorithm 1: end-to-end (registry dispatch, no method branching)
    # ==================================================================

    def run_task(self, task: Task) -> RunRecord:
        return self._method.run(task)

    # ==================================================================
    # inner loops (shared by every method strategy)
    # ==================================================================

    def _record_act_effects(
        self, task: Task, journal: StepJournal, round_idx: int,
        resp: Dict[str, Any],
    ) -> None:
        """Journal one actor round's env writes (reversible workspace
        puts). With a caller-owned journal the step stays open until the
        verifier commits/patches/rolls back; the default loops commit
        per step, so the journal is the single env-mutation path either
        way (the ``journal-discipline`` checker pins this)."""
        step = journal.begin_step(f"round-{round_idx}")
        ws = task.workspace
        for name in sorted(resp.get("values", {})):
            step.applied(ws.write(f"r{round_idx}/{name}", resp["values"][name]))

    def _loop_scratch(
        self, task: Task, *, large: bool,
        journal: Optional[StepJournal] = None,
        responses: Optional[List[Dict[str, Any]]] = None,
        start_round: int = 0,
    ) -> Tuple[Optional[float], int, ExecutionLog, float]:
        """Plan from scratch. ``responses``/``start_round`` let the
        speculative patch path re-enter mid-task: the verified planner
        continues from the committed prefix's retrievals instead of
        round 0."""
        role = "large_planner" if large else "small_planner"
        log = ExecutionLog(task_query=task.query)
        responses = list(responses or [])
        own_journal = journal is None
        journal = journal if journal is not None else StepJournal()
        lat = 0.0
        answer = None
        iters = 0
        for it in range(start_round, self.cfg.max_iterations):
            iters += 1
            msg, pi, po = self.be.plan(task, responses, large=large, round_idx=it)
            lat += self.ledger.record(role, pi, po)
            if msg.kind == "answer":
                log.final_answer = {"answer_text": msg.text, "op": msg.op}
                answer = msg.op.get("value")
                break
            resp, ai, ao = self.be.act(task, msg)
            lat += self.ledger.record("actor", ai, ao)
            responses.append(resp)
            log.append({"message": msg.text, "op": msg.op}, resp)
            self._record_act_effects(task, journal, it, resp)
            if own_journal:
                journal.commit()  # non-speculative: finalize per step
        return answer, iters or self.cfg.max_iterations, log, lat

    def _loop_adapt(
        self, task: Task, template: PlanTemplate, *, full_history: bool,
        journal: Optional[StepJournal] = None,
    ) -> Tuple[Optional[float], int, float]:
        responses: List[Dict[str, Any]] = []
        lat = 0.0
        n_rounds = max(1, template.n_rounds())
        own_journal = journal is None
        journal = journal if journal is not None else StepJournal()
        for it in range(self.cfg.max_iterations):
            msg, pi, po = self.be.adapt(
                task, template, responses, round_idx=it, full_history=full_history
            )
            lat += self.ledger.record("small_planner", pi, po)
            if msg.kind == "answer":
                return msg.op.get("value"), it + 1, lat
            resp, ai, ao = self.be.act(task, msg)
            lat += self.ledger.record("actor", ai, ao)
            responses.append(resp)
            self._record_act_effects(task, journal, it, resp)
            if own_journal:
                journal.commit()
            if it + 1 >= n_rounds and it + 1 < self.cfg.max_iterations:
                continue  # next adapt() call emits the answer
        return None, self.cfg.max_iterations, lat

"""Plan templates: extraction from execution logs (rule filter + lightweight
generalization filter) — paper Fig. 2(c) and §3.1 step (c).

A *plan* in this framework is a structured planner->actor message:
    {"message": <text>, "op": {"retrieve": [...fields], "scope": {...}}}
or the terminal
    {"answer": <text>, "op": {"compute": <expr>}}

Template generation (cache miss path, Algorithm 3 line 12):
  1. rule-based filter: project the raw execution log onto the
     message->output->...->answer skeleton, dropping planner chain-of-thought
     and actor verbosity (paper: "discarding irrelevant details");
  2. generalization filter (the paper uses GPT-4o-mini): replace
     context-specific slot values (entity names, fiscal years, numbers) with
     named placeholders so the template transfers across tasks.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class PlanStep:
    kind: str  # "message" | "output" | "answer"
    content: str
    op: Optional[Dict[str, Any]] = None

    def to_json(self) -> Dict[str, Any]:
        return {"kind": self.kind, "content": self.content, "op": self.op}


@dataclass
class PlanTemplate:
    keyword: str
    steps: List[PlanStep]
    source_task: str = ""
    uses: int = 0

    def message_steps(self) -> List[PlanStep]:
        return [s for s in self.steps if s.kind == "message"]

    def answer_step(self) -> Optional[PlanStep]:
        for s in self.steps:
            if s.kind == "answer":
                return s
        return None

    def n_rounds(self) -> int:
        return len(self.message_steps())

    def size_tokens(self) -> int:
        from repro.core.cost_model import estimate_tokens

        return sum(estimate_tokens(s.content) for s in self.steps) + 20


@dataclass
class ExecutionLog:
    """Raw Plan-Act trace (Algorithm 3's ``log``)."""

    task_query: str
    entries: List[Dict[str, Any]] = field(default_factory=list)  # {plan, response}
    final_answer: Optional[Dict[str, Any]] = None

    def append(self, plan: Dict[str, Any], response: Dict[str, Any]) -> None:
        self.entries.append({"plan": plan, "response": response})

    def raw_tokens(self) -> int:
        from repro.core.cost_model import estimate_tokens

        n = estimate_tokens(self.task_query)
        for e in self.entries:
            n += estimate_tokens(str(e["plan"])) + estimate_tokens(str(e["response"]))
        if self.final_answer:
            n += estimate_tokens(str(self.final_answer))
        return n


# ---------------------------------------------------------------------------
# Step 1: rule-based filter
# ---------------------------------------------------------------------------


def rule_filter(log: ExecutionLog) -> List[PlanStep]:
    """Keep the message/output/answer skeleton, drop reasoning prose.

    Planner messages carry a structured ``op`` plus prose; we keep the op and
    the first sentence of the message (the imperative part). Actor outputs
    keep only the structured values (what the next plan conditions on).
    """
    steps: List[PlanStep] = []
    for e in log.entries:
        plan = e["plan"]
        msg = plan.get("message", "")
        first_sentence = msg.split(". ")[0][:300]
        steps.append(PlanStep("message", first_sentence, plan.get("op")))
        resp = e["response"]
        keys = sorted(resp.get("values", {}).keys()) if isinstance(resp, dict) else []
        steps.append(PlanStep("output", "values: " + ", ".join(keys), None))
    if log.final_answer is not None:
        fa = log.final_answer
        steps.append(PlanStep("answer", fa.get("answer_text", "")[:200], fa.get("op")))
    return steps


# ---------------------------------------------------------------------------
# Step 2: generalization filter (lightweight-LM role, deterministic here)
# ---------------------------------------------------------------------------

_NUM_RE = re.compile(r"(?<![\w{])[-+]?\d[\d,]*(?:\.\d+)?%?(?![\w}])")


def generalize(
    steps: List[PlanStep],
    slots: Dict[str, str],
    *,
    miss_slots: Optional[List[str]] = None,
) -> List[PlanStep]:
    """Replace slot values with {slot} placeholders and scrub free numbers.

    ``miss_slots`` models generalization errors of the lightweight filter
    model (a slot it failed to abstract stays baked into the template — the
    template then mis-transfers, which shows up as a cache-hit accuracy
    cost; the simulated backend injects these at its error rate).
    """
    miss = set(miss_slots or [])
    # longest-first so "Best Buy" is replaced before "Best"
    items = sorted(slots.items(), key=lambda kv: -len(str(kv[1])))
    out: List[PlanStep] = []
    for s in steps:
        content = s.content
        op = _deep_copy_op(s.op)
        for name, val in items:
            if name in miss:
                continue
            sval = str(val)
            if not sval:
                continue
            content = content.replace(sval, "{%s}" % name)
            op = _op_replace(op, sval, "{%s}" % name)
        if s.kind != "answer":
            content = _NUM_RE.sub("{N}", content)
        out.append(PlanStep(s.kind, content, op))
    return out


def _deep_copy_op(op):
    if op is None:
        return None
    if isinstance(op, dict):
        return {k: _deep_copy_op(v) for k, v in op.items()}
    if isinstance(op, list):
        return [_deep_copy_op(v) for v in op]
    return op


def _op_replace(op, old: str, new: str):
    if op is None:
        return None
    if isinstance(op, dict):
        return {k: _op_replace(v, old, new) for k, v in op.items()}
    if isinstance(op, list):
        return [_op_replace(v, old, new) for v in op]
    if isinstance(op, str):
        return op.replace(old, new)
    return op


def make_template(
    log: ExecutionLog,
    keyword: str,
    slots: Dict[str, str],
    *,
    miss_slots: Optional[List[str]] = None,
) -> PlanTemplate:
    steps = rule_filter(log)
    steps = generalize(steps, slots, miss_slots=miss_slots)
    src = log.task_query
    for name, val in sorted(slots.items(), key=lambda kv: -len(str(kv[1]))):
        src = src.replace(str(val), "{%s}" % name)
    return PlanTemplate(keyword=keyword, steps=steps, source_task=src[:300])


# ---------------------------------------------------------------------------
# Template instantiation (used by adapt.py)
# ---------------------------------------------------------------------------


def instantiate(tpl_text_or_op, slots: Dict[str, str]):
    """Fill {slot} placeholders from the *current* task's slot bindings."""
    if tpl_text_or_op is None:
        return None
    if isinstance(tpl_text_or_op, dict):
        return {k: instantiate(v, slots) for k, v in tpl_text_or_op.items()}
    if isinstance(tpl_text_or_op, list):
        return [instantiate(v, slots) for v in tpl_text_or_op]
    if isinstance(tpl_text_or_op, str):
        out = tpl_text_or_op
        for name, val in slots.items():
            out = out.replace("{%s}" % name, str(val))
        return out
    return tpl_text_or_op

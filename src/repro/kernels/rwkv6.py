"""Pallas TPU kernel for the RWKV6 (wkv6) chunked recurrence.

TPU adaptation of the CUDA wkv6 kernel (which uses warp shuffles over the
head dim): grid = (B, H, n_chunks) with the chunk axis sequential
("arbitrary"); the (N, N) fp32 state lives in VMEM scratch across chunk
steps, intra-chunk work is (C, N) x (N, C) matmuls on the MXU, and the
decay factorization matches models/rwkv.py::wkv6_chunked exactly:

    y = (r * exp(la_prev)) @ S + tril_strict((r e^{la_prev}) (k e^{-la})^T) V
        + (sum_n r u k) * v
    S' = diag(e^{la_C}) S + (k e^{la_C - la})^T V

Chunk C=64, N=64: state 16 KiB + 4 chunk tensors 64 KiB — trivially VMEM
resident; the kernel is compute-bound on the (C,C)x(C,N) matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, st_out_ref, state_scr,
                 *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)  # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)  # log-decay, negative
    u = u_ref[0].astype(jnp.float32)  # (N,)

    la = jnp.cumsum(w, axis=0)  # (C, N) inclusive
    la_prev = la - w
    la_end = la[-1:]  # (1, N)

    q_t = r * jnp.exp(la_prev)
    k_t = k * jnp.exp(-la)
    scores = jax.lax.dot_general(
        q_t, k_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols < rows, scores, 0.0)  # strictly lower
    y_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    diag_c = jnp.sum(r * u[None] * k, axis=-1, keepdims=True)  # (C, 1)
    y_diag = diag_c * v
    state = state_scr[...]
    y_inter = jax.lax.dot_general(
        q_t, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = (y_intra + y_diag + y_inter).astype(o_ref.dtype)

    k_dec = k * jnp.exp(la_end - la)  # (C, N)
    state_scr[...] = jnp.exp(la_end[0])[:, None] * state + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        st_out_ref[0, 0] = state_scr[...]


def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w_log: jnp.ndarray,
    u: jnp.ndarray,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """r,k,v,w_log: (B, H, S, N); u: (H, N).
    Returns (y (B,H,S,N), final state (B,H,N,N))."""
    B, H, S, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, N), lambda b, h, ic: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w_log, u)
    return y, state

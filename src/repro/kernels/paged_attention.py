"""Pallas TPU paged decode attention: one query token vs K/V gathered
through a page table.

The KV cache here is not a per-sequence slab but a shared page pool
(``serving/kv_cache.py``): pages of ``page_size`` tokens live at arbitrary
pool rows, and each sequence names its pages through a ``(B, P)`` page
table. The kernel streams K/V one page per grid step, with the page row
resolved *before* the DMA via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) — the page table and per-sequence
lengths are SMEM-resident, and the BlockSpec index map reads
``page_table[b, ip]`` to aim each HBM->VMEM copy at the right pool row.
Entries past a sequence's last page are ``-1``; the index map clamps them
to row 0 and the length mask (plus a ``pl.when`` skip) discards the block.

The accumulation is the same block-sequential online softmax as
``decode_attention.py`` — with ``page_size == block_k`` and in-order
pages, the two kernels perform bit-identical arithmetic, which is exactly
what ``tests/test_kv_cache.py`` pins (paged-vs-dense bitwise parity).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size, n_pages):
    ib = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[ib]

    @pl.when(ip * page_size < length)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page_size, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / jnp.sqrt(float(hd))  # (G, page_size)
        pos = ip * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos >= length, NEG_INF, s)
        m_old = m_scr[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def paged_decode_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, hd); k_pages, v_pages: (N, page_size, Hkv, hd) pool slabs;
    page_table: (B, P) int32 pool rows, -1 past a sequence's last page;
    lengths: (B,) or () int32 valid token counts -> (B, Hq, hd)."""
    B, Hq, hd = q.shape
    N, page_size, Hkv, _ = k_pages.shape
    P = page_table.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    table = jnp.asarray(page_table, jnp.int32)
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1), (B,))

    kernel = functools.partial(_paged_kernel, page_size=page_size, n_pages=P)
    # index maps receive the scalar-prefetch refs after the grid indices;
    # invalid (-1) table entries clamp to pool row 0 — the DMA lands
    # somewhere legal, and the length mask discards the whole block
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page table + lengths
        grid=(B, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page_size, 1, hd),
                lambda b, h, ip, pt, ln: (jnp.maximum(pt[b, ip], 0), 0, h, 0),
            ),
            pl.BlockSpec(
                (1, page_size, 1, hd),
                lambda b, h, ip, pt, ln: (jnp.maximum(pt[b, ip], 0), 0, h, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, ip, pt, ln: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(table, lens, qg, k_pages, v_pages)
    return out.reshape(B, Hq, hd)

"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

Deliberately naive: O(S^2) attention materializing scores, O(S) sequential
recurrences for RWKV6/SSD. Small shapes only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Hq, S, hd); k, v: (B, Hkv, S, hd) -> (B, Hq, S, hd). fp32."""
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) / jnp.sqrt(hd)
    if causal:
        mask = jnp.arange(S)[None, :] > jnp.arange(S)[:, None]
        s = s + mask * NEG_INF
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)


def decode_attention_ref(q, k, v, length):
    """q: (B, Hq, hd); k, v: (B, Hkv, M, hd); length: () or (B,) valid kv
    counts (a scalar broadcasts to the whole batch). Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kr) / jnp.sqrt(hd)
    lens = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (B,))
    mask = jnp.arange(M)[None, None, :] >= lens[:, None, None]
    s = jnp.where(mask, NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vr).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Oracle for kernels/paged_attention.py: gather each sequence's pages
    into a dense cache, then dense masked decode attention.

    q: (B, Hq, hd); k_pages, v_pages: (N, page_size, Hkv, hd);
    page_table: (B, P) pool rows (-1 past the end); lengths: (B,).
    Returns (B, Hq, hd)."""
    import numpy as np

    pt = np.maximum(np.asarray(page_table, np.int64), 0)
    kg = np.asarray(k_pages)[pt]  # (B, P, page_size, Hkv, hd)
    vg = np.asarray(v_pages)[pt]
    B, P, ps, Hkv, hd = kg.shape
    kd = kg.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, P * ps, hd)
    vd = vg.transpose(0, 3, 1, 2, 4).reshape(B, Hkv, P * ps, hd)
    return decode_attention_ref(q, jnp.asarray(kd), jnp.asarray(vd), lengths)


def rwkv6_ref(r, k, v, w_log, u, state0):
    """Sequential wkv6. r,k,v,w_log: (B, S, H, N); u: (H, N);
    state0: (B, H, N, N). Returns (y (B,S,H,N), state)."""
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w_log.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs  # (B, H, N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, state)
        coef = jnp.sum(rt * u[None] * kt, axis=-1, keepdims=True)
        y = y + coef * vt
        state = jnp.exp(wt)[..., None] * state + kt[..., None] * vt[..., None, :]
        return state, y

    xs = (
        rf.transpose(1, 0, 2, 3),
        kf.transpose(1, 0, 2, 3),
        vf.transpose(1, 0, 2, 3),
        wf.transpose(1, 0, 2, 3),
    )
    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), state


def topk_cosine_ref(queries, bank, k):
    """Numpy oracle for kernels/similarity.py: brute-force scores + stable
    argsort. queries (Q, D), bank (N, D), rows L2-normalized.
    Returns (scores (Q, k) f32, indices (Q, k) i32), -1/-1e30 padded when
    N < k; ties resolve to the lowest bank row (matches the kernel)."""
    import numpy as np

    q = np.asarray(queries, np.float32)
    b = np.asarray(bank, np.float32)
    Q, N = q.shape[0], b.shape[0]
    out_s = np.full((Q, k), NEG_INF, np.float32)
    out_i = np.full((Q, k), -1, np.int32)
    if N == 0 or Q == 0:
        return out_s, out_i
    scores = q @ b.T  # (Q, N)
    kk = min(k, N)
    order = np.argsort(-scores, axis=1, kind="stable")[:, :kk]
    out_s[:, :kk] = np.take_along_axis(scores, order, axis=1)
    out_i[:, :kk] = order
    return out_s, out_i


def ssd_ref(x, dt, A_log, B_, C_, D, state0):
    """Sequential SSD. x: (B,S,H,P); dt: (B,S,H); B_/C_: (B,S,Ns);
    A_log, D: (H,); state0: (B,H,P,Ns). Returns (y, state)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    neg_A = -jnp.exp(A_log.astype(jnp.float32))

    def step(state, xs):
        xt, dtt, Bt, Ct = xs  # (B,H,P), (B,H), (B,Ns), (B,Ns)
        a = jnp.exp(dtt * neg_A[None])  # (B,H)
        dtx = xt * dtt[..., None]
        state = a[..., None, None] * state + dtx[..., None] * Bt[:, None, None, :]
        y = jnp.einsum("bhps,bs->bhp", state, Ct) + D[None, :, None] * xt
        return state, y

    xs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        B_.astype(jnp.float32).transpose(1, 0, 2),
        C_.astype(jnp.float32).transpose(1, 0, 2),
    )
    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), state

"""Pallas TPU kernel for Mamba2 SSD chunked scan.

Same factorization as models/mamba.py::ssd_chunked: grid = (B, H, n_chunks),
chunk axis sequential, (P, Ns) fp32 state in VMEM scratch. B/C projections
are shared across heads (n_groups=1) so their blocks are indexed by (b, ic)
only — fetched once per head iteration from the same HBM region (backed by
Pallas's block revisiting; on TPU the pipeline keeps them VMEM-resident).

Intra-chunk: scores = (C @ B^T) * exp(la_i - la_j) masked to j<=i, then
scores @ (dt*x) on the MXU; inter-chunk via state matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, o_ref, st_out_ref,
                state_scr, *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (C,)
    A_log = A_ref[0]  # ()
    Bc = B_ref[0].astype(jnp.float32)  # (C, Ns)
    Cc = C_ref[0].astype(jnp.float32)  # (C, Ns)
    D = D_ref[0]  # ()

    dlog = dt * (-jnp.exp(A_log))  # (C,) log decay
    la = jnp.cumsum(dlog)  # inclusive
    la_end = la[-1]

    dec = jnp.exp(la[:, None] - la[None, :])  # (Ci, Cj)
    cb = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Ci, Cj)
    rows = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 1)
    scores = jnp.where(cols <= rows, cb * dec, 0.0)
    dtx = x * dt[:, None]  # (C, P)
    y_intra = jax.lax.dot_general(
        scores, dtx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state = state_scr[...]  # (P, Ns)
    y_inter = jnp.exp(la)[:, None] * jax.lax.dot_general(
        Cc, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, P)
    o_ref[0, 0] = (y_intra + y_inter + D * x).astype(o_ref.dtype)

    k_dec = dtx * jnp.exp(la_end - la)[:, None]  # (C, P)
    state_scr[...] = jnp.exp(la_end) * state + jax.lax.dot_general(
        k_dec, Bc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_chunks - 1)
    def _emit():
        st_out_ref[0, 0] = state_scr[...]


def ssd(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A_log: jnp.ndarray,
    B_: jnp.ndarray,
    C_: jnp.ndarray,
    D: jnp.ndarray,
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """x: (B, H, S, P); dt: (B, H, S); A_log, D: (H,); B_/C_: (B, S, Ns).
    Returns (y (B,H,S,P), state (B,H,P,Ns))."""
    Bb, H, S, P = x.shape
    Ns = B_.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bb, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, Ns), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, Ns), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, P, Ns), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, Ns), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, Ns), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A_log, B_, C_, D)
    return y, state

"""Pallas TPU decode attention (one query token vs a long KV cache).

This kernel is memory-bound (arithmetic intensity ~1 FLOP/byte streaming
K/V), so the tiling targets HBM->VMEM streaming, not the MXU: grid =
(B, Hkv, n_k) with all G q-heads of a kv-group processed together per block
(the (G, bk) score tile keeps the VPU busy while K/V stream). Valid-length
masking uses a per-sequence ``lengths`` vector in SMEM — mixed-length
batches mask each row to its own valid count (the historical scalar
``length`` masked every row to one shared length, silently wrong for any
batch whose sequences differ).

VMEM per step: k,v blocks 2*bk*hd*2B (bf16) + q (G*hd) + acc (G*hd) fp32;
bk=512, hd=128: ~260 KiB — sized so ~8 outstanding copies double-buffer the
HBM stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   block_k, n_k):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[ib]

    @pl.when(ik * block_k < length)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / jnp.sqrt(float(hd))  # (G, bk)
        pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos >= length, NEG_INF, s)
        m_old = m_scr[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, hd); k, v: (B, Hkv, M, hd); length: () or (B,) int32 valid
    KV counts (a scalar broadcasts to the whole batch) -> (B, Hq, hd)."""
    B, Hq, hd = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_k = min(block_k, M)
    assert M % block_k == 0, (M, block_k)
    n_k = M // block_k
    qg = q.reshape(B, Hkv, G, hd)
    lengths = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (B,)
    )

    kernel = functools.partial(_decode_kernel, block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # per-sequence lengths
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, Hq, hd)

"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True``; on TPU the
same call sites compile to Mosaic. ``use_kernels(cfg)``-style dispatch lives
in the model code; these wrappers normalize layouts (models use (B,S,H,hd),
kernels use (B,H,S,hd)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import rwkv6 as _rwkv
from repro.kernels import similarity as _sim
from repro.kernels import ssd as _ssd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_op(q, k, v, *, causal=True, block_q=128, block_k=128):
    """Model layout: q (B,S,Hq,hd), k/v (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _fa.flash_attention(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_on_cpu(),
    )
    return o.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention_op(q, cache_k, cache_v, length, *, block_k=512):
    """q (B,1,Hq,hd); cache (B,M,Hkv,hd); length () or (B,) per-sequence
    valid counts -> (B,1,Hq,hd)."""
    qt = q[:, 0]  # (B,Hq,hd)
    kt = cache_k.transpose(0, 2, 1, 3)
    vt = cache_v.transpose(0, 2, 1, 3)
    o = _dec.decode_attention(qt, kt, vt, length, block_k=block_k, interpret=_on_cpu())
    return o[:, None]


@jax.jit
def paged_decode_attention_op(q, k_pages, v_pages, page_table, lengths):
    """Decode attention through a page table (serving/kv_cache.py pool).

    q (B,1,Hq,hd) model layout; k_pages/v_pages (N, page_size, Hkv, hd)
    pool slabs; page_table (B, P) int32 pool rows (-1 past the end);
    lengths (B,) valid tokens -> (B,1,Hq,hd). Same interpret/Mosaic
    dispatch rule as every other wrapper.
    """
    o = _paged.paged_decode_attention(
        q[:, 0], k_pages, v_pages, page_table, lengths, interpret=_on_cpu()
    )
    return o[:, None]


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_op(r, k, v, w_log, u, *, chunk=64):
    """Model layout (B,S,H,N) -> (y (B,S,H,N), state (B,H,N,N))."""
    tr = lambda x: x.transpose(0, 2, 1, 3)
    y, st = _rwkv.wkv6(
        tr(r), tr(k), tr(v), tr(w_log), u, chunk=chunk, interpret=_on_cpu()
    )
    return y.transpose(0, 2, 1, 3), st


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n"))
def batch_topk(queries, bank, *, k=1, block_q=128, block_n=1024):
    """Batched fuzzy-lookup primitive for the repro.index subsystem.

    queries (Q, D) against bank (N, D), rows L2-normalized -> (scores
    (Q, k) f32, indices (Q, k) i32), one device call for the whole request
    batch. Indices are -1 (scores -1e30) where fewer than k rows exist.

    The bank argument is uploaded to the device on every call when it is a
    host array — ``resident_topk`` is the zero-copy variant for banks that
    already live on-device (``repro.index.DeviceBank``).
    """
    return _sim.topk_cosine(
        queries, bank, k, block_q=block_q, block_n=block_n, interpret=_on_cpu()
    )


@functools.partial(jax.jit, static_argnames=("k",))
def _dense_topk(queries, bank, *, k):
    """XLA dense cosine top-k with the same tie/padding semantics as the
    Pallas kernel: ties go to the lowest bank row (``jax.lax.top_k``), and
    positions past the bank end come back as (-1e30, -1)."""
    s = jax.lax.dot_general(
        queries.astype(jnp.float32),
        bank.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, N)
    n = bank.shape[0]
    if n < k:
        s = jnp.pad(s, ((0, 0), (0, k - n)), constant_values=_sim.NEG_INF)
    top_s, top_i = jax.lax.top_k(s, k)
    top_i = jnp.where(top_s <= _sim.NEG_INF / 2, -1, top_i).astype(jnp.int32)
    return top_s, top_i


def resident_topk(queries, bank, *, k=1, block_q=128, block_n=1024):
    """Top-k against a bank that is already device-resident (DeviceBank).

    Dispatch rule (the resident twin of the interpret/Mosaic rule above):
    on TPU this compiles the Pallas blocked kernel with Mosaic, streaming
    the resident bank through the MXU with zero bank H2D; on CPU it runs a
    jitted dense XLA matmul + ``lax.top_k`` instead — interpret-mode Pallas
    would re-simulate the grid in Python per call and forfeit the resident
    bank's entire advantage. Both paths match ``ref.topk_cosine_ref`` on
    indices exactly (scores to float tolerance).
    """
    if queries.shape[0] == 0 or bank.shape[0] == 0:
        return (
            jnp.full((queries.shape[0], k), _sim.NEG_INF, jnp.float32),
            jnp.full((queries.shape[0], k), -1, jnp.int32),
        )
    if _on_cpu():
        return _dense_topk(queries, bank, k=k)
    return _sim.topk_cosine(
        queries, bank, k, block_q=block_q, block_n=block_n, interpret=False
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_op(x, dt, A_log, B_, C_, D, *, chunk=128):
    """Model layout x (B,S,H,P), dt (B,S,H) -> (y (B,S,H,P), state)."""
    y, st = _ssd.ssd(
        x.transpose(0, 2, 1, 3),
        dt.transpose(0, 2, 1),
        A_log,
        B_,
        C_,
        D,
        chunk=chunk,
        interpret=_on_cpu(),
    )
    return y.transpose(0, 2, 1, 3), st

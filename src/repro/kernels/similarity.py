"""Pallas blocked cosine-similarity top-k (the plan-cache lookup kernel).

One device call answers a whole batch of fuzzy lookups: ``queries`` (Q, D)
against a ``bank`` (N, D) of L2-normalized rows -> top-k scores and row
indices per query. This replaces the O(N*D) host numpy scan the paper's
prototype runs per request (Table 5's scaling cliff) with an MXU matmul
whose N dimension is streamed block-by-block.

Tiling: grid = (n_q_blocks, n_n_blocks) with the N axis ``arbitrary`` so a
running top-k can live in VMEM scratch. Each step computes a (bq, bn) score
tile on the MXU, masks the N-padding tail, concatenates with the carried
(bq, k) best-so-far and re-selects top-k via ``jax.lax.top_k`` — a k-way
merge whose cost is O(bq * (k + bn)) on the VPU, negligible next to the
matmul. Ties resolve to the lowest bank row (carried entries precede the
current tile, and earlier tiles hold earlier rows).

On CPU (this container) the kernel runs with ``interpret=True``; on TPU the
same call sites compile to Mosaic. D must be a multiple of 128 (lane width);
the bank embedding dim 384 = 3*128 satisfies this.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _topk_kernel(q_ref, b_ref, s_out, i_out, s_scr, i_scr, *, block_n, n_total,
                 n_blocks, k):
    jn = pl.program_id(1)

    @pl.when(jn == 0)
    def _init():
        s_scr[...] = jnp.full_like(s_scr, NEG_INF)
        i_scr[...] = jnp.full_like(i_scr, -1)

    q = q_ref[...].astype(jnp.float32)  # (bq, D)
    b = b_ref[...].astype(jnp.float32)  # (bn, D)
    s = jax.lax.dot_general(
        q, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn)
    pos = jn * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos >= n_total, NEG_INF, s)

    cat_s = jnp.concatenate([s_scr[...], s], axis=1)  # (bq, k + bn)
    cat_i = jnp.concatenate([i_scr[...], pos], axis=1)
    top_s, sel = jax.lax.top_k(cat_s, k)
    s_scr[...] = top_s
    i_scr[...] = jnp.take_along_axis(cat_i, sel, axis=1)

    @pl.when(jn == n_blocks - 1)
    def _finalize():
        s_out[...] = s_scr[...]
        i_out[...] = jnp.where(s_scr[...] <= NEG_INF / 2, -1, i_scr[...])


def topk_cosine(
    queries: jnp.ndarray,
    bank: jnp.ndarray,
    k: int,
    *,
    block_q: int = 128,
    block_n: int = 1024,
    interpret: bool = False,
):
    """queries (Q, D), bank (N, D), both L2-normalized rows.

    Returns (scores (Q, k) f32, indices (Q, k) i32); indices are -1 (scores
    NEG_INF) past the end when N < k. Q, N need not be block multiples —
    padding is handled here; D must be a lane multiple.
    """
    Q, D = queries.shape
    N = bank.shape[0]
    assert bank.shape[1] == D, (queries.shape, bank.shape)
    assert k >= 1
    if Q == 0 or N == 0:  # degenerate: empty batch or empty bank
        return (
            jnp.full((Q, k), NEG_INF, jnp.float32),
            jnp.full((Q, k), -1, jnp.int32),
        )
    block_q = max(8, min(block_q, max(8, Q)))
    block_n = max(k, min(block_n, max(128, N)))

    q_pad = (-Q) % block_q
    n_pad = (-N) % block_n
    qp = jnp.pad(queries.astype(jnp.float32), ((0, q_pad), (0, 0)))
    bp = jnp.pad(bank.astype(jnp.float32), ((0, n_pad), (0, 0)))
    n_blocks = bp.shape[0] // block_n

    kernel = functools.partial(
        _topk_kernel, block_n=block_n, n_total=N, n_blocks=n_blocks, k=k
    )
    scores, idx = pl.pallas_call(
        kernel,
        grid=(qp.shape[0] // block_q, n_blocks),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda iq, jn: (iq, 0)),
            pl.BlockSpec((block_n, D), lambda iq, jn: (jn, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda iq, jn: (iq, 0)),
            pl.BlockSpec((block_q, k), lambda iq, jn: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, bp)
    return scores[:Q], idx[:Q]

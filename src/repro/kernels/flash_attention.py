"""Pallas TPU flash attention (causal, GQA).

Tiling: grid = (B, Hq, n_q, n_k) with the kv axis innermost ("arbitrary"
semantics — sequential per q block). Per (b, h, iq): stream K/V blocks
through VMEM, fp32 online-softmax accumulators live in VMEM scratch and the
output block is written once on the last kv step. GQA is handled in the
index map (kv head = q head // group), so K/V blocks are fetched once per
q-head without materializing the repeat.

Block sizes default to (128, 128) (MXU-aligned: head_dim 64/80/128 are lane
multiples); for long-context prefill block_k 512 amortizes HBM->VMEM
latency. VMEM footprint per step: q(1*bq*hd) + k,v(2*bk*hd) + acc(bq*hd)
fp32 ~ 128*128*4*4B = 256 KiB at defaults — well under the 16 MiB/core
budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, block_q, block_k, causal, n_k
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # whole block strictly above the diagonal contributes nothing
        run = (ik * block_k) <= (iq * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / jnp.sqrt(float(hd))  # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols > rows, NEG_INF, s)
        m_old = m_scr[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, S, hd); k, v: (B, Hkv, S, hd) -> (B, Hq, S, hd)."""
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k

    grid = (B, Hq, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal, n_k=n_k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)

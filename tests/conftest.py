import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests must see 1 device.
# Multi-device tests spawn subprocesses with their own flags.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can import the tools.analyze gate package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.PRNGKey(0)

"""Training stack: step/loss, optimizer, grad compression, checkpointing,
fault tolerance, microbatching."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.distributed.fault import FaultPolicy, FaultTolerantRunner
from repro.models import lm
from repro.training.grad_compress import (
    compress_with_ef,
    dequantize_int8,
    quantize_int8,
)
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_step import cross_entropy, make_train_step


def _tiny_setup(rng_key, arch="olmo-1b"):
    cfg = registry.get_smoke(arch)
    params = lm.init_params(cfg, rng_key)
    opt_cfg = AdamWConfig(lr=2e-3)
    opt = adamw_init(params, opt_cfg)
    return cfg, params, opt_cfg, opt


def _batch(cfg, step, B=4, S=24):
    rng = np.random.RandomState(step)
    toks = rng.randint(16, 400, size=(B, S + 1))
    toks[:, 1::2] = toks[:, 0:-1:2]  # learnable copy structure
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }


def test_loss_decreases(rng_key):
    cfg, params, opt_cfg, opt = _tiny_setup(rng_key)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    losses = []
    for i in range(25):
        params, opt, m = step(params, opt, _batch(cfg, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses[::6]
    assert all(math.isfinite(l) for l in losses)


def test_chunked_cross_entropy_matches():
    rng = np.random.RandomState(0)
    logits = jnp.asarray(rng.randn(2, 8, 515), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 515, (2, 8)), jnp.int32)
    a = cross_entropy(logits, labels)
    b = cross_entropy(logits, labels, chunk_vocab=128)
    assert abs(float(a) - float(b)) < 1e-5


def test_microbatch_equivalence(rng_key):
    cfg, params, opt_cfg, opt = _tiny_setup(rng_key)
    b = _batch(cfg, 0, B=8)
    s1 = jax.jit(make_train_step(cfg, opt_cfg))
    s2 = jax.jit(make_train_step(cfg, opt_cfg, microbatch=4))
    p1, _, m1 = s1(params, opt, b)
    p2, _, m2 = s2(params, opt, b)
    d = max(
        float(jnp.abs(a.astype(jnp.float32) - c.astype(jnp.float32)).max())
        for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert d < 5e-2  # bf16 params: accumulation-order drift only


def test_adamw_matches_manual_reference():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3], jnp.float32)}
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    st = adamw_init(p, cfg)
    newp, st, _ = adamw_update(p, g, st, cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat, vhat = m / 0.1, v / 0.001
    ref = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)


# -- grad compression -----------------------------------------------------------


def test_quantize_roundtrip_small_error():
    x = jnp.asarray(np.random.RandomState(0).randn(1000), jnp.float32)
    rec = dequantize_int8(quantize_int8(x))
    assert float(jnp.abs(rec - x).max()) < float(jnp.abs(x).max()) / 100


def test_error_feedback_preserves_signal():
    """Sum of (reconstruction + residual) over steps equals sum of grads."""
    rng = np.random.RandomState(1)
    ef = None
    total_recon = np.zeros(300, np.float32)
    total_g = np.zeros(300, np.float32)
    for i in range(20):
        g = jnp.asarray(rng.randn(300) * (1 + i), jnp.float32)
        payload, ef = compress_with_ef(g, ef)
        total_recon += np.asarray(dequantize_int8(payload))
        total_g += np.asarray(g)
    # residual carries over, so totals match up to the final ef
    np.testing.assert_allclose(total_recon + np.asarray(ef), total_g, atol=1e-2)


# -- checkpointing ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, rng_key):
    cfg, params, opt_cfg, opt = _tiny_setup(rng_key)
    store = CheckpointStore(tmp_path, keep_last=2)
    store.save(7, {"params": params, "opt": opt}, extra={"step": 7})
    restored, extra = store.restore({"params": params, "opt": opt})
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep_last=2)
    for s in (10, 20, 30):
        store.save(s, {"x": jnp.ones((4,))})
    assert store.committed_steps() == [20, 30]  # keep_last pruned
    # torn checkpoint (no COMMITTED) is invisible
    torn = tmp_path / "step_000000040"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert 40 not in store.committed_steps()
    store.gc()
    assert not torn.exists()


def test_checkpoint_gc_never_deletes_pinned_segment(tmp_path):
    # cold-tier contract: segments referenced by a live manifest entry are
    # pinned — keep_last age rotation must skip them no matter how many
    # newer segments land, and reclaim them once unpinned
    live = {10}
    store = CheckpointStore(tmp_path, keep_last=2,
                            pin_check=lambda s: s in live)
    for s in (10, 20, 30, 40):
        store.save(s, {"x": jnp.ones((4,))})
    # age order would rotate 10 first; the pin protects it, 20 rotates
    assert store.committed_steps() == [10, 30, 40]
    restored, _ = store.restore({"x": jnp.zeros(4, jnp.float32)}, step=10)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(4))
    live.discard(10)
    store.gc()  # unpinned now: ordinary rotation reclaims it
    assert store.committed_steps() == [30, 40]


def test_checkpoint_corruption_detected(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, {"x": jnp.arange(8, dtype=jnp.float32)})
    d = store._step_dir(1)
    # corrupt the shard
    shard = d / "shard_00000.npz"
    data = bytearray(shard.read_bytes())
    data[len(data) // 2] ^= 0xFF
    shard.write_bytes(bytes(data))
    with pytest.raises(Exception):
        store.restore({"x": jnp.zeros(8, jnp.float32)})


# -- fault tolerance ---------------------------------------------------------------


def _counter_stepper():
    def step(state, batch):
        return state + 1, {"loss": 1.0 / (state + 1)}

    return step


def test_fault_runner_nan_rollback(tmp_path):
    store = CheckpointStore(tmp_path, keep_last=3)
    r = FaultTolerantRunner(_counter_stepper(), store,
                            FaultPolicy(checkpoint_every=5))
    r.inject(12, "nan")
    state, completed, events = r.run(0, lambda s: None, 20)
    assert completed == 20
    assert any(e.kind == "nan" for e in events)
    assert state >= 20  # rollback replays steps; state monotone


def test_fault_runner_worker_loss_resume(tmp_path):
    store = CheckpointStore(tmp_path, keep_last=3)
    r = FaultTolerantRunner(_counter_stepper(), store,
                            FaultPolicy(checkpoint_every=4))
    r.inject(9, "worker_lost")
    state, completed, events = r.run(0, lambda s: None, 15)
    assert completed == 15
    assert any(e.kind == "worker_lost" for e in events)


def test_fault_runner_resumes_from_existing_checkpoint(tmp_path):
    store = CheckpointStore(tmp_path, keep_last=3)
    r1 = FaultTolerantRunner(_counter_stepper(), store,
                             FaultPolicy(checkpoint_every=5))
    r1.run(0, lambda s: None, 10)
    # new runner (fresh process) resumes from step 10's checkpoint
    r2 = FaultTolerantRunner(_counter_stepper(), store,
                             FaultPolicy(checkpoint_every=5))
    state, completed, _ = r2.run(0, lambda s: None, 12)
    assert completed == 12


def test_fault_runner_straggler_detected_on_virtual_clock(tmp_path):
    """Straggler detection without wall-clock flakiness: the runner reads
    an injected VirtualClock, and the stepper makes exactly one step take
    100x the median — that step (and only that step) must roll back."""
    from repro.sim.clock import VirtualClock

    clock = VirtualClock()
    seen = {"straggled": False}

    def stepper(state, batch):
        if state == 15 and not seen["straggled"]:
            seen["straggled"] = True
            clock.advance(10.0)  # one pathological step
        else:
            clock.advance(0.1)  # healthy cadence
        return state + 1, {"loss": 1.0 / (state + 1)}

    store = CheckpointStore(tmp_path, keep_last=3)
    policy = FaultPolicy(checkpoint_every=5, min_steps_for_deadline=5,
                         step_deadline_factor=5.0, min_deadline_s=0.5)
    r = FaultTolerantRunner(stepper, store, policy, clock=clock)
    state, completed, events = r.run(0, lambda s: None, 25)
    assert completed == 25
    stalls = [e for e in events if e.kind == "stall"]
    assert len(stalls) == 1 and stalls[0].action == "rollback"


def test_fault_runner_healthy_virtual_cadence_never_stalls(tmp_path):
    from repro.sim.clock import VirtualClock

    clock = VirtualClock()

    def stepper(state, batch):
        clock.advance(0.1)
        return state + 1, {"loss": 1.0}

    store = CheckpointStore(tmp_path, keep_last=3)
    r = FaultTolerantRunner(stepper, store,
                            FaultPolicy(checkpoint_every=10), clock=clock)
    _, completed, events = r.run(0, lambda s: None, 30)
    assert completed == 30 and events == []


def test_fault_schedule_shared_inject_path():
    """FaultSchedule is the shared inject surface for the runner AND the
    repro.sim harness: multiple faults per step, fire-once semantics."""
    from repro.distributed.fault import FaultSchedule

    fs = FaultSchedule()
    fs.inject(3, "crash", node="cache-1")
    fs.inject(3, "lag", steps=5)
    assert fs.pending() == 2 and bool(fs)
    specs = fs.pop(3)
    assert [s.kind for s in specs] == ["crash", "lag"]
    assert specs[0].details == {"node": "cache-1"}
    assert fs.pop(3) == [] and not fs

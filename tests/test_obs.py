"""repro.obs: metrics registry, structured spans, exporters, attribution.

Covers the four contracts the observability spine makes:

* histogram percentiles track a numpy reference within bucket resolution;
* a traced ``route_batch`` produces the documented span tree
  (router -> distributed lookup -> per-tier -> shard -> pipeline stage)
  with attribute propagation and tokens_saved attribution on hits;
* every counter write is thread-safe — concurrent route_batch + async
  cachegen must conserve requests = hits + misses exactly (the seed had a
  data race here: cachegen-pool threads bumped RouterMetrics unlocked);
* the sim emits byte-identical span streams for identical seeds.
"""

import json
import threading

import numpy as np
import pytest

from repro.core.distributed_cache import DistributedPlanCache
from repro.obs import (
    Histogram,
    InMemoryExporter,
    JsonlExporter,
    MetricsRegistry,
    NOOP_SPAN,
    Tracer,
    chrome_trace,
    current_span,
    get_tracer,
    latency_buckets,
    pow2_buckets,
    trace_span,
    use_tracer,
)
from repro.obs import names as N
from repro.serving.router import TwoTierRouter
from repro.sim import SimConfig, run_sim

# -- registry ------------------------------------------------------------------


def test_registry_counter_gauge_labels():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.counter("c", shard="cache-1").inc(5)
    g = reg.gauge("g")
    g.set(7)
    g.dec(3)
    snap = reg.snapshot()
    assert snap["c"][""] == 3
    assert snap["c"]["shard=cache-1"] == 5
    assert snap["g"][""] == 4
    # same (name, labels) -> same instance, regardless of kwarg order
    h1 = reg.histogram("h", a="1", b="2")
    h2 = reg.histogram("h", b="2", a="1")
    assert h1 is h2


def test_registry_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.histogram("x")


def test_registry_snapshot_is_canonical_and_resettable():
    reg = MetricsRegistry()
    reg.counter("b").inc()
    reg.counter("a", z="1").inc(2)
    reg.histogram("lat").observe(0.5)
    s1 = json.dumps(reg.snapshot(), sort_keys=True)
    s2 = json.dumps(reg.snapshot(), sort_keys=True)
    assert s1 == s2
    reg.reset()
    assert reg.snapshot()["a"]["z=1"] == 0
    assert reg.snapshot()["lat"][""]["count"] == 0


# -- histogram percentile math -------------------------------------------------


def test_histogram_percentiles_track_numpy():
    rs = np.random.RandomState(7)
    samples = rs.lognormal(mean=-5.0, sigma=1.2, size=4000)
    h = Histogram("lat", bounds=latency_buckets())
    for s in samples:
        h.observe(float(s))
    for q in (50.0, 90.0, 99.0):
        ref = float(np.percentile(samples, q))
        est = h.percentile(q)
        # geometric x2 buckets: the interpolated estimate must land within
        # one bucket (a factor of 2) of the numpy reference...
        assert ref / 2 <= est <= ref * 2, (q, ref, est)
        # ...and inside the observed range
        assert samples.min() <= est <= samples.max()
    # monotone in q
    qs = [h.percentile(q) for q in (10, 50, 90, 99, 100)]
    assert qs == sorted(qs)
    snap = h.snapshot()
    assert snap["count"] == len(samples)
    assert snap["mean"] == pytest.approx(samples.mean(), rel=1e-6)
    assert snap["max"] == pytest.approx(samples.max())


def test_histogram_degenerate_and_empty():
    h = Histogram("x")
    assert h.percentile(50) is None
    for _ in range(10):
        h.observe(0.37)
    # all mass in one bucket: clamping to observed min/max makes every
    # percentile exact
    assert h.percentile(50) == pytest.approx(0.37)
    assert h.percentile(99) == pytest.approx(0.37)


def test_pow2_buckets_bucket_small_counts_exactly():
    h = Histogram("cand", bounds=pow2_buckets(8))
    for v in (0, 1, 2, 3, 4, 1000):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 6
    assert snap["max"] == 1000
    assert snap["buckets"]["le_1"] == 2  # 0 and 1
    assert snap["buckets"]["le_2"] == 1
    assert snap["buckets"]["le_4"] == 2  # 3 and 4


# -- spans: nesting, attributes, exporters -------------------------------------


def test_span_nesting_and_attribute_propagation():
    mem = InMemoryExporter()
    fake = {"t": 0.0}

    def clock():
        fake["t"] += 0.25
        return fake["t"]

    tracer = Tracer(clock=clock, exporters=[mem])
    with use_tracer(tracer):
        with trace_span("outer", a=1) as outer:
            assert current_span() is outer
            with trace_span("inner", b=2) as inner:
                assert current_span() is inner
                inner.event("cache.attribution", i=0, hit=False)
            assert current_span() is outer
        assert current_span() is NOOP_SPAN
    # children export before parents (exported on end)
    assert [s["name"] for s in mem.spans] == ["inner", "outer"]
    inner_d, outer_d = mem.spans
    assert inner_d["parent_id"] == outer_d["span_id"]
    assert outer_d["parent_id"] is None
    assert outer_d["attrs"] == {"a": 1}
    assert inner_d["attrs"] == {"b": 2}
    assert inner_d["events"][0]["name"] == "cache.attribution"
    assert outer_d["start"] < inner_d["start"] <= inner_d["end"] <= outer_d["end"]


def test_tracer_disabled_is_noop():
    assert get_tracer().n_spans == 0  # NoopTracer outside use_tracer
    with trace_span("anything", x=1) as sp:
        assert sp is NOOP_SPAN
        sp.set(y=2)
        sp.event("e")  # all swallowed


def test_jsonl_lines_are_canonical_and_chrome_trace_loads(tmp_path):
    mem = InMemoryExporter()
    path = tmp_path / "t.jsonl"
    jsonl = JsonlExporter(str(path))
    tracer = Tracer(exporters=[mem, jsonl])
    with use_tracer(tracer):
        with trace_span("outer"):
            with trace_span("inner", k="v"):
                pass
    jsonl.close()
    lines = path.read_text().splitlines()
    assert lines == mem.lines()
    for line in lines:
        assert json.dumps(json.loads(line), sort_keys=True,
                          separators=(",", ":")) == line
    doc = chrome_trace(mem.spans)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert sorted(e["name"] for e in xs) == ["inner", "outer"]
    # one admission tree = one track: tid is the root span id
    root = [s for s in mem.spans if s["parent_id"] is None][0]["span_id"]
    assert {e["tid"] for e in xs} == {root}


# -- the traced serving path ---------------------------------------------------


def _router(cache, **kw):
    return TwoTierRouter(
        cache,
        extract_keyword=lambda r: r["kw"],
        plan_large=lambda r: {"plan": f"fresh:{r['kw']}"},
        plan_small_with_template=lambda r, tpl: {"plan": "adapted", "tpl": tpl},
        make_template=lambda r, res: res["plan"],
        **kw,
    )


def _trace_route_batch(async_cachegen=False):
    mem = InMemoryExporter()
    tracer = Tracer(exporters=[mem])
    cache = DistributedPlanCache(2, fuzzy=True, fuzzy_threshold=0.5,
                                 capacity_per_node=32)
    router = _router(cache, async_cachegen=async_cachegen)
    with use_tracer(tracer):
        router.route_batch([{"kw": "alpha beta"}, {"kw": "gamma delta"}])
        out = router.route_batch(
            [{"kw": "alpha beta"},          # exact hit
             {"kw": "alpha beta please"},   # fuzzy hit
             {"kw": "zeta eta"}])           # miss
        router.drain()
    router.close()
    return mem, router, out


def test_route_batch_span_tree_and_attribution():
    mem, router, out = _trace_route_batch()
    by_id = {s["span_id"]: s for s in mem.spans}

    def ancestry(s):
        names = []
        pid = s["parent_id"]
        while pid is not None:
            names.append(by_id[pid]["name"])
            pid = by_id[pid]["parent_id"]
        return names

    # the acceptance chain: a match.stage span whose ancestry walks up
    # through the shard cache, the tier fan-out, the distributed lookup,
    # and the router batch
    chains = [
        ancestry(s) for s in mem.spans if s["name"] == N.SPAN_MATCH_STAGE
    ]
    assert any(
        set(c) >= {N.SPAN_CACHE_LOOKUP, N.SPAN_SHARD_CALL, N.SPAN_DCACHE_TIER,
                   N.SPAN_DCACHE_LOOKUP, N.SPAN_ROUTER_LOOKUP,
                   N.SPAN_ROUTE_BATCH}
        for c in chains
    ), chains
    # attribute propagation: shard label on the per-shard cache span,
    # stage name on the pipeline stage span, backend on index.topk
    cache_spans = [s for s in mem.spans if s["name"] == N.SPAN_CACHE_LOOKUP]
    assert {s["attrs"]["shard"] for s in cache_spans} <= {"cache-0", "cache-1"}
    stages = {s["attrs"]["stage"] for s in mem.spans
              if s["name"] == N.SPAN_MATCH_STAGE}
    assert "exact" in stages and "fuzzy" in stages
    topk = [s for s in mem.spans if s["name"] == N.SPAN_INDEX_TOPK]
    assert topk and all("backend" in s["attrs"] for s in topk)

    # attribution: batch 2 had 2 hits, 1 miss
    batches = [s for s in mem.spans if s["name"] == N.SPAN_ROUTE_BATCH]
    events = [ev for s in batches for ev in s["events"]
              if ev["name"] == N.EVENT_ATTRIBUTION]
    assert len(events) == 5  # one per routed request
    hits = [ev["attrs"] for ev in events if ev["attrs"]["hit"]]
    misses = [ev["attrs"] for ev in events if not ev["attrs"]["hit"]]
    assert len(hits) == 2 and len(misses) == 3
    for a in hits:
        assert a["tier"] == "small"
        assert a["tokens_saved"] >= 1
        assert a["stage"] in ("exact", "fuzzy")
        assert a["node"] in ("cache-0", "cache-1")
        assert "matched_key" in a and "replica_tier" in a
    assert {a["stage"] for a in hits} == {"exact", "fuzzy"}
    assert all(a["tier"] == "large" for a in misses)
    assert router.metrics.tokens_saved == sum(a["tokens_saved"] for a in hits)


def test_async_cachegen_spans_parent_to_submitting_route():
    mem, router, _ = _trace_route_batch(async_cachegen=True)
    gens = [s for s in mem.spans if s["name"] == N.SPAN_CACHEGEN]
    assert gens, "async cachegen produced no spans"
    by_id = {s["span_id"]: s for s in mem.spans}
    for g in gens:
        assert by_id[g["parent_id"]]["name"] in (N.SPAN_ROUTE,
                                                 N.SPAN_ROUTE_BATCH)
    fates = [ev["attrs"]["fate"] for s in mem.spans for ev in s["events"]
             if ev["name"] == N.EVENT_CACHEGEN_FATE]
    assert fates and set(fates) <= {"async", "sync_fallback", "dropped"}


def test_instrumented_names_stay_inside_catalog():
    mem, router, _ = _trace_route_batch(async_cachegen=True)
    span_names = {s["name"] for s in mem.spans}
    assert span_names <= set(N.SPAN_NAMES), span_names - set(N.SPAN_NAMES)
    event_names = {ev["name"] for s in mem.spans for ev in s["events"]}
    assert event_names <= set(N.EVENT_NAMES)
    # the shared registry saw only catalogued metric names
    reg_names = set(router.metrics.registry.names())
    assert reg_names <= set(N.METRIC_NAMES), reg_names - set(N.METRIC_NAMES)


def test_one_registry_spans_router_store_and_index():
    reg = MetricsRegistry()
    cache = DistributedPlanCache(2, fuzzy=True, capacity_per_node=32, obs=reg)
    router = _router(cache)  # auto-discovers cache.obs
    router.route_batch([{"kw": "alpha beta"}])  # miss -> sync admission
    router.route_batch([{"kw": "alpha beta"}])  # exact hit
    router.close()
    snap = reg.snapshot()
    assert snap[N.ROUTER_REQUESTS][""] == 2
    assert snap[N.ROUTER_HITS][""] == 1
    # per-shard store series carry the shard label
    assert set(snap[N.CACHE_HITS]) >= {"", "shard=cache-0", "shard=cache-1"}
    facade_hits = snap[N.CACHE_HITS][""]
    shard_hits = sum(v for k, v in snap[N.CACHE_HITS].items() if k)
    assert facade_hits == shard_hits == 1
    assert snap[N.ROUTER_LOOKUP_LATENCY][""]["count"] == 2


# -- thread safety (the seed's RouterMetrics data race) ------------------------


def test_concurrent_route_batch_with_async_cachegen_conserves_counts():
    mem = InMemoryExporter()
    tracer = Tracer(exporters=[mem])
    cache = DistributedPlanCache(2, fuzzy=False, capacity_per_node=4096)
    router = _router(cache, async_cachegen=True)
    n_threads, per_thread = 8, 30
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(t):
        try:
            barrier.wait()
            for i in range(per_thread):
                # ~half repeats (hits after first admission), ~half unique
                kw = f"shared-{i % 5}" if i % 2 else f"uniq-{t}-{i}"
                router.route_batch([{"kw": kw}])
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    with use_tracer(tracer):
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        router.drain()
    router.close()
    assert not errors
    m = router.metrics
    total = n_threads * per_thread
    assert m.requests == total
    assert m.hits + m.misses == total
    # the raced counters: every miss wave is accounted to exactly one fate
    assert (m.async_cachegens + m.sync_cachegen_fallbacks
            + m.cachegen_dropped) == m.misses
    assert m.lookup_latency.snapshot()["count"] == total
    # span ids unique even under contention
    ids = [s["span_id"] for s in mem.spans]
    assert len(ids) == len(set(ids))


def test_registry_counter_parallel_increments_exact():
    reg = MetricsRegistry()
    c = reg.counter("n")

    def bump():
        for _ in range(10_000):
            c.inc()

    threads = [threading.Thread(target=bump) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 80_000


# -- back-compat views ---------------------------------------------------------


def test_snapshot_schemas_preserved_for_migrated_islands():
    cache = DistributedPlanCache(2, fuzzy=True, capacity_per_node=32)
    router = _router(cache)
    router.route_batch([{"kw": "a b"}, {"kw": "a b"}])
    router.close()
    m = router.metrics.snapshot()
    for k in ("requests", "hit_rate", "large_tier_calls", "small_tier_calls",
              "async_cachegens", "sync_cachegen_fallbacks",
              "cachegen_dropped", "lookup_s", "tokens_saved",
              "lookup_latency"):
        assert k in m
    s = cache.stats.snapshot()
    assert set(s) >= {"hits", "misses", "inserts", "evictions", "hit_rate"}
    # reset-on-clear: the shared-registry views must zero, not detach
    cache.clear()
    assert cache.stats.hits == 0 and cache.stats.inserts == 0


# -- sim determinism -----------------------------------------------------------


@pytest.mark.parametrize("fault", ["none", "async_cachegen"])
def test_sim_span_stream_is_byte_identical_per_seed(fault):
    cfg = SimConfig(seed=11, scenario="skewed_reuse", fault=fault, n_ops=20)
    a = run_sim(cfg)
    b = run_sim(cfg)
    assert a.n_spans > 0
    assert a.span_digest == b.span_digest
    assert a.trace_hash == b.trace_hash
    assert a.span_summary == b.span_summary
    assert N.SPAN_DCACHE_LOOKUP in a.span_summary
    other = run_sim(SimConfig(seed=12, scenario="skewed_reuse", fault=fault,
                              n_ops=20))
    assert other.span_digest != a.span_digest

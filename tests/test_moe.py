"""MoE path equivalence + capacity semantics (single device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe


def _cfg(E=8, K=2, cf=8.0, act="swiglu"):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=2, head_dim=8, d_ff=16, vocab_size=64,
        mlp_act=act,
        moe=MoEConfig(num_experts=E, experts_per_token=K, d_ff_expert=16,
                      capacity_factor=cf, mode="dense"),
        param_dtype="float32", dtype="float32",
    )


def test_dense_matches_grouped_high_capacity(rng_key):
    cfg = _cfg(cf=8.0)
    p = moe.moe_init(cfg, rng_key)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 32))
    y_d, aux_d = moe.moe_forward_dense(cfg, p, x)
    y_g, aux_g = moe.moe_forward_grouped(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_g), atol=1e-5)
    assert abs(float(aux_d) - float(aux_g)) < 1e-6


def test_squared_relu_experts(rng_key):
    cfg = _cfg(act="squared_relu")
    p = moe.moe_init(cfg, rng_key)
    assert "w_gate" not in p
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 32))
    y_d, _ = moe.moe_forward_dense(cfg, p, x)
    y_g, _ = moe.moe_forward_grouped(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_g), atol=1e-5)


def test_low_capacity_drops_tokens(rng_key):
    """With capacity_factor << 1, outputs differ from dropless (drops occur)
    but remain finite — GShard semantics."""
    cfg = _cfg(cf=0.25)
    p = moe.moe_init(cfg, rng_key)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32))
    y_d, _ = moe.moe_forward_dense(cfg, p, x)
    y_g, _ = moe.moe_forward_grouped(cfg, p, x)
    assert np.isfinite(np.asarray(y_d)).all()
    assert np.abs(np.asarray(y_d - y_g)).max() > 1e-4


def test_aux_loss_decreases_for_balanced_router(rng_key):
    """Uniform router ~ lowest aux loss; a collapsed router scores higher."""
    cfg = _cfg()
    p = moe.moe_init(cfg, rng_key)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 32))
    _, aux_uniform = moe.moe_forward_grouped(cfg, p, x)
    p_collapsed = dict(p)
    p_collapsed["router"] = p["router"].at[:, 0].add(100.0)  # all -> expert 0
    _, aux_collapsed = moe.moe_forward_grouped(cfg, p_collapsed, x)
    assert float(aux_collapsed) > float(aux_uniform)


def test_router_weights_normalized(rng_key):
    cfg = _cfg()
    p = moe.moe_init(cfg, rng_key)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 32))
    _, topk_w, _, _ = moe._routing(cfg, p, x.reshape(-1, 32))
    np.testing.assert_allclose(np.asarray(topk_w.sum(-1)), 1.0, atol=1e-5)


def test_ep_fallback_without_mesh(rng_key):
    """mode='ep' without a mesh must fall back to the grouped oracle."""
    import dataclasses

    cfg = _cfg()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, mode="ep"))
    p = moe.moe_init(cfg, rng_key)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 32))
    y, _ = moe.moe_forward(cfg, p, x, None)
    y_g, _ = moe.moe_forward_grouped(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_g), atol=1e-6)

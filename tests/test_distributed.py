"""Multi-device tests (subprocesses with forced host device counts) +
single-process sharding-rule tests."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.models import lm

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# -- sharding rules (no mesh needed beyond fake shapes) ------------------------


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_param_pspecs_cover_tree(arch):
    """Every leaf gets a spec and specs never reuse a mesh axis."""
    cfg = registry.get_smoke(arch)
    profile = registry.get_sharding(arch)
    params = lm.abstract_params(registry.get(arch))

    class FakeMesh:
        axis_names = ("pod", "data", "model")

        class devices:
            shape = (2, 16, 16)

    specs = shd.param_pspecs(params, profile, FakeMesh)
    n_sharded = 0
    for spec, leaf in zip(jax.tree.leaves(specs), jax.tree.leaves(params)):
        seen = []
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            ext = 1
            for a in axes:
                assert a not in seen, (arch, spec)
                seen.append(a)
                ext *= dict(zip(("pod", "data", "model"), (2, 16, 16)))[a]
            assert leaf.shape[i] % ext == 0, (arch, spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0, arch  # something must actually shard


def test_big_matrices_are_sharded():
    cfg = registry.get("kimi-k2-1t-a32b")
    profile = registry.get_sharding("kimi-k2-1t-a32b")
    params = lm.abstract_params(cfg)

    class FakeMesh:
        axis_names = ("pod", "data", "model")

        class devices:
            shape = (2, 16, 16)

    specs = shd.param_pspecs(params, profile, FakeMesh)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    leaves = dict()
    for path, spec in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        leaves[key] = spec
    # expert weights must shard over EP axis
    moe_spec = [s for k, s in leaves.items() if "moe" in k and "w_up" in k][0]
    assert "model" in str(moe_spec)
    assert any("data" in str(s) for s in leaves.values())  # FSDP present


# -- multi-device subprocess tests ----------------------------------------------
#
# These target the jax>=0.6 mesh surface through repro.distributed.mesh_compat
# (AxisType / set_mesh / shard_map(check_vma=) mapped onto their jax 0.4.37
# equivalents), so they run on both the pinned 0.4.37 container and newer jax.


def test_ep_moe_matches_oracle_on_mesh():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.distributed.mesh_compat import make_mesh, set_mesh
        from repro.models import moe
        cfg = ModelConfig(name='t', family='moe', num_layers=1, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32,
                          vocab_size=128,
                          moe=MoEConfig(num_experts=8, experts_per_token=2,
                                        d_ff_expert=32, capacity_factor=8.0,
                                        mode='ep'),
                          param_dtype='float32', dtype='float32')
        p = moe.moe_init(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64), jnp.float32)
        y_ref, _ = moe.moe_forward_grouped(cfg, p, x)
        mesh = make_mesh((2, 4), ('data', 'model'))
        with set_mesh(mesh):
            y, _ = jax.jit(lambda p, x: moe.moe_forward_ep(
                cfg, p, x, mesh=mesh, ep_axis='model', dp_axes=('data',)))(p, x)
        err = float(jnp.abs(y - y_ref).max())
        assert err < 1e-5, err
        print('OK', err)
        """
    )


def test_pipeline_parallel_fwd_bwd():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.mesh_compat import make_mesh, set_mesh
        from repro.distributed.pipeline import pipeline_apply, sequential_reference
        mesh = make_mesh((4,), ('pipe',))
        L, D, B = 8, 16, 8
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
        layer_fn = lambda w, h: jnp.tanh(h @ w) + h
        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        with set_mesh(mesh):
            y = jax.jit(lambda ws, x: pipeline_apply(
                layer_fn, ws, x, mesh=mesh, axis='pipe', n_microbatches=4))(ws, x)
            g = jax.jit(jax.grad(lambda ws: jnp.sum(pipeline_apply(
                layer_fn, ws, x, mesh=mesh, axis='pipe',
                n_microbatches=4)**2)))(ws)
        ref = sequential_reference(layer_fn, ws, x)
        gref = jax.grad(lambda ws: jnp.sum(
            sequential_reference(layer_fn, ws, x)**2))(ws)
        assert float(jnp.abs(y - ref).max()) < 1e-5
        assert float(jnp.abs(g - gref).max()) < 1e-3
        print('OK')
        """,
        devices=4,
    )


def test_sharded_train_step_runs_and_matches_single():
    """Tiny model: sharded (2x4 mesh) train step == single-device step."""
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import registry
        from repro.distributed import sharding as shd
        from repro.distributed.mesh_compat import make_mesh, set_mesh
        from repro.models import lm
        from repro.training.optimizer import AdamWConfig, adamw_init
        from repro.training.train_step import make_train_step
        cfg = dataclasses.replace(registry.get_smoke('olmo-1b'),
                                  dtype='float32', param_dtype='float32')
        profile = registry.get_sharding('olmo-1b')
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, opt_cfg)
        rng = np.random.RandomState(0)
        toks = rng.randint(10, 400, size=(8, 17))
        batch = {'tokens': jnp.asarray(toks[:, :-1], jnp.int32),
                 'labels': jnp.asarray(toks[:, 1:], jnp.int32)}
        # single device reference
        p1, o1, m1 = jax.jit(make_train_step(cfg, opt_cfg))(params, opt, batch)
        # sharded
        mesh = make_mesh((2, 4), ('data', 'model'))
        ctx = lm.ParallelCtx(mesh=mesh, dp_axes=('data',))
        psh = shd.to_shardings(shd.param_pspecs(params, profile, mesh), mesh)
        bsh = shd.to_shardings(shd.batch_pspecs(batch, mesh), mesh)
        osh = {'m': psh, 'v': psh,
               'step': shd.to_shardings(jax.sharding.PartitionSpec(), mesh)}
        with set_mesh(mesh):
            step = jax.jit(make_train_step(cfg, opt_cfg, ctx),
                           in_shardings=(psh, osh, bsh))
            p2, o2, m2 = step(params, opt, batch)
        d = float(abs(float(m1['loss']) - float(m2['loss'])))
        assert d < 1e-4, d
        dp = max(float(jnp.abs(a - b).max())
                 for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert dp < 1e-4, dp
        print('OK', d, dp)
        """
    )


def test_elastic_reshard_preserves_values():
    run_sub(
        """
        import jax, jax.numpy as jnp
        from repro.configs import registry
        from repro.distributed.elastic import reshard_tree
        from repro.distributed.mesh_compat import make_mesh
        from repro.models import lm
        cfg = registry.get_smoke('qwen2.5-3b')
        profile = registry.get_sharding('qwen2.5-3b')
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        mesh8 = make_mesh((2, 4), ('data', 'model'))
        mesh4 = make_mesh((1, 4), ('data', 'model'))
        p8 = reshard_tree(params, mesh8, profile)
        p4 = reshard_tree(p8, mesh4, profile)  # "node loss": shrink mesh
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p4)):
            assert float(jnp.abs(a.astype(jnp.float32) -
                                 b.astype(jnp.float32)).max()) == 0.0
        print('OK')
        """
    )


def test_compressed_allreduce_on_mesh():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.mesh_compat import make_mesh, set_mesh, shard_map
        from repro.training.grad_compress import compressed_allreduce, ef_state_init
        mesh = make_mesh((8,), ('data',))
        grads = {'w': jnp.arange(8*512, dtype=jnp.float32).reshape(8, 512) / 100}
        ef = ef_state_init({'w': grads['w'][0]})
        def f(g, ef):
            return compressed_allreduce({'w': g}, ef, 'data')
        fn = shard_map(f, mesh=mesh, in_specs=(P('data', None), P()),
                       out_specs=(P(), P()), check_vma=False)
        with set_mesh(mesh):
            out, new_ef = fn(grads['w'], ef)
        ref = np.asarray(grads['w']).mean(0)
        err = float(np.abs(np.asarray(out['w']) - ref).max())
        rel = err / (abs(ref).max() + 1e-9)
        assert rel < 0.02, rel  # int8 quantization error bound
        print('OK', rel)
        """
    )

"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(shape, dtype, k):
    x = jax.random.normal(k, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,hd,bq,bk",
    [
        (1, 4, 4, 128, 64, 64, 64),   # MHA
        (2, 8, 2, 256, 64, 128, 64),  # GQA 4:1
        (1, 8, 8, 192, 32, 64, 64),   # non-pow2 seq (192 = 3*64)
        (2, 4, 1, 128, 128, 64, 128), # MQA, wide head
    ],
)
def test_flash_attention_sweep(B, Hq, Hkv, S, hd, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((B, S, Hq, hd), dtype, ks[0])
    k = _rand((B, S, Hkv, hd), dtype, ks[1])
    v = _rand((B, S, Hkv, hd), dtype, ks[2])
    o = ops.flash_attention_op(q, k, v, block_q=bq, block_k=bk)
    o_ref = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,M,hd,length,bk",
    [
        (2, 8, 2, 256, 64, 177, 64),
        (1, 4, 4, 512, 128, 512, 128),
        (3, 8, 1, 128, 64, 1, 64),  # single valid position
    ],
)
def test_decode_attention_sweep(B, Hq, Hkv, M, hd, length, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((B, 1, Hq, hd), dtype, ks[0])
    ck = _rand((B, M, Hkv, hd), dtype, ks[1])
    cv = _rand((B, M, Hkv, hd), dtype, ks[2])
    o = ops.decode_attention_op(q, ck, cv, jnp.asarray(length, jnp.int32), block_k=bk)
    o_ref = ref.decode_attention_ref(
        q[:, 0], ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
        jnp.asarray(length, jnp.int32),
    )
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o[:, 0], np.float32), np.asarray(o_ref, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,M,hd,lengths,bk",
    [
        (3, 8, 2, 256, 64, (17, 177, 256), 64),   # mixed-length batch
        (2, 4, 4, 128, 32, (1, 128), 64),         # extremes
        (4, 4, 1, 256, 64, (64, 64, 64, 64), 128),  # uniform via vector
    ],
)
def test_decode_attention_per_sequence_lengths(B, Hq, Hkv, M, hd, lengths, bk,
                                               dtype):
    """Each batch row masks to ITS OWN valid count (the historical scalar
    masked every row to one shared length — wrong for mixed batches)."""
    ks = jax.random.split(KEY, 3)
    q = _rand((B, 1, Hq, hd), dtype, ks[0])
    ck = _rand((B, M, Hkv, hd), dtype, ks[1])
    cv = _rand((B, M, Hkv, hd), dtype, ks[2])
    lens = jnp.asarray(lengths, jnp.int32)
    o = ops.decode_attention_op(q, ck, cv, lens, block_k=bk)
    o_ref = ref.decode_attention_ref(
        q[:, 0], ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3), lens
    )
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o[:, 0], np.float32), np.asarray(o_ref, np.float32),
        atol=tol, rtol=tol,
    )
    # and each row individually equals a scalar-length call on that row
    for b, ln in enumerate(lengths):
        ob = ops.decode_attention_op(
            q[b : b + 1], ck[b : b + 1], cv[b : b + 1],
            jnp.asarray(ln, jnp.int32), block_k=bk,
        )
        np.testing.assert_array_equal(np.asarray(o[b : b + 1]), np.asarray(ob))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,hd,ps,npages,P,lengths",
    [
        (3, 8, 2, 64, 64, 16, 4, (200, 100, 256)),
        (2, 4, 4, 32, 16, 32, 8, (128, 7)),
        (1, 4, 1, 64, 32, 8, 2, (33,)),
    ],
)
def test_paged_attention_vs_ref(B, Hq, Hkv, hd, ps, npages, P, lengths, dtype):
    """Gather-through-page-table decode vs the numpy gather + dense oracle.
    Page tables are permuted (out-of-order pool rows) with -1 past the end."""
    ks = jax.random.split(KEY, 3)
    q = _rand((B, Hq, hd), dtype, ks[0])
    k_pages = _rand((npages, ps, Hkv, hd), dtype, ks[1])
    v_pages = _rand((npages, ps, Hkv, hd), dtype, ks[2])
    rng = np.random.RandomState(0)
    table = np.full((B, P), -1, np.int32)
    for b, ln in enumerate(lengths):
        n = -(-ln // ps)
        table[b, :n] = rng.choice(npages, size=n, replace=False)
    table = jnp.asarray(table)
    lens = jnp.asarray(lengths, jnp.int32)
    from repro.kernels.paged_attention import paged_decode_attention

    o = paged_decode_attention(q, k_pages, v_pages, table, lens, interpret=True)
    o_ref = ref.paged_attention_ref(q, k_pages, v_pages, table, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_paged_attention_bitmatches_dense_kernel():
    """With page_size == block_k and the pages gathered dense in table
    order, the paged kernel performs the same block-sequential online
    softmax as decode_attention — outputs must be BITWISE equal."""
    B, Hq, Hkv, hd, ps, npages, P = 3, 8, 2, 64, 64, 16, 4
    ks = jax.random.split(KEY, 3)
    q = _rand((B, 1, Hq, hd), jnp.float32, ks[0])
    k_pages = _rand((npages, ps, Hkv, hd), jnp.float32, ks[1])
    v_pages = _rand((npages, ps, Hkv, hd), jnp.float32, ks[2])
    table = jnp.asarray(
        [[3, 1, 7, -1], [2, 0, -1, -1], [5, 9, 11, 4]], jnp.int32
    )
    lens = jnp.asarray([200, 100, 256], jnp.int32)
    o_paged = ops.paged_decode_attention_op(q, k_pages, v_pages, table, lens)
    pt = np.maximum(np.asarray(table, np.int64), 0)
    kd = jnp.asarray(np.asarray(k_pages)[pt].reshape(B, P * ps, Hkv, hd))
    vd = jnp.asarray(np.asarray(v_pages)[pt].reshape(B, P * ps, Hkv, hd))
    o_dense = ops.decode_attention_op(q, kd, vd, lens, block_k=ps)
    assert np.array_equal(np.asarray(o_paged), np.asarray(o_dense))


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 32)])
@pytest.mark.parametrize("N", [16, 64])
def test_wkv6_sweep(S, chunk, N):
    B, H = 2, 3
    ks = jax.random.split(KEY, 5)
    r = _rand((B, S, H, N), jnp.float32, ks[0]) * 0.5
    k = _rand((B, S, H, N), jnp.float32, ks[1]) * 0.5
    v = _rand((B, S, H, N), jnp.float32, ks[2]) * 0.5
    w = -jnp.exp(_rand((B, S, H, N), jnp.float32, ks[3]) * 0.5 - 2.0)
    u = _rand((H, N), jnp.float32, ks[4]) * 0.3
    if S % chunk:
        pytest.skip("kernel requires divisibility")
    y, st = ops.wkv6_op(r, k, v, w, u, chunk=chunk)
    y_ref, st_ref = ref.rwkv6_ref(r, k, v, w, u, jnp.zeros((B, H, N, N)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("S,chunk,P,Ns", [(64, 16, 32, 16), (128, 64, 64, 64)])
def test_ssd_sweep(S, chunk, P, Ns):
    B, H = 2, 3
    ks = jax.random.split(KEY, 4)
    x = _rand((B, S, H, P), jnp.float32, ks[0]) * 0.5
    dt = jax.nn.softplus(_rand((B, S, H), jnp.float32, ks[1]))
    A_log = jnp.zeros((H,))
    D = jnp.ones((H,))
    Bc = _rand((B, S, Ns), jnp.float32, ks[2]) * 0.5
    Cc = _rand((B, S, Ns), jnp.float32, ks[3]) * 0.5
    y, st = ops.ssd_op(x, dt, A_log, Bc, Cc, D, chunk=chunk)
    y_ref, st_ref = ref.ssd_ref(x, dt, A_log, Bc, Cc, D, jnp.zeros((B, H, P, Ns)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=5e-4, rtol=1e-3)


def test_kernels_match_model_modules():
    """Kernel paths equal the model's chunked jnp implementations too."""
    from repro.models.rwkv import wkv6_chunked

    B, S, H, N = 1, 64, 2, 32
    ks = jax.random.split(KEY, 5)
    r = _rand((B, S, H, N), jnp.float32, ks[0]) * 0.5
    k = _rand((B, S, H, N), jnp.float32, ks[1]) * 0.5
    v = _rand((B, S, H, N), jnp.float32, ks[2]) * 0.5
    w = -jnp.exp(_rand((B, S, H, N), jnp.float32, ks[3]) * 0.3 - 2.0)
    u = _rand((H, N), jnp.float32, ks[4]) * 0.3
    y_kernel, st_kernel = ops.wkv6_op(r, k, v, w, u, chunk=16)
    y_model, st_model = wkv6_chunked(r, k, v, w, u, jnp.zeros((B, H, N, N)), chunk=16)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_model), atol=1e-4, rtol=1e-3
    )
